//! End-to-end validation driver (DESIGN.md deliverable (b)/(e2e)):
//! train the scaled AlexNet on a real synthetic corpus for a few
//! hundred steps and log the loss curve, proving all three layers
//! compose: rust pipeline + device sim (L3) -> fused Pallas preprocess
//! kernel (L1) -> AlexNet fwd/bwd/Adam step (L2), all via PJRT.
//!
//! Run: `cargo run --release --example train_alexnet`
//! Env: DLIO_STEPS (default 300), DLIO_PROFILE (micro|mini, default
//!      micro), DLIO_BATCH (default 32), DLIO_EPOCH_FILES (default 2048).
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use dlio::config::{MiniAppConfig, Testbed};
use dlio::coordinator::fixtures::{ensure_corpus, make_sim};
use dlio::coordinator::miniapp;
use dlio::data::CorpusSpec;
use dlio::metrics::Timer;
use dlio::pipeline::Dataset;
use dlio::runtime::Runtime;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let steps = env_usize("DLIO_STEPS", 300);
    let batch = env_usize("DLIO_BATCH", 32);
    let profile =
        std::env::var("DLIO_PROFILE").unwrap_or_else(|_| "micro".into());
    let epoch_files = env_usize("DLIO_EPOCH_FILES", 2048);

    let mut testbed = Testbed::paper(16.0);
    testbed.workdir = format!("{}/train", dlio::config::default_workdir());
    let sim = make_sim(&testbed, None)?;
    let rt = Runtime::open_default()?;

    let manifest =
        ensure_corpus(&sim, "ssd", &CorpusSpec::caltech101(epoch_files))?;
    println!(
        "# corpus: {} files (caltech-101 profile) on simulated SSD",
        manifest.len()
    );
    println!("# model: alexnet-{profile}, batch {batch}, {steps} steps");

    let cfg = MiniAppConfig {
        device: "ssd".into(),
        threads: 4,
        batch,
        prefetch: 1,
        iterations: usize::MAX, // bounded by `steps` below
        profile: profile.clone(),
        seed: 7,
    };

    let mut trainer =
        dlio::model::Trainer::new(&rt, &profile, batch, cfg.seed)?;
    println!(
        "# params: {} tensors, {} values ({:.1} MB checkpoint)",
        trainer.profile().params.len(),
        trainer.profile().num_params,
        trainer.profile().checkpoint_bytes() as f64 / 1e6
    );

    let total = Timer::start();
    let mut step = 0usize;
    let mut epoch = 0usize;
    println!("step\tepoch\tloss\tstep_ms\timgs_per_s");
    'outer: while step < steps {
        // One epoch per pipeline instantiation (the paper runs single
        // epochs; we chain them with re-shuffled order per epoch).
        let mut epoch_cfg = cfg.clone();
        epoch_cfg.seed = cfg.seed + epoch as u64;
        let mut ds = miniapp::input_pipeline(
            Arc::clone(&sim), &rt, &manifest, &epoch_cfg)?;
        while let Some(b) = ds.next() {
            let b = b?;
            let t = Timer::start();
            let loss = trainer.step(&b)?;
            let dt = t.secs();
            step += 1;
            if step % 10 == 0 || step == 1 {
                println!(
                    "{step}\t{epoch}\t{loss:.4}\t{:.0}\t{:.1}",
                    dt * 1e3,
                    batch as f64 / dt
                );
            }
            if step >= steps {
                break 'outer;
            }
        }
        epoch += 1;
        sim.drop_caches(); // cold-cache per epoch, as the paper enforces
    }
    let secs = total.secs();

    let losses = trainer.losses();
    let first_avg: f32 =
        losses.iter().take(20).sum::<f32>() / losses.len().min(20) as f32;
    let last_avg: f32 = losses.iter().rev().take(20).sum::<f32>()
        / losses.len().min(20) as f32;
    println!(
        "# done: {step} steps, {} epochs, {:.1}s wall \
         ({:.1} imgs/s end-to-end)",
        epoch + 1, secs, (step * batch) as f64 / secs
    );
    println!(
        "# loss: first-20 avg {first_avg:.4} -> last-20 avg {last_avg:.4}"
    );
    anyhow::ensure!(
        last_avg < first_avg,
        "training did not reduce loss ({first_avg} -> {last_avg})"
    );
    println!("# OK: loss decreased");
    Ok(())
}
