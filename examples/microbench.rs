//! The STREAM-like I/O micro-benchmark (paper §III-A), swept the way
//! §V-A does: threads x devices, full-preprocessing and read-only
//! variants — a compact live rendition of Figs. 4 & 5.
//!
//! Run: `cargo run --release --example microbench`
//! Env: DLIO_TIME_SCALE (default 8), DLIO_FILES (default 1024).

use std::sync::Arc;

use dlio::config::{default_time_scale, MicrobenchConfig, Testbed};
use dlio::coordinator::{ensure_corpus, make_sim, microbench};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;
use dlio::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let files: usize = std::env::var("DLIO_FILES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let mut testbed = Testbed::paper(default_time_scale());
    testbed.workdir =
        format!("{}/microbench", dlio::config::default_workdir());
    let sim = make_sim(&testbed, None)?;
    let rt = Runtime::open_default()?;

    // ImageNet-subset-like corpus (median 112 KB), mirrored per device.
    let spec = CorpusSpec::imagenet_subset(files);

    for preprocess in [true, false] {
        println!(
            "\n== micro-benchmark, {} ==",
            if preprocess {
                "full pipeline: read + decode + fused resize (Fig. 4)"
            } else {
                "read-only map function (Fig. 5)"
            }
        );
        let mut table =
            Table::new(&["Device", "1 thr", "2 thr", "4 thr", "8 thr",
                         "scale 1->8"]);
        for device in ["hdd", "ssd", "optane", "lustre"] {
            let manifest = ensure_corpus(&sim, device, &spec)?;
            let mut cells = vec![device.to_string()];
            let mut first = 0.0;
            let mut last = 0.0;
            for threads in [1usize, 2, 4, 8] {
                let cfg = MicrobenchConfig {
                    device: device.into(),
                    threads,
                    batch: 64,
                    iterations: files.min(512) / 64,
                    preprocess,
                    out_size: 64,
                    readahead: 0,
                    shards: 1,
                };
                let r = microbench::run(
                    Arc::clone(&sim), &rt, &manifest, &cfg, 7)?;
                let ips = r.images_per_sec();
                if threads == 1 {
                    first = ips;
                }
                last = ips;
                cells.push(format!("{ips:.0} img/s"));
            }
            cells.push(format!("{:.2}x", last / first));
            table.row(&cells);
        }
        print!("{}", table.render());
    }
    println!("\n(paper: HDD 2.3x at 8 threads, Lustre 7.8x; read-only \
              approaches the IOR bound, preprocessing caps below it)");
    Ok(())
}
