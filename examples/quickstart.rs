//! Quickstart: the whole stack in ~60 lines.
//!
//! Builds the simulated testbed, synthesizes a tiny Caltech-101-style
//! corpus on the simulated SSD, assembles the paper's input pipeline
//! (shuffle -> parallel map with the fused Pallas preprocess kernel ->
//! batch -> prefetch), and trains a scaled AlexNet for a few steps via
//! the AOT train-step executable.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::sync::Arc;

use dlio::config::{MiniAppConfig, Testbed};
use dlio::coordinator::{ensure_corpus, make_sim, miniapp};
use dlio::data::CorpusSpec;
use dlio::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Testbed: the paper's four devices (HDD/SSD/Optane/Lustre),
    //    simulated 16x faster than the modelled hardware.
    let mut testbed = Testbed::paper(16.0);
    testbed.workdir = format!("{}/quickstart", dlio::config::default_workdir());
    let sim = make_sim(&testbed, None)?;

    // 2. Data: 512 synthetic images with Caltech-101's size profile.
    let corpus = CorpusSpec::caltech101(512);
    let manifest = ensure_corpus(&sim, "ssd", &corpus)?;
    println!("corpus: {} files on ssd://, {} classes",
             manifest.len(), manifest.num_classes);

    // 3. Runtime: AOT artifacts (HLO text) compiled via PJRT.
    let rt = Runtime::open_default()?;

    // 4. The mini-application (paper §III-B): input pipeline + training.
    let cfg = MiniAppConfig {
        device: "ssd".into(),
        threads: 4,
        batch: 16,
        prefetch: 1,
        iterations: 8,
        profile: "micro".into(),
        seed: 42,
    };
    let result = miniapp::run(Arc::clone(&sim), &rt, &manifest, &cfg)?;

    println!(
        "trained {} steps over {} images in {:.2}s \
         (ingest wait {:.3}s, compute {:.2}s)",
        result.steps, result.images, result.total_secs,
        result.ingest_wait_secs, result.compute_secs
    );
    println!("loss curve: {:?}", result.losses);
    Ok(())
}
