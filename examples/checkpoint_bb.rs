//! Checkpoint + burst-buffer study (paper §III-C / §V-C, Figs. 9-10).
//!
//! Trains the mini-app for N iterations, checkpointing every K to each
//! target the paper tests — HDD, SSD, Optane, and the Optane->HDD
//! burst buffer — plus the no-checkpoint baseline, printing total
//! runtimes and median checkpoint stalls, then a dstat-style trace of
//! the burst-buffer run.
//!
//! Run: `cargo run --release --example checkpoint_bb`

use std::sync::Arc;

use dlio::config::{
    CheckpointTarget, CkptStudyConfig, MiniAppConfig, Testbed,
};
use dlio::coordinator::fixtures::{ensure_corpus, make_sim};
use dlio::coordinator::miniapp;
use dlio::data::CorpusSpec;
use dlio::metrics::{median, Table};
use dlio::runtime::Runtime;
use dlio::trace::Dstat;

fn main() -> anyhow::Result<()> {
    let mut testbed = Testbed::paper(8.0);
    testbed.workdir = format!("{}/ckpt", dlio::config::default_workdir());
    let rt = Runtime::open_default()?;

    // Paper protocol: images on SSD, prefetch enabled, checkpoint every
    // 20 of 100 iterations (scaled to every 4 of 20 here).
    let mini = MiniAppConfig {
        device: "ssd".into(),
        threads: 4,
        batch: 32,
        prefetch: 1,
        iterations: 20,
        profile: "mini".into(), // ~75 MB checkpoints
        seed: 11,
    };
    let targets = [
        CheckpointTarget::None,
        CheckpointTarget::Direct("hdd".into()),
        CheckpointTarget::Direct("ssd".into()),
        CheckpointTarget::Direct("optane".into()),
        CheckpointTarget::BurstBuffer {
            fast: "optane".into(),
            slow: "hdd".into(),
        },
    ];

    let mut table = Table::new(&[
        "Target", "Total s", "Ckpt stall s", "Median ckpt s",
    ]);
    let mut hdd_total = 0.0;
    let mut bb_total = 0.0;
    for target in targets {
        let tracer = Arc::new(Dstat::new(0.25));
        let sim = make_sim(&testbed, Some(tracer.clone()))?;
        let manifest =
            ensure_corpus(&sim, "ssd", &CorpusSpec::caltech101(1024))?;
        let cfg = CkptStudyConfig {
            mini: mini.clone(),
            target: target.clone(),
            interval: 4,
            max_to_keep: 5,
        };
        let r = miniapp::run_with_checkpoints(
            Arc::clone(&sim), &rt, &manifest, &cfg)?;
        match &target {
            CheckpointTarget::Direct(d) if d == "hdd" => {
                hdd_total = r.total_secs
            }
            CheckpointTarget::BurstBuffer { .. } => bb_total = r.total_secs,
            _ => {}
        }
        table.row(&[
            target.label(),
            format!("{:.2}", r.total_secs),
            format!("{:.2}", r.ckpt_secs),
            format!("{:.2}", median(&mut r.ckpt_durations.clone())),
        ]);
        if matches!(target, CheckpointTarget::BurstBuffer { .. }) {
            println!("\n== dstat trace of the burst-buffer run \
                      (Fig. 10 bottom panel) ==");
            print!("{}", tracer.to_csv());
        }
    }
    println!("\n== Fig. 9: total runtime per checkpoint target ==");
    print!("{}", table.render());
    if hdd_total > 0.0 && bb_total > 0.0 {
        println!(
            "\nburst-buffer speedup over direct-to-HDD (ckpt overhead): \
             paper reports 2.6x total-overhead improvement"
        );
        println!("measured totals: hdd {hdd_total:.2}s vs bb {bb_total:.2}s");
    }
    Ok(())
}
