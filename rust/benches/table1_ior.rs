//! Table I: IOR raw device bandwidth upper bounds.
//!
//! Protocol (§IV): sequential read+write of one large file, 6 reps,
//! first rep discarded as warm-up, median reported, caches dropped
//! between runs.  File size is bench-scaled (the token-bucket model
//! makes bandwidth size-independent past the burst window).

use dlio::bench;
use dlio::config::default_time_scale;
use dlio::metrics::Table;
use dlio::storage::ior;

const PAPER: [(&str, f64, f64); 4] = [
    ("hdd", 163.00, 133.14),
    ("ssd", 280.55, 195.05),
    ("optane", 1603.06, 511.78),
    ("lustre", 1968.618, 991.914),
];

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Table I",
        "IOR max read/write bandwidth per device",
        "HDD 163.00/133.14, SSD 280.55/195.05, Optane 1603.06/511.78, \
         Lustre 1968.618/991.914 MB/s",
    );
    let env = bench::env("table1", None)?;
    let cfg = ior::IorConfig {
        file_bytes: bench::pick(16_000_000u64, 64_000_000, 512_000_000),
        reps: bench::pick(3usize, 6, 6),
    };
    let ts = default_time_scale();
    println!(
        "probe: {} MB x {} reps (time-scale {ts}x; measured values are \
         divided by the scale to report modelled-device terms)",
        cfg.file_bytes / 1_000_000, cfg.reps
    );

    let mut table = Table::new(&[
        "Device", "Read MB/s", "(paper)", "Write MB/s", "(paper)",
        "read err", "write err",
    ]);
    for row in ior::run_all(&env.sim, &cfg)? {
        let (_, pr, pw) = PAPER
            .iter()
            .find(|(n, _, _)| *n == row.device)
            .copied()
            .unwrap_or(("", f64::NAN, f64::NAN));
        let read = row.max_read_mbs / ts;
        let write = row.max_write_mbs / ts;
        table.row(&[
            row.device.clone(),
            format!("{read:.2}"),
            format!("{pr:.2}"),
            format!("{write:.2}"),
            format!("{pw:.2}"),
            format!("{:+.1}%", (read / pr - 1.0) * 100.0),
            format!("{:+.1}%", (write / pw - 1.0) * 100.0),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
