//! Fig. 9: checkpoint study — total mini-app runtime when
//! checkpointing to HDD / SSD / Optane / burst buffer, vs the
//! no-checkpoint baseline.
//!
//! Paper shapes: Optane fastest, then SSD, HDD slowest; the burst
//! buffer (Optane stage + async HDD drain) matches Optane while still
//! landing data on HDD; headline 2.6x improvement vs direct-to-HDD.

use std::sync::Arc;

use dlio::bench;
use dlio::config::{CheckpointTarget, CkptStudyConfig, MiniAppConfig};
use dlio::coordinator::{ensure_corpus, miniapp};
use dlio::data::CorpusSpec;
use dlio::metrics::{median, Table};

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Fig. 9",
        "mini-app runtime by checkpoint target (+ no-ckpt baseline)",
        "optane < ssd < hdd; burst buffer ~= optane; 2.6x vs HDD (§V-C)",
    );
    // Device clock at 1x: checkpoint stalls must dominate single-core
    // training-time jitter (±0.5 s/run) for the Fig. 9 ordering to be
    // readable; at the default 8x a 73 MB HDD checkpoint costs only
    // ~70 ms.
    let env = bench::env_with_scale("fig9", 1.0, None)?;
    // Paper: 100 iterations, ckpt every 20, batch 64 on SSD, prefetch
    // on.  Bench-scaled; the `mini` profile gives ~56 MB checkpoints.
    let iterations = bench::pick(8usize, 10, 100);
    let interval = bench::pick(2usize, 2, 20);
    let files = bench::pick(384usize, 512, 9144);
    let manifest =
        ensure_corpus(&env.sim, "ssd", &CorpusSpec::caltech101(files))?;

    let targets = [
        CheckpointTarget::None,
        CheckpointTarget::Direct("hdd".into()),
        CheckpointTarget::Direct("ssd".into()),
        CheckpointTarget::Direct("optane".into()),
        CheckpointTarget::BurstBuffer {
            fast: "optane".into(),
            slow: "hdd".into(),
        },
    ];
    // Pre-warm the train-step executable so its one-off compile cost
    // doesn't land inside the first target's measured runtime.
    {
        let mut warm = dlio::model::Trainer::new(&env.rt, "mini", 32, 13)?;
        let prof = warm.profile().clone();
        let mut rng = dlio::util::Rng::new(1);
        let samples: Vec<_> = (0..32)
            .map(|_| dlio::pipeline::ProcessedImage {
                pixels: (0..prof.input_size * prof.input_size * 3)
                    .map(|_| rng.next_f32())
                    .collect(),
                size: prof.input_size as u32,
                label: rng.next_below(prof.num_classes as u64) as u32,
                bytes_read: 0,
            })
            .collect();
        let b = dlio::pipeline::ImageBatch::assemble(
            samples, prof.num_classes as u32)?;
        warm.step(&b)?;
    }

    let mut table = Table::new(&[
        "Ckpt target", "Total s", "Ckpt stall s", "Median ckpt s",
    ]);
    let mut baseline = 0.0f64;
    let mut hdd_overhead = 0.0f64;
    let mut bb_overhead = 0.0f64;
    for target in targets {
        let cfg = CkptStudyConfig {
            mini: MiniAppConfig {
                device: "ssd".into(),
                threads: 4,
                batch: 32,
                prefetch: 1,
                iterations,
                profile: "mini".into(),
                seed: 13,
            },
            target: target.clone(),
            interval,
            max_to_keep: 5,
        };
        env.sim.drop_caches();
        let r = miniapp::run_with_checkpoints(
            Arc::clone(&env.sim), &env.rt, &manifest, &cfg)?;
        match &target {
            CheckpointTarget::None => baseline = r.total_secs,
            CheckpointTarget::Direct(d) if d == "hdd" => {
                hdd_overhead = r.total_secs - baseline
            }
            CheckpointTarget::BurstBuffer { .. } => {
                bb_overhead = r.total_secs - baseline
            }
            _ => {}
        }
        table.row(&[
            target.label(),
            format!("{:.2}", r.total_secs),
            format!("{:.2}", r.ckpt_secs),
            format!("{:.2}", median(&mut r.ckpt_durations.clone())),
        ]);
    }
    print!("{}", table.render());
    if bb_overhead > 0.0 {
        println!(
            "checkpoint-overhead improvement bb vs hdd: {:.1}x \
             (paper: 2.6x)",
            hdd_overhead / bb_overhead
        );
    }
    Ok(())
}
