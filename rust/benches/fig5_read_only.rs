//! Fig. 5: micro-benchmark bandwidth with a *read-only* map function —
//! every preprocessing step removed, isolating raw tf.read() ingestion.
//!
//! Paper shape: bandwidths rise well above the Fig. 4 (preprocessing)
//! numbers, approaching the device's IOR bound at high thread counts.

use std::sync::Arc;

use dlio::bench;
use dlio::config::MicrobenchConfig;
use dlio::coordinator::{ensure_corpus, microbench};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Fig. 5",
        "micro-benchmark bandwidth, read-only map function",
        "read-only pipeline approaches the IOR bound; preprocessing \
         (Fig. 4) caps bandwidth below it (§V-A)",
    );
    let env = bench::env_with_scale("fig5", 0.5, None)?;
    let files = bench::pick(128usize, 384, 16384);
    let spec = CorpusSpec::imagenet_subset_96(files);
    let iterations = files / 64;
    let ts = bench::effective_scale(0.5);

    let mut table = Table::new(&[
        "Device", "1 thr MB/s", "2 thr", "4 thr", "8 thr",
        "IOR read bound", "8-thr vs bound",
    ]);
    for (device, bound) in
        [("hdd", 163.0), ("ssd", 280.55), ("optane", 1603.06),
         ("lustre", 1968.618)]
    {
        let manifest = ensure_corpus(&env.sim, device, &spec)?;
        let mut mbs = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let cfg = MicrobenchConfig {
                device: device.into(),
                threads,
                batch: 64,
                iterations,
                preprocess: false,
                out_size: 64,
                readahead: 0,
                shards: 1,
            };
            env.sim.drop_caches();
            let r = microbench::run(
                Arc::clone(&env.sim), &env.rt, &manifest, &cfg, 7)?;
            mbs.push(r.mb_per_sec() / ts); // modelled-device terms
        }
        table.row(&[
            device.into(),
            format!("{:.1}", mbs[0]),
            format!("{:.1}", mbs[1]),
            format!("{:.1}", mbs[2]),
            format!("{:.1}", mbs[3]),
            format!("{bound:.1}"),
            format!("{:.0}%", mbs[3] / bound * 100.0),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
