//! Fig. 6: mini-application runtime vs map threads, per device, with
//! prefetch disabled / one batch prefetched.
//!
//! Paper shapes: with prefetch the runtime collapses to (nearly) the
//! same value regardless of device or thread count — a complete
//! overlap of input pipeline and computation; without prefetch the
//! excess runtime is the visible cost of I/O, largest on HDD.

use std::sync::Arc;

use dlio::bench;
use dlio::config::MiniAppConfig;
use dlio::coordinator::{ensure_corpus, miniapp};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Fig. 6",
        "mini-app runtime: threads x device x prefetch{0,1}",
        "prefetch=1 makes runtimes equal across devices/threads \
         (complete overlap, §V-B); prefetch=0 excess = I/O cost",
    );
    let env = bench::env("fig6", None)?;
    let files = bench::pick(512usize, 1024, 9144);
    let iterations = bench::pick(6usize, 8, 142);
    let spec = CorpusSpec::caltech101(files);
    let threads_sweep: &[usize] = if bench::level() >= 2 {
        &[1, 2, 4, 8]
    } else {
        &[1, 4, 8]
    };

    let mut table = Table::new(&[
        "Device", "thr", "prefetch=0 s", "prefetch=1 s",
        "excess (I/O cost) s", "ingest-wait pf=1 s",
    ]);
    for device in ["hdd", "ssd", "optane", "lustre"] {
        let manifest = ensure_corpus(&env.sim, device, &spec)?;
        for &threads in threads_sweep {
            let mut totals = [0.0f64; 2];
            let mut wait1 = 0.0;
            for (i, prefetch) in [0usize, 1].into_iter().enumerate() {
                let cfg = MiniAppConfig {
                    device: device.into(),
                    threads,
                    batch: 32,
                    prefetch,
                    iterations,
                    profile: "micro".into(),
                    seed: 9,
                };
                env.sim.drop_caches();
                let r = miniapp::run(
                    Arc::clone(&env.sim), &env.rt, &manifest, &cfg)?;
                totals[i] = r.total_secs;
                if prefetch == 1 {
                    wait1 = r.ingest_wait_secs;
                }
            }
            table.row(&[
                device.into(),
                threads.to_string(),
                format!("{:.2}", totals[0]),
                format!("{:.2}", totals[1]),
                format!("{:.2}", totals[0] - totals[1]),
                format!("{wait1:.3}"),
            ]);
        }
    }
    print!("{}", table.render());
    Ok(())
}
