//! Ablations beyond the paper's figures (DESIGN.md §5): design-choice
//! checks on the knobs the paper holds fixed.
//!
//!  A1 shuffle-buffer size    — randomness/memory trade-off has no
//!                              bandwidth cost (the paper shuffles the
//!                              whole path list).
//!  A2 prefetch depth > 1     — the paper uses 0/1; deeper buffers
//!                              should not help once overlap is full.
//!  A3 warm page cache        — second-epoch speedup when caches are
//!                              not dropped (why the paper runs one
//!                              epoch cold).
//!  A4 burst-buffer drain bw  — staging wins even as the slow device
//!                              gets slower; direct writes degrade
//!                              proportionally.

use std::sync::Arc;

use dlio::bench;
use dlio::config::{MicrobenchConfig, MiniAppConfig};
use dlio::coordinator::{ensure_corpus, microbench, miniapp};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;
use dlio::model::ModelState;
use dlio::runtime::meta::{ParamSpec, ProfileMeta};

fn main() -> anyhow::Result<()> {
    bench::banner("Ablations", "design-choice checks", "beyond the paper");
    let env = bench::env("ablations", None)?;
    let files = bench::pick(256usize, 512, 2048);

    // --- A1: shuffle buffer size ---
    println!("\n[A1] shuffle-buffer size vs ingestion bandwidth (ssd, 4 thr)");
    let spec = CorpusSpec::caltech101(files);
    let manifest = ensure_corpus(&env.sim, "ssd", &spec)?;
    let mut t = Table::new(&["shuffle buffer", "img/s"]);
    for frac in [1usize, 8, 64] {
        // microbench::run shuffles with a full buffer; emulate smaller
        // buffers through the pipeline API directly.
        use dlio::pipeline::{from_manifest, DatasetExt};
        let sim2 = Arc::clone(&env.sim);
        let ds = from_manifest(&manifest)
            .shuffle(manifest.len() / frac + 1, dlio::util::Rng::new(1))
            .parallel_map(4, move |s| {
                sim2.read(&s.path).map(|b| b.len() as u64)
            })
            .batch(64, false);
        env.sim.drop_caches();
        let t0 = std::time::Instant::now();
        let n: usize = dlio::pipeline::collect(ds)?.iter().map(Vec::len).sum();
        t.row(&[
            format!("n/{frac}"),
            format!("{:.0}", n as f64 / t0.elapsed().as_secs_f64()),
        ]);
    }
    print!("{}", t.render());

    // --- A2: prefetch depth ---
    println!("\n[A2] prefetch depth (micro profile, ssd, 4 thr)");
    let mut t = Table::new(&["prefetch", "total s", "ingest wait s"]);
    for prefetch in [0usize, 1, 2, 4] {
        let cfg = MiniAppConfig {
            device: "ssd".into(),
            threads: 4,
            batch: 32,
            prefetch,
            iterations: bench::pick(4, 6, 20),
            profile: "micro".into(),
            seed: 2,
        };
        env.sim.drop_caches();
        let r = miniapp::run(Arc::clone(&env.sim), &env.rt, &manifest, &cfg)?;
        t.row(&[
            prefetch.to_string(),
            format!("{:.2}", r.total_secs),
            format!("{:.3}", r.ingest_wait_secs),
        ]);
    }
    print!("{}", t.render());

    // --- A3: warm page cache ---
    println!("\n[A3] cold vs warm page cache (micro-benchmark, hdd, 4 thr)");
    {
        let mut testbed = env.testbed.clone();
        testbed.cache_bytes = 4 << 30;
        testbed.workdir =
            format!("{}/bench-ablation-cache", dlio::config::default_workdir());
        let sim = dlio::coordinator::make_sim(&testbed, None)?;
        let manifest = ensure_corpus(&sim, "hdd", &spec)?;
        let cfg = MicrobenchConfig {
            device: "hdd".into(),
            threads: 4,
            batch: 64,
            iterations: files / 64,
            preprocess: false,
            out_size: 64,
            readahead: 0,
            shards: 1,
        };
        let mut t = Table::new(&["epoch", "MB/s", "cache hits"]);
        for epoch in ["cold", "warm"] {
            let r = microbench::run(
                Arc::clone(&sim), &env.rt, &manifest, &cfg, 3)?;
            let (hits, _) = sim.cache().stats();
            t.row(&[
                epoch.into(),
                format!("{:.1}", r.mb_per_sec()),
                hits.to_string(),
            ]);
        }
        print!("{}", t.render());
    }

    // --- A4: burst-buffer drain bandwidth sensitivity ---
    println!("\n[A4] BB save latency is independent of drain-target speed");
    {
        use dlio::checkpoint::BurstBuffer;
        use dlio::storage::{DeviceModel, StorageSim};
        let profile = ProfileMeta {
            name: "abl".into(),
            input_size: 8,
            num_classes: 4,
            num_params: 700_000,
            params: vec![ParamSpec {
                name: "fc1/kernel".into(),
                shape: vec![700, 1000],
            }],
        };
        let state = ModelState::init(&profile, 1);
        let mut t = Table::new(&["slow-device write bw", "BB save s",
                                 "drain visible to training?"]);
        for slow_bw in [40e6, 20e6, 10e6] {
            let dir = format!(
                "{}/bench-ablation-bb-{}", dlio::config::default_workdir(),
                slow_bw as u64);
            let _ = std::fs::remove_dir_all(&dir);
            let mk = |name: &str, bw: f64| DeviceModel {
                name: name.into(),
                read_bw: 1e9,
                write_bw: bw,
                read_lat: 0.0,
                write_lat: 0.0,
                channels: 4,
                elevator: vec![(1, 1.0)],
                time_scale: 1.0,
                lat_tables: None,
            };
            let sim = Arc::new(StorageSim::cold(
                dir, vec![mk("slow", slow_bw), mk("fast", 600e6)])?);
            let mut bb = BurstBuffer::new(
                Arc::clone(&sim), profile.clone(), "fast", "slow",
                "ck/m", 5)?;
            bb.saver_mut().sync_on_save = false;
            let t0 = std::time::Instant::now();
            bb.save(&state, 1)?;
            let save_s = t0.elapsed().as_secs_f64();
            bb.wait_drained();
            t.row(&[
                format!("{:.0} MB/s", slow_bw / 1e6),
                format!("{save_s:.3}"),
                "no (async)".into(),
            ]);
        }
        print!("{}", t.render());
    }
    Ok(())
}
