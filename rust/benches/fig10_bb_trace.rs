//! Fig. 10: dstat write traces during checkpointing — direct-to-HDD
//! (top panel) vs Optane burst buffer with async HDD drain (bottom).
//!
//! Paper shapes: direct HDD writes are long and stall training; with
//! the burst buffer the Optane absorbs the checkpoint bursts and the
//! delayed HDD drain continues after (training, even after the app
//! would have ended).

use std::sync::Arc;

use dlio::bench;
use dlio::config::{CheckpointTarget, CkptStudyConfig, MiniAppConfig};
use dlio::coordinator::fixtures::{ensure_corpus, make_sim};
use dlio::coordinator::miniapp;
use dlio::data::CorpusSpec;
use dlio::runtime::Runtime;
use dlio::trace::Dstat;

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Fig. 10",
        "dstat write traces: ckpt to HDD vs Optane burst buffer",
        "BB: optane absorbs bursts, HDD drain is delayed & off the \
         training path (§V-C)",
    );
    let rt = Runtime::open_default()?;
    let files = bench::pick(384usize, 512, 9144);
    let iterations = bench::pick(8usize, 10, 100);
    let interval = bench::pick(2usize, 2, 20);
    let spec = CorpusSpec::caltech101(files);

    for (label, target) in [
        ("direct-to-HDD (top panel)",
         CheckpointTarget::Direct("hdd".into())),
        ("optane burst buffer (bottom panel)",
         CheckpointTarget::BurstBuffer {
             fast: "optane".into(),
             slow: "hdd".into(),
         }),
    ] {
        let tracer = Arc::new(Dstat::new(0.25));
        // Same 1x clock rationale as Fig. 9.
        let mut testbed = dlio::config::Testbed::paper(
            bench::effective_scale(1.0));
        testbed.workdir =
            format!("{}/bench-fig10", dlio::config::default_workdir());
        let sim = make_sim(&testbed, Some(tracer.clone()))?;
        let manifest = ensure_corpus(&sim, "ssd", &spec)?;
        let cfg = CkptStudyConfig {
            mini: MiniAppConfig {
                device: "ssd".into(),
                threads: 4,
                batch: 32,
                prefetch: 1,
                iterations,
                profile: "mini".into(),
                seed: 17,
            },
            target,
            interval,
            max_to_keep: 5,
        };
        let r = miniapp::run_with_checkpoints(
            Arc::clone(&sim), &rt, &manifest, &cfg)?;
        println!(
            "\n--- {label}: {} steps in {:.2}s, ckpt stall {:.2}s ---",
            r.steps, r.total_secs, r.ckpt_secs
        );
        println!("sec,device,write_mb");
        for row in tracer.rows() {
            if row.device == "hdd" || row.device == "optane" {
                println!(
                    "{:.2},{},{:.3}",
                    row.interval as f64 * tracer.interval_secs(),
                    row.device,
                    row.write_bytes as f64 / 1e6
                );
            }
        }
    }
    Ok(())
}
