//! Hot-path micro-benchmarks (§Perf instrument, EXPERIMENTS.md §Perf).
//!
//! Times the L3 building blocks in isolation so the perf pass can see
//! where per-element cost goes: pipeline dispatch, prefetch handoff,
//! batch assembly, SIMG decode, literal marshalling, the preprocess
//! kernel execution, and one train step.

use std::time::Instant;

use dlio::data::format;
use dlio::pipeline::{from_vec, DatasetExt, ImageBatch, ProcessedImage};
use dlio::runtime::executable::lit;
use dlio::runtime::Runtime;
use dlio::util::Rng;

fn time_per<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn row(name: &str, per: f64, unit: &str) {
    let v = if per >= 1e-3 {
        format!("{:.3} ms", per * 1e3)
    } else {
        format!("{:.1} us", per * 1e6)
    };
    println!("{name:<44} {v:>12}  {unit}");
}

fn main() -> anyhow::Result<()> {
    println!("\n=== hotpath: L3 per-element costs ===");
    let mut rng = Rng::new(1);

    // Pipeline dispatch overhead: pass-through map of unit items.
    let per = {
        let n = 100_000;
        let t0 = Instant::now();
        let ds = from_vec((0..n as u64).collect::<Vec<_>>())
            .parallel_map(4, Ok);
        let out = dlio::pipeline::collect(ds)?;
        assert_eq!(out.len(), n);
        t0.elapsed().as_secs_f64() / n as f64
    };
    row("parallel_map dispatch (4 thr, no work)", per, "per element");

    // Prefetch handoff.
    let per = {
        let n = 100_000;
        let t0 = Instant::now();
        let ds = from_vec((0..n as u64).collect::<Vec<_>>()).prefetch(4);
        let out = dlio::pipeline::collect(ds)?;
        assert_eq!(out.len(), n);
        t0.elapsed().as_secs_f64() / n as f64
    };
    row("prefetch handoff", per, "per element");

    // SIMG decode (96px caltech-style image).
    let img = {
        let mut pixels = vec![0u8; 96 * 96 * 3];
        for (i, p) in pixels.iter_mut().enumerate() {
            *p = ((i * 31) % 251) as u8;
        }
        dlio::data::Image {
            width: 96, height: 96, channels: 3, label: 1, pixels,
        }
    };
    let encoded = format::encode(&img, Some(12 * 1024), 7)?;
    let per = time_per(500, || {
        let _ = format::decode(&encoded).unwrap();
    });
    row("SIMG decode (96x96, ~12 KB file)", per, "per image");

    let encoded_big = {
        let mut pixels = vec![0u8; 256 * 256 * 3];
        rng.fill_bytes(&mut pixels);
        let img = dlio::data::Image {
            width: 256, height: 256, channels: 3, label: 1, pixels,
        };
        format::encode(&img, Some(112 * 1024), 7)?
    };
    let per = time_per(200, || {
        let _ = format::decode(&encoded_big).unwrap();
    });
    row("SIMG decode (256x256, ~112 KB file)", per, "per image");

    // Batch assembly (32 x 32x32 images).
    let samples: Vec<ProcessedImage> = (0..32)
        .map(|i| ProcessedImage {
            pixels: vec![0.1; 32 * 32 * 3],
            size: 32,
            label: i % 4,
            bytes_read: 0,
        })
        .collect();
    let per = time_per(2000, || {
        let _ = ImageBatch::assemble(samples.clone(), 102).unwrap();
    });
    row("batch assembly (32 x 32px, incl clone)", per, "per batch");

    // Literal marshalling: 1 MB f32.
    let data = vec![0.5f32; 262_144];
    let per = time_per(500, || {
        let _ = lit::f32(&[262_144], &data).unwrap();
    });
    row("literal upload 1 MB f32", per, "per literal");

    // PJRT paths (need artifacts).
    match Runtime::open_default() {
        Err(_) => println!("(artifacts not built; skipping PJRT rows)"),
        Ok(rt) => {
            let exe = rt.preprocess(96, 64)?.get()?;
            let raw = vec![128u8; 96 * 96 * 3];
            let per = time_per(200, || {
                let _ = dlio::coordinator::workload::run_preprocess(
                    &exe, &raw, 96, 64).unwrap();
            });
            row("preprocess kernel exec (96->64, PJRT)", per, "per image");

            let mut trainer =
                dlio::model::Trainer::new(&rt, "micro", 16, 1)?;
            let prof = trainer.profile().clone();
            let samples: Vec<ProcessedImage> = (0..16)
                .map(|_| ProcessedImage {
                    pixels: (0..prof.input_size * prof.input_size * 3)
                        .map(|_| rng.next_f32())
                        .collect(),
                    size: prof.input_size as u32,
                    label: rng.next_below(prof.num_classes as u64) as u32,
                    bytes_read: 0,
                })
                .collect();
            let batch = ImageBatch::assemble(samples,
                                             prof.num_classes as u32)?;
            let per = time_per(10, || {
                trainer.step(&batch).unwrap();
            });
            row("train step micro b16 (PJRT, incl marshal)", per,
                "per step");
        }
    }
    Ok(())
}
