//! Fig. 8: dstat I/O trace of the mini-application over time, HDD and
//! SSD, prefetch disabled vs one batch prefetched.
//!
//! Paper shapes: without prefetch a stable interleaving of read bursts
//! between batch draws; with prefetch the intervals are closer and
//! per-interval read volume higher (the pipeline runs ahead).

use std::sync::Arc;

use dlio::bench;
use dlio::config::MiniAppConfig;
use dlio::coordinator::fixtures::{ensure_corpus, make_sim};
use dlio::coordinator::miniapp;
use dlio::data::CorpusSpec;
use dlio::runtime::Runtime;
use dlio::trace::Dstat;

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Fig. 8",
        "dstat trace of mini-app reads (HDD / SSD, prefetch 0/1)",
        "prefetch=0: interleaved read bursts; prefetch=1: denser, \
         higher-volume reads (§V-B)",
    );
    let files = bench::pick(384usize, 768, 9144);
    let iterations = bench::pick(6usize, 10, 142);
    let spec = CorpusSpec::caltech101(files);
    let rt = Runtime::open_default()?;

    for device in ["hdd", "ssd"] {
        for prefetch in [0usize, 1] {
            // Fresh sim per run so traces are isolated.
            let tracer = Arc::new(Dstat::new(0.25));
            let mut testbed = dlio::config::Testbed::paper(
                dlio::config::default_time_scale());
            testbed.workdir = format!(
                "{}/bench-fig8", dlio::config::default_workdir());
            let sim = make_sim(&testbed, Some(tracer.clone()))?;
            let manifest = ensure_corpus(&sim, device, &spec)?;
            let cfg = MiniAppConfig {
                device: device.into(),
                threads: 4,
                batch: 32,
                prefetch,
                iterations,
                profile: "micro".into(),
                seed: 3,
            };
            let r = miniapp::run(Arc::clone(&sim), &rt, &manifest, &cfg)?;
            println!(
                "\n--- {device}, prefetch={prefetch}: {} steps in {:.2}s \
                 (ingest wait {:.2}s) ---",
                r.steps, r.total_secs, r.ingest_wait_secs
            );
            // Print only this device's series.
            println!("sec,read_mb");
            for row in tracer.rows() {
                if row.device == device {
                    println!(
                        "{:.2},{:.3}",
                        row.interval as f64 * tracer.interval_secs(),
                        row.read_bytes as f64 / 1e6
                    );
                }
            }
        }
    }
    Ok(())
}
