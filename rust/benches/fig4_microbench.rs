//! Fig. 4: micro-benchmark ingestion bandwidth (images/s), full
//! preprocessing pipeline (read + decode + fused resize), strong
//! scaling over map threads 1/2/4/8 on each device.
//!
//! Paper shapes to reproduce: HDD 1.65x/1.95x/2.3x at 2/4/8 threads
//! and flattening past 4; SSD/Optane ~2x then saturation; Lustre best
//! scalability (7.8x at 8 threads); all well below the IOR bound
//! because of preprocessing compute (§V-A).

use std::sync::Arc;

use dlio::bench;
use dlio::config::MicrobenchConfig;
use dlio::coordinator::{ensure_corpus, microbench};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Fig. 4",
        "micro-benchmark bandwidth, full input pipeline",
        "HDD scaling 1.65x/1.95x/2.3x @ 2/4/8 threads; Lustre 7.8x @ 8",
    );
    // Device clock at 0.5x (slower than hardware): on this single-core
    // host the map function's CPU work cannot parallelize, so device
    // service time must dominate per-worker compute to expose the
    // paper's multi-core scaling shapes (see EXPERIMENTS.md Fig. 4).
    let env = bench::env_with_scale("fig4", 0.5, None)?;
    // §IV-A file sizes (median 112 KB); 96px payloads (cheap decode).
    let files = bench::pick(128usize, 384, 16384);
    let spec = CorpusSpec::imagenet_subset_96(files);
    let iterations = files / 64;

    let mut table = Table::new(&[
        "Device", "1 thr img/s", "2 thr", "4 thr", "8 thr",
        "1->2", "1->4", "1->8", "(paper 1->8)",
    ]);
    for device in ["hdd", "ssd", "optane", "lustre"] {
        let manifest = ensure_corpus(&env.sim, device, &spec)?;
        let mut ips = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let cfg = MicrobenchConfig {
                device: device.into(),
                threads,
                batch: 64,
                iterations,
                preprocess: true,
                out_size: 64,
                readahead: 0,
                shards: 1,
            };
            env.sim.drop_caches();
            let r = microbench::run(
                Arc::clone(&env.sim), &env.rt, &manifest, &cfg, 7)?;
            ips.push(r.images_per_sec());
        }
        let paper_1to8 = match device {
            "hdd" => "2.3x",
            "lustre" => "7.8x",
            _ => "-",
        };
        table.row(&[
            device.into(),
            format!("{:.0}", ips[0]),
            format!("{:.0}", ips[1]),
            format!("{:.0}", ips[2]),
            format!("{:.0}", ips[3]),
            format!("{:.2}x", ips[1] / ips[0]),
            format!("{:.2}x", ips[2] / ips[0]),
            format!("{:.2}x", ips[3] / ips[0]),
            paper_1to8.into(),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
