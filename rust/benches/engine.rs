//! IoEngine acceptance bench (DESIGN.md §9): the two properties the
//! request-level engine buys over the old blocking facade.
//!
//! 1. **Overlapped checkpoint save** — the saver submits the
//!    meta/index/data triple through one doorbell, so even a
//!    single-channel HDD sees the burst and its elevator gain cuts the
//!    per-file seek cost.  Target: >= 1.5x over the serial three-write
//!    baseline on the Blackdog HDD profile.
//! 2. **Bounded drain memory** — a burst-buffer style cross-device
//!    copy streams chunks through a bounded window; peak buffered
//!    bytes are a function of the chunk size, not the file size.
//! 3. **Class isolation (QoS)** — with a saturating checkpoint burst
//!    on the HDD profile, ingest p99 queue latency under the DRR
//!    scheduler is <= 0.5x the single-FIFO baseline while checkpoint
//!    completion degrades <= 20% (§V's interference, removed).
//! 4. **Sharded read scaling** — 4 reader shards reach >= 2x the
//!    single-shard read bandwidth on a parallel device (Fig. 4/8's
//!    2.3x-7.8x thread scaling, reproduced without threads).
//! 5. **Adaptive QoS** — under a repeating checkpoint-burst workload,
//!    the AIMD ingest-weight controller's ingest p99 queue latency is
//!    <= the static-weights baseline.
//! 6. **Rate caps** — a token-bucket-capped Checkpoint class stays
//!    within 1.1x of its configured bytes/sec while uncapped ingest
//!    proceeds at device speed.
//! 7. **Drain-rate study** — a capped Drain class stretches its own
//!    makespan >= 2x (staying within 1.1x of its cap) while ingest p99
//!    stays flat: the burst-buffer drain knob bounds background
//!    bandwidth without taxing the foreground.
//! 8. **Trace replay** — a recorded contention trace closed-loop
//!    replayed on the slow HDD profile reproduces per-class byte
//!    totals exactly, and replaying the SAME file under FIFO vs
//!    static DRR shows the PR-2 isolation effect end-to-end from a
//!    trace file.
//! 9. **Tier placement** — on the 2-tier Optane/HDD hierarchy with a
//!    hot-set ingest workload, the frequency-promotion policy beats
//!    Noop: strictly higher tier-0 hit fraction and ingest p99 queue
//!    wait <= 0.85x (the hot set leaves the seek-bound HDD queue).
//! 10. **Hierarchy checkpoint drain** — the paper's fast→slow drain
//!    as tier-sweep cells: training-visible save makespan against
//!    `blackdog-bb` (Optane staging, background drain to HDD) is
//!    >= 2x better than `blackdog-direct-hdd` (Fig. 9's 2.6x, as a
//!    pair of sweep rows).
//! 11. **Wall vs virtual clock parity + speedup** — one pinned
//!    qos-sweep cell (sharded ingest + checkpoint bursts under DRR)
//!    run under both clocks: per-class byte totals and completion
//!    counts identical, ingest p99 queue wait within one log2
//!    histogram bucket, and the virtual run >= 50x faster in wall
//!    seconds.
//! 12. **Virtual-clock scale** — a million engine requests through
//!    the DRR scheduler in discrete-event time finish in under a
//!    minute of wall time.
//! 13. **Fleet isolation** — four equal-share tenants (each with the
//!    same fair-share ingest admission cap) on a saturated
//!    single-channel device, one a closed-loop hog at 10x load: under
//!    the nested tenant DRR every victim's ingest p99 stays <= 1.3x
//!    its solo baseline and Jain's index over per-tenant goodput is
//!    >= 0.9, while the tenant-blind scheduler fails both gates on
//!    the identical cell.
//! 14. **Fault seam** — degraded-mode operation (DESIGN.md §15):
//!    (a) a mid-drain slow-tier outage pauses the burst-buffer
//!    migrator without losing a checkpoint — every triple drains
//!    oldest-first once the fault clears and restores bit-exact from
//!    the slow tier; (b) the fleet restart-storm cell reports a
//!    positive per-tenant time-to-recover bounded by the cell
//!    makespan, with a valid goodput Jain; (c) two identical
//!    fault-injected virtual-clock replays are bit-deterministic in
//!    clock makespan.
//! 15. **Prefetcher overlap** — the paper's headline result on the
//!    modelled accelerator (DESIGN.md §16): on a pinned compute-bound
//!    virtual-clock cell (alexnet @ batch 16 on K80, 1 shard x 1-wide
//!    window off the SSD), prefetch depth 4 converges the steady step
//!    time to <= 1.05x max(compute, input) with stall fraction
//!    <= 0.05, while the synchronous `--prefetch 0` column pays
//!    >= 0.9x (compute + input) additively.
//! 16. **Cost-aware placement** — on the calibrated 2-tier preset
//!    (per-block-size latency tables feeding the policy's cost
//!    model) under a Zipf read-write mix whose working set is 3x
//!    tier-0 capacity, the bidirectional cost policy beats
//!    promote-only freq: ingest p99 <= 0.9x and tier-0 hit fraction
//!    >= 1.1x.  Freq promotes every block past its access threshold
//!    and thrashes on evictions; cost rejects colder-than-victim
//!    candidates and keeps the head set resident.
//!
//! No PJRT artifacts needed.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use dlio::checkpoint::{BurstBuffer, CheckpointHandle, Saver};
use dlio::coordinator::{fleet_sweep, overlap_sweep, qos_sweep, tier_sweep};
use dlio::data::manifest::Sample;
use dlio::metrics::{median, Table};
use dlio::model::ModelState;
use dlio::pipeline::{sharded_reader, Dataset};
use dlio::runtime::meta::{ParamSpec, ProfileMeta};
use dlio::storage::engine::{DEFAULT_CHUNK, STREAM_WINDOW};
use dlio::storage::{
    profiles, with_tenant, Clock, ClockSpec, Device, DeviceModel,
    EngineObserver, FaultPlan, IoClass, IoEngine, IoRequest, NullObserver,
    QosConfig, SimPath, StorageSim, TenantId, TenantQos,
};
use dlio::trace::{
    analyze, replay, MemorySink, ReplayConfig, Trace, TraceManifest,
    TraceRecorder, TRACE_VERSION,
};

fn small_profile() -> ProfileMeta {
    // ~26 KB data payload: seek-dominated on an HDD, which is the
    // regime where overlapping the triple matters most.
    ProfileMeta {
        name: "bench".into(),
        input_size: 8,
        num_classes: 4,
        num_params: 32 * 64 + 64,
        params: vec![
            ParamSpec { name: "fc1/kernel".into(), shape: vec![32, 64] },
            ParamSpec { name: "fc1/bias".into(), shape: vec![64] },
        ],
    }
}

fn workdir(tag: &str) -> std::path::PathBuf {
    let base = if std::path::Path::new("/dev/shm").is_dir() {
        std::path::PathBuf::from("/dev/shm")
    } else {
        std::env::temp_dir()
    };
    let dir = base.join(format!("dlio-bench-engine-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn main() -> anyhow::Result<()> {
    println!("\n=== engine: request-level I/O engine acceptance ===");

    // ---- 1. overlapped checkpoint triple vs serial, HDD profile ----
    // Unscaled HDD (8 ms write latency) so the modelled seeks dwarf
    // host noise.
    let sim = Arc::new(StorageSim::cold(
        workdir("overlap"),
        vec![profiles::blackdog_hdd(1.0)],
    )?);
    let profile = small_profile();
    let state = ModelState::init(&profile, 1);

    let reps = 5;
    let mut serial_times = Vec::new();
    let mut overlap_times = Vec::new();
    for rep in 0..=reps {
        // Serial baseline: the pre-engine behaviour — three blocking
        // whole-file writes, one after another.
        let h_base = format!("serial/m{rep}");
        let data = state.to_bytes();
        let t0 = std::time::Instant::now();
        sim.write(&SimPath::new("hdd", format!("{h_base}.meta")), b"{}")?;
        sim.write(&SimPath::new("hdd", format!("{h_base}.index")), b"{}")?;
        sim.write(&SimPath::new("hdd", format!("{h_base}.data")), &data)?;
        let t_serial = t0.elapsed().as_secs_f64();

        // Overlapped: the saver's batched submissions.
        let mut saver = Saver::new(
            Arc::clone(&sim),
            profile.clone(),
            "hdd",
            &format!("overlap/m{rep}"),
            2,
        );
        saver.sync_on_save = false;
        let t0 = std::time::Instant::now();
        saver.save(&state, 1)?;
        let t_overlap = t0.elapsed().as_secs_f64();

        if rep > 0 {
            // First rep is warm-up (paper protocol).
            serial_times.push(t_serial);
            overlap_times.push(t_overlap);
        }
    }
    let t_serial = median(&mut serial_times);
    let t_overlap = median(&mut overlap_times);
    let speedup = t_serial / t_overlap;

    let mut t = Table::new(&["save strategy", "median ms", "speedup"]);
    t.row(&["serial 3-write (old facade)".into(),
            format!("{:.2}", t_serial * 1e3), "1.00x".into()]);
    t.row(&["overlapped engine triple".into(),
            format!("{:.2}", t_overlap * 1e3), format!("{speedup:.2}x")]);
    print!("{}", t.render());
    println!("target: >= 1.5x on the HDD profile (elevator gain over the \
              co-queued burst)");
    assert!(
        speedup >= 1.5,
        "overlapped save speedup {speedup:.2}x below the 1.5x target"
    );

    // ---- 2. drain memory bounded by chunk size, not file size ----
    // Accelerated devices: the 32 MB copy finishes in ms while the
    // stream accounting is time-scale independent.
    let sim = Arc::new(StorageSim::cold(
        workdir("drainmem"),
        vec![profiles::blackdog_optane(500.0), profiles::blackdog_hdd(500.0)],
    )?);
    let file_bytes = 32usize << 20;
    let src = SimPath::new("optane", "stage/ck.data");
    let dst = SimPath::new("hdd", "archive/ck.data");
    let payload: Vec<u8> = (0..file_bytes).map(|i| (i % 251) as u8).collect();
    sim.write(&src, &payload)?;

    sim.engine().reset_peak_stream_bytes();
    let copied = sim.copy(&src, &dst)?;
    assert_eq!(copied, file_bytes as u64);
    assert_eq!(sim.read(&dst)?, payload, "copy must be bit-exact");
    let peak = sim.engine().peak_stream_bytes();
    let bound = (DEFAULT_CHUNK * (STREAM_WINDOW + 1)) as u64;

    let mut t = Table::new(&["quantity", "bytes"]);
    t.row(&["file size".into(), format!("{file_bytes}")]);
    t.row(&["chunk size".into(), format!("{DEFAULT_CHUNK}")]);
    t.row(&["peak stream buffer".into(), format!("{peak}")]);
    t.row(&["bound (chunk * (window+1))".into(), format!("{bound}")]);
    print!("{}", t.render());
    assert!(peak <= bound, "peak {peak} exceeds chunked bound {bound}");
    assert!(
        peak < (file_bytes / 4) as u64,
        "peak {peak} scales with file size, not chunk size"
    );

    // ---- 3. per-request queue/service metrics surface ----
    let mut t = Table::new(&[
        "Device", "reqs", "mean queue ms", "mean service ms",
        "max depth", "MB read", "MB written",
    ]);
    for s in sim.engine().stats() {
        t.row(&[
            s.device.clone(),
            s.completed.to_string(),
            format!("{:.3}", s.mean_queue_secs() * 1e3),
            format!("{:.3}", s.mean_service_secs() * 1e3),
            s.max_queue_depth.to_string(),
            format!("{:.1}", s.bytes_read as f64 / 1e6),
            format!("{:.1}", s.bytes_written as f64 / 1e6),
        ]);
    }
    print!("{}", t.render());

    // ---- 4. class isolation: ingest vs checkpoint on the HDD ----
    // Mixed load, same in both runs: 16 x 2 MB checkpoint writes
    // submitted first (a ~90 ms modelled backlog at 4x scale), then
    // 10 x 32 KB ingest reads.  FIFO: the reads wait out the whole
    // backlog.  DRR: they are served after the in-flight write.
    let qos_run = |qos: QosConfig, tag: &str| -> anyhow::Result<(f64, f64)> {
        let sim = Arc::new(StorageSim::cold_with_qos(
            workdir(&format!("qos-{tag}")),
            vec![profiles::blackdog_hdd(4.0)],
            qos,
        )?);
        let eng = sim.engine();
        let t0 = Instant::now();
        let writes: Vec<_> = (0..16)
            .map(|_| {
                eng.submit(IoRequest::ProbeWrite {
                    device: "hdd".into(),
                    bytes: 2_000_000,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        let reads: Vec<_> = (0..10)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "hdd".into(),
                    bytes: 32_768,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        for t in writes {
            t.wait()?;
        }
        let ckpt_secs = t0.elapsed().as_secs_f64();
        for t in reads {
            t.wait()?;
        }
        let stats = eng.stats();
        let s = stats
            .iter()
            .find(|s| s.device == "hdd")
            .expect("hdd stats");
        Ok((s.class(IoClass::Ingest).p99_queue_secs(), ckpt_secs))
    };
    // Best-of-two per mode: a single noisy-neighbor stall on a shared
    // CI runner cannot fake a scheduling regression.
    let best = |qos: &QosConfig, tag: &str| -> anyhow::Result<(f64, f64)> {
        let (p99_a, ck_a) = qos_run(qos.clone(), &format!("{tag}-a"))?;
        let (p99_b, ck_b) = qos_run(qos.clone(), &format!("{tag}-b"))?;
        Ok((p99_a.min(p99_b), ck_a.min(ck_b)))
    };
    let (fifo_p99, fifo_ckpt) = best(&QosConfig::fifo(), "fifo")?;
    let (drr_p99, drr_ckpt) = best(&QosConfig::default(), "drr")?;

    let mut t = Table::new(&[
        "scheduler", "ingest p99 queue ms", "checkpoint makespan ms",
    ]);
    t.row(&["single FIFO (baseline)".into(),
            format!("{:.1}", fifo_p99 * 1e3),
            format!("{:.1}", fifo_ckpt * 1e3)]);
    t.row(&["weighted DRR (QoS)".into(),
            format!("{:.1}", drr_p99 * 1e3),
            format!("{:.1}", drr_ckpt * 1e3)]);
    print!("{}", t.render());
    println!("target: ingest p99 <= 0.5x FIFO, checkpoint makespan <= 1.2x");
    assert!(
        drr_p99 <= 0.5 * fifo_p99,
        "ingest p99 {:.1} ms !<= 0.5 * FIFO {:.1} ms",
        drr_p99 * 1e3,
        fifo_p99 * 1e3
    );
    assert!(
        drr_ckpt <= 1.2 * fifo_ckpt,
        "checkpoint makespan {:.1} ms degraded past 20% vs {:.1} ms",
        drr_ckpt * 1e3,
        fifo_ckpt * 1e3
    );

    // ---- 5. sharded reader scaling ----
    // Latency-bound parallel device (4 ms per read, 32 channels): a
    // single shard's window of 4 caps concurrency at 4; four shards
    // quadruple it.  Modelled speedup ~4x; the gate is 2x.
    let ost = DeviceModel {
        name: "ost".into(),
        read_bw: 2e9,
        write_bw: 2e9,
        read_lat: 4.0e-3,
        write_lat: 0.1e-3,
        channels: 32,
        elevator: vec![(1, 1.0)],
        time_scale: 1.0,
        lat_tables: None,
    };
    const SHARD_FILES: usize = 144;
    let sim = Arc::new(StorageSim::cold(workdir("shard"), vec![ost])?);
    let samples: Vec<Sample> = (0..SHARD_FILES)
        .map(|i| {
            let p = SimPath::new("ost", format!("f{i}.bin"));
            sim.write(&p, &vec![(i % 251) as u8; 16 * 1024]).unwrap();
            Sample { path: p, label: i as u32 }
        })
        .collect();
    let shard_run = |shards: usize| -> anyhow::Result<f64> {
        sim.drop_caches();
        let t0 = Instant::now();
        let mut ds =
            sharded_reader(samples.clone(), Arc::clone(&sim), shards, 4);
        let mut n = 0usize;
        while let Some(item) = ds.next() {
            let ls = item?;
            assert_eq!(ls.bytes.len(), 16 * 1024);
            n += 1;
        }
        assert_eq!(n, SHARD_FILES, "sharded reader dropped samples");
        Ok(t0.elapsed().as_secs_f64())
    };
    // Best-of-two per config: a CI scheduler stall in one short run
    // cannot sink the modelled ~4x ratio below the 2x gate.
    let t1 = shard_run(1)?.min(shard_run(1)?);
    let t4 = shard_run(4)?.min(shard_run(4)?);
    let speedup = t1 / t4;

    let mut t = Table::new(&["reader", "wall ms", "speedup"]);
    t.row(&["1 shard x window 4".into(),
            format!("{:.1}", t1 * 1e3), "1.00x".into()]);
    t.row(&["4 shards x window 4".into(),
            format!("{:.1}", t4 * 1e3), format!("{speedup:.2}x")]);
    print!("{}", t.render());
    println!("target: >= 2x single-shard read bandwidth with 4 shards");
    assert!(
        speedup >= 2.0,
        "sharded speedup {speedup:.2}x below the 2x target"
    );

    // ---- 6. adaptive QoS: AIMD ingest weight vs static weights ----
    // Repeating checkpoint-burst pattern on the HDD profile: each
    // round queues a 16 MB checkpoint backlog plus a 24 MB ingest
    // flood big enough that the static 8 MiB ingest quantum forces
    // several checkpoint interleavings per round.  The controller
    // (target: 2 ms modelled ingest p99, far below the contended
    // waits) walks the ingest weight to its ceiling during the
    // warm-up round; the measured rounds then interleave ~8x less
    // checkpoint service into the ingest backlog.  Gate: adaptive
    // ingest p99 <= the static baseline (acceptance criterion).
    let adaptive_run = |qos: QosConfig, tag: &str| -> anyhow::Result<f64> {
        let sim = Arc::new(StorageSim::cold_with_qos(
            workdir(&format!("adaptive-{tag}")),
            vec![profiles::blackdog_hdd(4.0)],
            qos,
        )?);
        let eng = sim.engine();
        let round = || -> anyhow::Result<()> {
            let writes: Vec<_> = (0..32)
                .map(|_| {
                    eng.submit(IoRequest::ProbeWrite {
                        device: "hdd".into(),
                        bytes: 512 * 1024,
                    })
                })
                .collect::<anyhow::Result<_>>()?;
            let reads: Vec<_> = (0..24)
                .map(|_| {
                    eng.submit(IoRequest::ProbeRead {
                        device: "hdd".into(),
                        bytes: 1_000_000,
                    })
                })
                .collect::<anyhow::Result<_>>()?;
            for t in reads {
                t.wait()?;
            }
            for t in writes {
                t.wait()?;
            }
            Ok(())
        };
        // Warm-up round: lets the controller converge (a no-op for
        // the static baseline), then bracket the measured rounds.
        round()?;
        eng.reset_stats();
        for _ in 0..2 {
            round()?;
        }
        let stats = eng.stats();
        let s = stats.iter().find(|s| s.device == "hdd").expect("hdd");
        if !s.weight_trajectory.is_empty() {
            println!(
                "  [{tag}] ingest weight ended at {} ({} changes)",
                s.ingest_weight,
                s.weight_trajectory.len()
            );
        }
        Ok(s.class(IoClass::Ingest).p99_queue_secs())
    };
    // Best-of-two per mode, as above: CI noise can't fake a
    // controller regression.
    let static_p99 = adaptive_run(QosConfig::default(), "static-a")?
        .min(adaptive_run(QosConfig::default(), "static-b")?);
    let adaptive_p99 = adaptive_run(QosConfig::adaptive(0.002), "aimd-a")?
        .min(adaptive_run(QosConfig::adaptive(0.002), "aimd-b")?);

    let mut t = Table::new(&["qos mode", "ingest p99 queue ms"]);
    t.row(&["static weights (8/4/2/1)".into(),
            format!("{:.1}", static_p99 * 1e3)]);
    t.row(&["adaptive (AIMD ingest weight)".into(),
            format!("{:.1}", adaptive_p99 * 1e3)]);
    print!("{}", t.render());
    println!("target: adaptive ingest p99 <= static baseline");
    assert!(
        adaptive_p99 <= static_p99,
        "adaptive ingest p99 {:.1} ms worse than static {:.1} ms",
        adaptive_p99 * 1e3,
        static_p99 * 1e3
    );

    // ---- 7. token-bucket rate cap on the Checkpoint class ----
    // Fast wall clock (HDD at 8x: ~1 GB/s write service), checkpoint
    // hard-capped at 40 modelled MB/s (wall 320 MB/s).  40 x 1 MB
    // writes must drain at <= 1.1x the cap while uncapped ingest
    // reads cut straight through.  Host stalls only lengthen the
    // window, i.e. lower the measured rate — the bound is
    // noise-safe.
    let cap_modelled = 40e6;
    let ts_scale = 8.0;
    let sim = Arc::new(StorageSim::cold_with_qos(
        workdir("ratecap"),
        vec![profiles::blackdog_hdd(ts_scale)],
        QosConfig::default().with_rate_cap(
            IoClass::Checkpoint,
            cap_modelled,
            256 * 1024,
        ),
    )?);
    let eng = sim.engine();
    let t0 = Instant::now();
    let writes: Vec<_> = (0..40)
        .map(|_| {
            eng.submit(IoRequest::ProbeWrite {
                device: "hdd".into(),
                bytes: 1_000_000,
            })
        })
        .collect::<anyhow::Result<_>>()?;
    let reads: Vec<_> = (0..16)
        .map(|_| {
            eng.submit(IoRequest::ProbeRead {
                device: "hdd".into(),
                bytes: 256 * 1024,
            })
        })
        .collect::<anyhow::Result<_>>()?;
    for t in reads {
        t.wait()?;
    }
    let ingest_secs = t0.elapsed().as_secs_f64();
    for t in writes {
        t.wait()?;
    }
    let ckpt_secs = t0.elapsed().as_secs_f64();
    // Wall window -> modelled rate: divide wall throughput by the
    // time scale.
    let achieved_modelled = 40e6 / ckpt_secs / ts_scale;

    let mut t = Table::new(&["quantity", "value"]);
    t.row(&["checkpoint cap (modelled MB/s)".into(),
            format!("{:.1}", cap_modelled / 1e6)]);
    t.row(&["achieved (modelled MB/s)".into(),
            format!("{:.1}", achieved_modelled / 1e6)]);
    t.row(&["uncapped ingest makespan ms".into(),
            format!("{:.1}", ingest_secs * 1e3)]);
    t.row(&["capped ckpt makespan ms".into(),
            format!("{:.1}", ckpt_secs * 1e3)]);
    print!("{}", t.render());
    println!("target: achieved <= 1.1x cap; ingest unaffected by the cap");
    assert!(
        achieved_modelled <= 1.1 * cap_modelled,
        "capped checkpoint ran at {:.1} MB/s, cap {:.1} MB/s",
        achieved_modelled / 1e6,
        cap_modelled / 1e6
    );
    assert!(
        ingest_secs <= 0.5 * ckpt_secs,
        "uncapped ingest ({:.1} ms) dragged behind the capped class \
         ({:.1} ms)",
        ingest_secs * 1e3,
        ckpt_secs * 1e3
    );

    // ---- 8. drain-rate study: capped Drain slows itself, not ingest ----
    // Burst-buffer drain traffic (Drain-class writes) against live
    // ingest reads on the HDD profile.  Uncapped, 24 MB of drain runs
    // at device speed; capped at 20 modelled MB/s it must stretch its
    // own makespan >= 2x (and stay within 1.1x of the cap) while the
    // ingest tail stays flat — the ROADMAP's drain-rate study, gated.
    let drain_run = |cap: Option<f64>, tag: &str| -> anyhow::Result<(f64, f64)> {
        let mut qos = QosConfig::default();
        if let Some(mbs) = cap {
            qos = qos.with_rate_cap(IoClass::Drain, mbs, 256 * 1024);
        }
        let sim = Arc::new(StorageSim::cold_with_qos(
            workdir(&format!("draincap-{tag}")),
            vec![profiles::blackdog_hdd(8.0)],
            qos,
        )?);
        let eng = sim.engine();
        let t0 = Instant::now();
        let drains: Vec<_> = (0..24)
            .map(|_| {
                eng.submit_class(
                    IoRequest::ProbeWrite {
                        device: "hdd".into(),
                        bytes: 1_000_000,
                    },
                    IoClass::Drain,
                )
            })
            .collect::<anyhow::Result<_>>()?;
        let reads: Vec<_> = (0..16)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "hdd".into(),
                    bytes: 128 * 1024,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        for t in reads {
            t.wait()?;
        }
        for t in drains {
            t.wait()?;
        }
        let drain_secs = t0.elapsed().as_secs_f64();
        let stats = eng.stats();
        let s = stats.iter().find(|s| s.device == "hdd").expect("hdd");
        Ok((s.class(IoClass::Ingest).p99_queue_secs(), drain_secs))
    };
    // Best-of-two per config: CI noise can't fake a rate regression.
    let best2 = |cap: Option<f64>, tag: &str| -> anyhow::Result<(f64, f64)> {
        let (p_a, d_a) = drain_run(cap, &format!("{tag}-a"))?;
        let (p_b, d_b) = drain_run(cap, &format!("{tag}-b"))?;
        Ok((p_a.min(p_b), d_a.min(d_b)))
    };
    let drain_cap_modelled = 20e6;
    let (free_p99, free_drain) = best2(None, "free")?;
    let (cap_p99, cap_drain) = best2(Some(drain_cap_modelled), "capped")?;
    // Wall window -> modelled rate at the 8x time scale.
    let achieved_modelled = 24e6 / cap_drain / 8.0;

    let mut t = Table::new(&[
        "drain mode", "drain makespan ms", "modelled MB/s", "ingest p99 ms",
    ]);
    t.row(&["uncapped".into(),
            format!("{:.1}", free_drain * 1e3),
            format!("{:.1}", 24e6 / free_drain / 8.0 / 1e6),
            format!("{:.2}", free_p99 * 1e3)]);
    t.row(&["capped 20 MB/s".into(),
            format!("{:.1}", cap_drain * 1e3),
            format!("{:.1}", achieved_modelled / 1e6),
            format!("{:.2}", cap_p99 * 1e3)]);
    print!("{}", t.render());
    println!("target: capped drain >= 2x uncapped makespan, <= 1.1x its \
              cap; ingest p99 flat");
    assert!(
        cap_drain >= 2.0 * free_drain,
        "capped drain ({:.1} ms) did not slow vs uncapped ({:.1} ms)",
        cap_drain * 1e3,
        free_drain * 1e3
    );
    assert!(
        achieved_modelled <= 1.1 * drain_cap_modelled,
        "capped drain ran at {:.1} MB/s, cap {:.1} MB/s",
        achieved_modelled / 1e6,
        drain_cap_modelled / 1e6
    );
    // "Flat": within one log2 histogram bucket (2x) of the uncapped
    // tail, with a small absolute floor for near-zero baselines.
    assert!(
        cap_p99 <= (2.0 * free_p99).max(0.004),
        "capping the DRAIN class moved the INGEST tail: {:.2} ms vs \
         uncapped {:.2} ms",
        cap_p99 * 1e3,
        free_p99 * 1e3
    );

    // ---- 9. trace replay: QoS isolation end-to-end from a trace ----
    // Record the §V contention pattern (a 16 x 2 MB checkpoint burst
    // with 10 small ingest reads behind it, everything co-in-flight)
    // on a near-instant device, then closed-loop replay the SAME file
    // on the slow HDD profile under FIFO vs static DRR.  The replayed
    // byte totals must reproduce the recording exactly, and the PR-2
    // isolation effect must emerge from the trace alone.
    let dir = workdir("tracereplay");
    std::fs::create_dir_all(&dir)?;
    let fast = DeviceModel {
        name: "hdd".into(), // traced name; the replay profile keys on it
        read_bw: 1e9,
        write_bw: 1e9,
        read_lat: 1.0,
        write_lat: 1.0,
        channels: 1,
        elevator: vec![(1, 1.0)],
        time_scale: 1000.0, // 1 ms wall per op: nothing completes
                            // before the whole burst is submitted
        lat_tables: None,
    };
    let trace_path = dir.join("contention.jsonl");
    {
        let mut devices = HashMap::new();
        devices.insert(
            "hdd".to_string(),
            Arc::new(Device::new(fast.clone(), Arc::new(NullObserver))),
        );
        let engine =
            IoEngine::with_config(&devices, DEFAULT_CHUNK, QosConfig::fifo());
        let rec = TraceRecorder::create(
            &trace_path,
            &TraceManifest {
                version: TRACE_VERSION,
                workload: "bench-contention".into(),
                qos_mode: "fifo".into(),
                qos: Some(QosConfig::fifo()),
                time_scale: 1000.0,
                devices: vec![fast],
            },
        )?;
        engine.set_observer(rec.observer());
        let writes: Vec<_> = (0..16)
            .map(|_| {
                engine.submit(IoRequest::ProbeWrite {
                    device: "hdd".into(),
                    bytes: 2_000_000,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        let reads: Vec<_> = (0..10)
            .map(|_| {
                engine.submit(IoRequest::ProbeRead {
                    device: "hdd".into(),
                    bytes: 32_768,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        for t in writes {
            t.wait()?;
        }
        for t in reads {
            t.wait()?;
        }
        engine.clear_observer();
        drop(engine);
        rec.finish()?;
    }
    let trace = Trace::load(&trace_path)?;
    let recorded = trace.recorded_aggregates();
    let replay_run = |qos: QosConfig| -> anyhow::Result<f64> {
        let cfg = ReplayConfig {
            qos,
            profile: Some("hdd".into()),
            time_scale: Some(4.0),
            ..ReplayConfig::default()
        };
        let outcome = replay(&trace, &cfg)?;
        assert_eq!(outcome.errors, 0);
        let aggs = analyze::class_aggregates(&outcome.replayed);
        for c in [IoClass::Ingest, IoClass::Checkpoint] {
            assert_eq!(
                aggs[c.index()].bytes,
                recorded[c.index()].bytes,
                "{c}: replayed byte totals diverged from the recording"
            );
        }
        Ok(aggs[IoClass::Ingest.index()].p99_queue_secs)
    };
    // Best-of-two per mode, as everywhere in this bench.
    let fifo_p99 = replay_run(QosConfig::fifo())?
        .min(replay_run(QosConfig::fifo())?);
    let static_p99 = replay_run(QosConfig::default())?
        .min(replay_run(QosConfig::default())?);

    let mut t = Table::new(&["replayed scheduler", "ingest p99 queue ms"]);
    t.row(&["fifo".into(), format!("{:.1}", fifo_p99 * 1e3)]);
    t.row(&["static DRR".into(), format!("{:.1}", static_p99 * 1e3)]);
    print!("{}", t.render());
    println!("target: static ingest p99 <= 0.75x fifo, from the same \
              trace file on the slow profile");
    assert!(
        static_p99 <= 0.75 * fifo_p99,
        "trace replay lost the isolation effect: static {:.1} ms !<= \
         0.75 * fifo {:.1} ms",
        static_p99 * 1e3,
        fifo_p99 * 1e3
    );

    // ---- 10. tier hierarchy: placement policies + checkpoint drain ----
    // Both gates run on tier-sweep cells — the same code path `dlio
    // tier-sweep` exercises.  Hot workload on the blackdog-bb shape
    // (Optane tier 0 over the 1-actuator HDD): under Noop every read
    // seeks the HDD and the windowed readers stack its queue; under
    // frequency promotion the hot set (80% of accesses) migrates to
    // Optane, so tier-0 hits rise and the HDD queue drains.
    let sweep_cfg = |tag: &str| {
        let mut cfg = tier_sweep::TierSweepConfig::smoke(
            workdir(&format!("tiersweep-{tag}"))
                .to_string_lossy()
                .into_owned(),
            8.0,
        );
        cfg.hierarchies = vec!["blackdog-bb".into()];
        cfg.policies = vec!["noop".into(), "freq".into()];
        cfg.workloads = vec!["hot".into()];
        cfg.files = 32;
        cfg.file_bytes = 32 * 1024;
        cfg.reads = 240;
        // Warm-up lets the promotion converge before the measured
        // phase (same protocol as the adaptive section's warm-up
        // round), so the p99 gate compares steady states.
        cfg.warmup_reads = 60;
        cfg.hot_files = 4;
        cfg.hot_frac = 0.8;
        cfg.shards = 2;
        cfg.window = 4;
        cfg.tier0_cap = 0; // preset default (unbounded staging)
        cfg
    };
    // Best-of-two per policy, as everywhere in this bench.
    let hot_cells = |tag: &str| -> anyhow::Result<(f64, f64, f64, f64)> {
        let cells = tier_sweep::run(&sweep_cfg(tag))?;
        let noop = cells
            .iter()
            .find(|c| c.policy == "noop")
            .expect("noop cell");
        let freq = cells
            .iter()
            .find(|c| c.policy == "freq")
            .expect("freq cell");
        Ok((
            noop.t0_hit_frac,
            noop.ingest_p99_ms,
            freq.t0_hit_frac,
            freq.ingest_p99_ms,
        ))
    };
    let (n_hit_a, n_p99_a, f_hit_a, f_p99_a) = hot_cells("a")?;
    let (n_hit_b, n_p99_b, f_hit_b, f_p99_b) = hot_cells("b")?;
    let (noop_hit, noop_p99) = (n_hit_a.max(n_hit_b), n_p99_a.min(n_p99_b));
    let (freq_hit, freq_p99) = (f_hit_a.max(f_hit_b), f_p99_a.min(f_p99_b));

    let mut t = Table::new(&[
        "policy", "tier-0 hit frac", "ingest p99 queue ms",
    ]);
    t.row(&["noop".into(), format!("{noop_hit:.2}"),
            format!("{noop_p99:.2}")]);
    t.row(&["freq".into(), format!("{freq_hit:.2}"),
            format!("{freq_p99:.2}")]);
    print!("{}", t.render());
    println!("target: freq hit frac > noop (noop promotes nothing); \
              freq ingest p99 <= 0.85x noop");
    assert_eq!(
        noop_hit, 0.0,
        "noop promoted data into tier 0 — placement is leaking"
    );
    assert!(
        freq_hit > 0.4,
        "freq tier-0 hit fraction {freq_hit:.2} did not capture the hot set"
    );
    assert!(
        freq_p99 <= 0.85 * noop_p99,
        "promotion did not unload the HDD queue: freq p99 {freq_p99:.2} ms \
         !<= 0.85 * noop {noop_p99:.2} ms"
    );

    // Checkpoint drain cells: blackdog-bb (save pauses = Optane only,
    // triples drain to HDD in the background) vs direct-to-HDD.
    let ckpt_cells = |tag: &str| -> anyhow::Result<(f64, f64)> {
        let mut cfg = sweep_cfg(&format!("ckpt-{tag}"));
        cfg.hierarchies =
            vec!["blackdog-bb".into(), "blackdog-direct-hdd".into()];
        cfg.workloads = vec!["ckpt".into()];
        cfg.ckpt_saves = 5;
        cfg.ckpt_params = 64 * 1024; // ~768 KB .data payload
        let cells = tier_sweep::run(&cfg)?;
        let bb = cells
            .iter()
            .find(|c| c.hierarchy == "blackdog-bb")
            .expect("bb cell");
        let direct = cells
            .iter()
            .find(|c| c.hierarchy == "blackdog-direct-hdd")
            .expect("direct cell");
        Ok((bb.save_total_secs, direct.save_total_secs))
    };
    let (bb_a, direct_a) = ckpt_cells("a")?;
    let (bb_b, direct_b) = ckpt_cells("b")?;
    let (bb_secs, direct_secs) = (bb_a.min(bb_b), direct_a.min(direct_b));
    let win = direct_secs / bb_secs;

    let mut t = Table::new(&["ckpt target", "save makespan ms", "win"]);
    t.row(&["blackdog-direct-hdd".into(),
            format!("{:.1}", direct_secs * 1e3), "1.00x".into()]);
    t.row(&["blackdog-bb (drain cell)".into(),
            format!("{:.1}", bb_secs * 1e3), format!("{win:.2}x")]);
    print!("{}", t.render());
    println!("target: >= 2x makespan win for the fast->slow drain cell \
              (paper reports 2.6x)");
    assert!(
        win >= 2.0,
        "burst-buffer drain cell win {win:.2}x below the 2x target"
    );

    // ---- 11. wall vs virtual clock: parity + >= 50x speedup ----
    // One pinned qos-sweep cell — sharded ingest with periodic
    // checkpoint bursts under static DRR on the slow HDD profile —
    // run under both clocks.  The workload structure (which requests,
    // how many bytes, in what submission order) is clock-independent,
    // so per-class byte totals and completion counts must match
    // EXACTLY; queue-wait tails come from the same modelled
    // contention, so the ingest p99 must land within one log2
    // histogram bucket (2x).  The virtual run never sleeps, so it
    // must beat the paced run by >= 50x in wall seconds.
    let parity_cfg = |clock: ClockSpec, tag: &str| {
        let mut cfg = qos_sweep::QosSweepConfig::standard(
            workdir(&format!("clockparity-{tag}"))
                .to_string_lossy()
                .into_owned(),
            0.25, // quarter speed: the wall run sleeps real seconds
        );
        cfg.modes = vec!["static".into()];
        cfg.intervals = vec![2];
        cfg.shards = vec![2];
        cfg.files = 128;
        cfg.clock = clock;
        cfg
    };
    let run_one = |clock: ClockSpec, tag: &str|
        -> anyhow::Result<(qos_sweep::QosSweepCell, f64)>
    {
        let t0 = Instant::now();
        let mut cells = qos_sweep::run(&parity_cfg(clock, tag))?;
        let wall = t0.elapsed().as_secs_f64();
        Ok((cells.remove(0), wall))
    };
    let (wall_cell, wall_secs) = run_one(ClockSpec::Wall, "wall")?;
    // Best-of-two for the virtual run: only its *wall* duration is
    // noise-sensitive (the cell itself is deterministic).
    let (virt_cell, virt_a) = run_one(ClockSpec::Virtual, "virt-a")?;
    let (_, virt_b) = run_one(ClockSpec::Virtual, "virt-b")?;
    let virt_secs = virt_a.min(virt_b);
    let clock_speedup = wall_secs / virt_secs;

    let mut t = Table::new(&[
        "clock", "run wall s", "images", "ingest MB", "ckpt MB",
        "ingest p99 ms",
    ]);
    for (name, c, w) in [
        ("wall", &wall_cell, wall_secs),
        ("virtual", &virt_cell, virt_secs),
    ] {
        t.row(&[
            name.into(),
            format!("{w:.3}"),
            c.images.to_string(),
            format!("{:.2}", c.ingest.mbytes),
            format!("{:.2}", c.checkpoint.mbytes),
            format!("{:.2}", c.ingest.p99_queue_ms),
        ]);
    }
    print!("{}", t.render());
    println!("target: byte/count parity exact, p99 within one log2 \
              bucket, virtual >= 50x faster ({clock_speedup:.0}x)");
    assert_eq!(virt_cell.images, wall_cell.images, "image counts diverged");
    assert_eq!(
        virt_cell.ingest.completed, wall_cell.ingest.completed,
        "ingest completion counts diverged across clocks"
    );
    assert_eq!(
        virt_cell.checkpoint.completed, wall_cell.checkpoint.completed,
        "checkpoint completion counts diverged across clocks"
    );
    assert_eq!(
        virt_cell.ingest.mbytes, wall_cell.ingest.mbytes,
        "ingest byte totals diverged across clocks"
    );
    assert_eq!(
        virt_cell.checkpoint.mbytes, wall_cell.checkpoint.mbytes,
        "checkpoint byte totals diverged across clocks"
    );
    let (p_lo, p_hi) = (
        virt_cell.ingest.p99_queue_ms.min(wall_cell.ingest.p99_queue_ms),
        virt_cell.ingest.p99_queue_ms.max(wall_cell.ingest.p99_queue_ms),
    );
    // Adjacent log2 buckets are 2x apart; the floor forgives
    // sub-quarter-millisecond tails where one host stall spans
    // several near-empty buckets.
    assert!(
        p_hi <= (2.05 * p_lo).max(0.25),
        "ingest p99 diverged past one log2 bucket: wall {:.3} ms vs \
         virtual {:.3} ms",
        wall_cell.ingest.p99_queue_ms,
        virt_cell.ingest.p99_queue_ms
    );
    assert!(
        clock_speedup >= 50.0,
        "virtual clock speedup {clock_speedup:.1}x below the 50x gate \
         (wall {wall_secs:.3} s vs virtual {virt_secs:.3} s)"
    );

    // ---- 12. virtual-clock scale: a million requests, one minute ----
    // 1M probe reads through the DRR scheduler on the SSD profile in
    // discrete-event time.  A sliding in-flight window keeps memory
    // bounded; the wall-time gate is what makes million-request
    // workloads admissible in CI at all (in wall mode this workload
    // is ~100 modelled seconds of sleeping).
    let sim = Arc::new(StorageSim::cold_with_qos_clock(
        workdir("million"),
        vec![profiles::blackdog_ssd(1.0)],
        QosConfig::default(),
        Clock::virt(),
    )?);
    let eng = sim.engine();
    let clock = sim.clock().clone();
    let _reg = clock.enter();
    const MILLION: u64 = 1_000_000;
    let t0_wall = Instant::now();
    let t0_virt = clock.now();
    let mut inflight = std::collections::VecDeque::with_capacity(4096);
    for _ in 0..MILLION {
        inflight.push_back(eng.submit(IoRequest::ProbeRead {
            device: "ssd".into(),
            bytes: 4096,
        })?);
        if inflight.len() >= 4096 {
            inflight.pop_front().expect("non-empty window").wait()?;
        }
    }
    for tk in inflight {
        tk.wait()?;
    }
    let wall = t0_wall.elapsed().as_secs_f64();
    let virt = clock.now() - t0_virt;
    let stats = eng.stats();
    let s = stats.iter().find(|s| s.device == "ssd").expect("ssd stats");
    assert_eq!(s.completed, MILLION, "requests lost at scale");

    let mut t = Table::new(&["quantity", "value"]);
    t.row(&["requests".into(), MILLION.to_string()]);
    t.row(&["modelled (virtual) s".into(), format!("{virt:.1}")]);
    t.row(&["wall s".into(), format!("{wall:.1}")]);
    t.row(&["requests / wall s".into(),
            format!("{:.0}", MILLION as f64 / wall)]);
    print!("{}", t.render());
    println!("target: 1M requests complete in < 60 s of wall time");
    assert!(
        wall < 60.0,
        "million-request cell took {wall:.1} s wall (gate: 60 s)"
    );

    // ---- 13. fleet isolation: nested DRR vs tenant-blind ----
    // Four equal-share tenants on a saturated single-channel 200 MB/s
    // device, every one admission-capped at the fair quarter
    // (50 MB/s): tenant "hog" floods a 64-deep closed loop with 10x a
    // victim's read volume while three victims run paced 8-read
    // ingest bursts.  Under the nested scheduler a victim's p99 is
    // dominated by its own admission pacing — identical whether the
    // fleet is there or not — so p99 stays within 1.3x of the solo
    // run and goodput splits fairly.  The tenant-blind scheduler (one
    // slot, no caps) serves the shared Ingest queue in arrival order,
    // so the hog's backlog sits in front of every victim read: the
    // identical cell fails both gates.
    drop(_reg); // §12's clock guard; §13 cells run their own clocks.
    const FLEET_CHUNK: usize = 64 * 1024;
    const FLEET_READ: u64 = 64 * 1024;
    const FLEET_BURST: usize = 8;
    const FLEET_BURSTS: usize = 60;
    const FLEET_PERIOD: f64 = 12e-3;
    const FLEET_VICTIMS: usize = 3;
    const FAIR_CAP: f64 = 50e6;
    const NOISY_WINDOW: usize = 64;
    const NOISY_READS: usize = 10 * FLEET_BURSTS * FLEET_BURST;

    fn fleet_device() -> DeviceModel {
        DeviceModel {
            name: "dev".into(),
            read_bw: 200e6,
            write_bw: 200e6,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 1,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
            lat_tables: None,
        }
    }

    /// One cell: `victims` paced tenants (plus an optional hog at 10x
    /// load) on one device under `qos`.  Returns per-victim ingest
    /// p99 queue waits (secs) and per-tenant goodputs (MB/s over each
    /// tenant's own active window, hog last).
    fn fleet_cell(
        qos: QosConfig,
        victims: usize,
        noisy: bool,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
        let clock = Clock::virt();
        let mut devices = HashMap::new();
        devices.insert(
            "dev".to_string(),
            Arc::new(Device::with_clock(
                fleet_device(),
                Arc::new(NullObserver),
                clock.clone(),
            )),
        );
        let engine =
            Arc::new(IoEngine::with_config(&devices, FLEET_CHUNK, qos));
        let sink = MemorySink::new();
        engine.set_observer(Arc::clone(&sink) as Arc<dyn EngineObserver>);
        let names: Vec<String> = (0..victims)
            .map(|i| format!("t{i}"))
            .chain(noisy.then(|| "hog".to_string()))
            .collect();
        // Register-then-barrier (the clock-test idiom): every tenant
        // thread joins the clock before any submits, so virtual time
        // can't run ahead of a late-spawning thread.
        let barrier = Arc::new(std::sync::Barrier::new(names.len()));
        let t0 = clock.now();
        let handles: Vec<_> = names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let engine = Arc::clone(&engine);
                let clock = clock.clone();
                let barrier = Arc::clone(&barrier);
                let tenant = TenantId::new(name);
                let hog = noisy && i == victims;
                std::thread::spawn(move || -> anyhow::Result<f64> {
                    let _reg = clock.enter();
                    barrier.wait();
                    with_tenant(&tenant, || {
                        if hog {
                            // The closed-loop flood the admission
                            // layer (when present) has to police.
                            let mut win =
                                std::collections::VecDeque::new();
                            for _ in 0..NOISY_READS {
                                if win.len() >= NOISY_WINDOW {
                                    win.pop_front()
                                        .expect("non-empty window")
                                        .wait()?;
                                }
                                win.push_back(engine.submit(
                                    IoRequest::ProbeRead {
                                        device: "dev".into(),
                                        bytes: FLEET_READ,
                                    },
                                )?);
                            }
                            for tk in win {
                                tk.wait()?;
                            }
                        } else {
                            // Paced ingest: one burst per period,
                            // gated on the previous burst completing
                            // (a training step consuming its batch),
                            // phases staggered across victims.
                            let phase = i as f64 * FLEET_PERIOD / 4.0;
                            for b in 0..FLEET_BURSTS {
                                let due = t0
                                    + phase
                                    + b as f64 * FLEET_PERIOD;
                                let now = clock.now();
                                if due > now {
                                    clock.sleep_secs(due - now);
                                }
                                let burst: Vec<_> = (0..FLEET_BURST)
                                    .map(|_| {
                                        engine.submit(
                                            IoRequest::ProbeRead {
                                                device: "dev".into(),
                                                bytes: FLEET_READ,
                                            },
                                        )
                                    })
                                    .collect::<anyhow::Result<_>>()?;
                                for tk in burst {
                                    tk.wait()?;
                                }
                            }
                        }
                        Ok(clock.now() - t0)
                    })
                })
            })
            .collect();
        let mut actives = Vec::new();
        for h in handles {
            actives.push(h.join().map_err(|_| {
                anyhow::anyhow!("fleet tenant thread panicked")
            })??);
        }
        engine.clear_observer();

        let events = sink.events();
        let mut p99s = Vec::new();
        let mut goodputs = Vec::new();
        for (i, name) in names.iter().enumerate() {
            let mut queues: Vec<f64> = Vec::new();
            let mut bytes = 0u64;
            for e in events.iter().filter(|e| &e.tenant == name) {
                if matches!(e.class, IoClass::Ingest) {
                    bytes += e.bytes;
                    queues.push(e.queue_secs);
                }
            }
            goodputs.push(bytes as f64 / 1e6 / actives[i].max(1e-9));
            if !(noisy && i == victims) {
                assert!(
                    !queues.is_empty(),
                    "victim {name} completed no ingest reads"
                );
                queues.sort_by(|a, b| a.total_cmp(b));
                let n = queues.len();
                let rank =
                    ((n as f64 * 0.99).ceil() as usize).max(1) - 1;
                p99s.push(queues[rank.min(n - 1)]);
            }
        }
        Ok((p99s, goodputs))
    }

    // Shares police the queue, caps police admission — the hog gets
    // the same quarter as everyone else, no tenant is special.
    let mut fleet_names: Vec<String> =
        (0..FLEET_VICTIMS).map(|i| format!("t{i}")).collect();
    fleet_names.push("hog".to_string());
    let mut aware_tq = TenantQos::default();
    for n in &fleet_names {
        aware_tq = aware_tq.with_rate_cap(n, FAIR_CAP, FLEET_READ);
    }
    let aware = QosConfig::default().with_tenants(aware_tq);

    let (solo_aware, _) = fleet_cell(aware.clone(), 1, false)?;
    let (fleet_aware, good_aware) =
        fleet_cell(aware, FLEET_VICTIMS, true)?;
    let (solo_blind, _) = fleet_cell(QosConfig::default(), 1, false)?;
    let (fleet_blind, good_blind) =
        fleet_cell(QosConfig::default(), FLEET_VICTIMS, true)?;

    let base_aware = solo_aware[0];
    let base_blind = solo_blind[0].max(1e-6);
    let j_aware = fleet_sweep::jain(&good_aware);
    let j_blind = fleet_sweep::jain(&good_blind);

    let mut t = Table::new(&[
        "scheduler", "victim", "solo p99 ms", "fleet p99 ms", "ratio",
    ]);
    for (i, p) in fleet_aware.iter().enumerate() {
        t.row(&[
            "tenant-aware".into(),
            format!("t{i}"),
            format!("{:.3}", base_aware * 1e3),
            format!("{:.3}", p * 1e3),
            format!("{:.2}x", p / base_aware),
        ]);
    }
    for (i, p) in fleet_blind.iter().enumerate() {
        t.row(&[
            "tenant-blind".into(),
            format!("t{i}"),
            format!("{:.3}", base_blind * 1e3),
            format!("{:.3}", p * 1e3),
            format!("{:.2}x", p / base_blind),
        ]);
    }
    print!("{}", t.render());
    println!(
        "jain(goodput): tenant-aware {j_aware:.3}, tenant-blind \
         {j_blind:.3}"
    );
    println!(
        "target: victim p99 <= 1.3x solo and jain >= 0.9 under the \
         nested DRR; tenant-blind fails both"
    );
    assert!(
        base_aware >= 2e-3,
        "solo baseline p99 {:.3} ms too small to anchor the ratio gate",
        base_aware * 1e3
    );
    for (i, p) in fleet_aware.iter().enumerate() {
        assert!(
            *p <= 1.3 * base_aware,
            "victim t{i} ingest p99 {:.3} ms exceeds 1.3x its solo \
             baseline {:.3} ms under the nested DRR",
            p * 1e3,
            base_aware * 1e3
        );
    }
    assert!(
        j_aware >= 0.9,
        "per-tenant goodput jain {j_aware:.3} below the 0.9 gate under \
         the nested DRR"
    );
    let worst_blind = fleet_blind.iter().copied().fold(0.0_f64, f64::max);
    assert!(
        worst_blind > 1.3 * base_blind,
        "tenant-blind victim p99 {:.3} ms unexpectedly within 1.3x of \
         its solo baseline {:.3} ms — the hog no longer hurts",
        worst_blind * 1e3,
        base_blind * 1e3
    );
    assert!(
        j_blind < 0.9,
        "tenant-blind jain {j_blind:.3} unexpectedly fair — the hog no \
         longer skews goodput"
    );

    // ---- 14. fault seam: degraded-mode operation (DESIGN.md §15) ----
    // (a) Mid-drain outage: the slow tier is offline for the first
    // 100 ms while the burst buffer drains.  Saves keep landing on
    // the healthy fast tier, the migrator pauses and requeues instead
    // of erroring, and once the fault clears every checkpoint drains
    // oldest-first — zero lost, all restorable from the slow tier.
    let mk = |name: &str, write_lat: f64| DeviceModel {
        name: name.into(),
        read_bw: 1e9,
        write_bw: 1e9,
        read_lat: 0.0,
        write_lat,
        channels: 1,
        elevator: vec![(1, 1.0)],
        time_scale: 1.0,
        lat_tables: None,
    };
    let sim = Arc::new(StorageSim::cold(
        workdir("faultbb"),
        vec![mk("fast", 0.0), mk("slow", 0.004)],
    )?);
    sim.apply_fault_plan(&FaultPlan::parse("offline:slow:0:0.1")?)?;
    let profile = small_profile();
    let state = ModelState::init(&profile, 14);
    let fault_steps: Vec<u64> = (1..=5).map(|i| i * 10).collect();
    let t0 = Instant::now();
    {
        let mut bb = BurstBuffer::new(
            Arc::clone(&sim),
            profile.clone(),
            "fast",
            "slow",
            "ck/m",
            2, // retention quota below the paused backlog
        )?;
        bb.saver_mut().sync_on_save = false;
        for &s in &fault_steps {
            bb.save(&state, s)?;
        }
        bb.wait_drained();
        let pauses = bb.hierarchy().migration_pauses();
        let mut t = Table::new(&["quantity", "value"]);
        t.row(&["checkpoints saved".into(),
                fault_steps.len().to_string()]);
        t.row(&["drained to slow tier".into(),
                bb.drained_count().to_string()]);
        t.row(&["migrator pauses".into(), pauses.to_string()]);
        t.row(&["drain errors".into(),
                bb.drain_error_count().to_string()]);
        t.row(&["wall s incl. 0.1 s outage".into(),
                format!("{:.3}", t0.elapsed().as_secs_f64())]);
        print!("{}", t.render());
        assert_eq!(
            bb.drain_error_count(),
            0,
            "paused drains must not surface as migration errors"
        );
        assert!(pauses >= 1, "offline window never paused the migrator");
        assert_eq!(
            bb.drained_steps(),
            fault_steps,
            "drains must stay oldest-first across the fault"
        );
    }
    for &s in &fault_steps {
        let h = CheckpointHandle {
            device: "slow".into(),
            prefix: "ck/m".into(),
            step: s,
        };
        let back = Saver::restore(&sim, &profile, &h)?;
        assert_eq!(
            back.params, state.params,
            "step {s} lost or corrupted across the fault window"
        );
    }
    sim.clear_faults();
    println!(
        "target: zero drain errors, >= 1 migrator pause, all {} \
         checkpoints restorable from the slow tier",
        fault_steps.len()
    );

    // (b) Restart storm: every tenant opens with a correlated
    // checkpoint-restore burst; the fleet cell must report a positive
    // per-tenant time-to-recover bounded by the cell makespan, with a
    // valid goodput Jain.
    let mut fault_fleet = fleet_sweep::FleetSweepConfig::smoke(1000.0);
    fault_fleet.schemes = vec!["equal".into()];
    fault_fleet.scenarios = vec!["restart".into()];
    let rows = fleet_sweep::run(&fault_fleet)?;
    assert_eq!(rows.len(), 2, "one smoke restart cell, two tenants");
    let mut t = Table::new(&[
        "tenant", "recovery ms", "elapsed ms", "jain goodput",
    ]);
    for r in &rows {
        t.row(&[
            r.tenant.clone(),
            format!("{:.3}", r.recovery_secs * 1e3),
            format!("{:.3}", r.elapsed_secs * 1e3),
            format!("{:.3}", r.jain_goodput),
        ]);
        assert!(
            r.recovery_secs > 0.0,
            "{}: restart cell reported no time-to-recover",
            r.tenant
        );
        assert!(
            r.recovery_secs <= r.elapsed_secs + 1e-9,
            "{}: recovery {:.6} s exceeds cell makespan {:.6} s",
            r.tenant,
            r.recovery_secs,
            r.elapsed_secs
        );
        assert!(
            r.jain_goodput > 0.0 && r.jain_goodput <= 1.0 + 1e-9,
            "{}: goodput jain {:.3} outside (0, 1]",
            r.tenant,
            r.jain_goodput
        );
    }
    print!("{}", t.render());
    println!(
        "target: restart rows report recovery > 0 within the cell \
         makespan and a valid goodput jain"
    );

    // (c) Determinism: the same fault-injected replay under the
    // virtual clock is bit-deterministic — two runs of the §9
    // contention trace with an armed `slow:hdd` fault produce the
    // exact same clock makespan, and the fault visibly stretches the
    // healthy replay's.
    let run_injected = |inject: Option<&str>| -> anyhow::Result<f64> {
        let cfg = ReplayConfig {
            qos: QosConfig::default(),
            profile: Some("hdd".into()),
            time_scale: Some(4.0),
            clock: ClockSpec::Virtual,
            inject: inject.map(str::to_string),
            ..ReplayConfig::default()
        };
        let outcome = replay(&trace, &cfg)?;
        assert_eq!(outcome.errors, 0, "slow fault must not error");
        Ok(outcome.wall_secs)
    };
    let healthy = run_injected(None)?;
    let inj_a = run_injected(Some("slow:hdd"))?;
    let inj_b = run_injected(Some("slow:hdd"))?;
    let mut t = Table::new(&["replay", "virtual makespan s"]);
    t.row(&["healthy".into(), format!("{healthy:.6}")]);
    t.row(&["slow:hdd run 1".into(), format!("{inj_a:.6}")]);
    t.row(&["slow:hdd run 2".into(), format!("{inj_b:.6}")]);
    print!("{}", t.render());
    println!(
        "target: injected runs bit-equal; fault stretches the healthy \
         makespan >= 2x"
    );
    assert_eq!(
        inj_a.to_bits(),
        inj_b.to_bits(),
        "identical virtual-clock fault replays diverged: {inj_a} vs \
         {inj_b}"
    );
    assert!(
        inj_a >= 2.0 * healthy,
        "slow:hdd replay {inj_a:.6} s not >= 2x healthy {healthy:.6} s"
    );

    // ---- 15. prefetcher overlap: step time -> max(compute, input) ----
    // The paper's headline result, gated on the modelled accelerator
    // (DESIGN.md §16).  The cell is pinned compute-bound (alexnet @
    // batch 16 on a K80: C ≈ 3.8 ms scaled vs I ≈ 1.3 ms off the SSD)
    // with a 1-shard x 1-wide reader window, so the synchronous column
    // can hide at most one file read per step and stays additive,
    // while depth-4 prefetch overlaps the whole input pipeline.
    let mut ov = overlap_sweep::OverlapSweepConfig::standard(
        workdir("overlap-gate").to_string_lossy().into_owned(),
        8.0,
    );
    ov.targets = vec!["ssd".into()];
    ov.shards = vec![1];
    ov.window = 1;
    ov.prefetch = vec![0, 4];
    ov.batch = 16;
    ov.steps = 30;
    let rows = overlap_sweep::run(&ov)?;
    assert_eq!(rows.len(), 2, "one pinned cell per prefetch depth");
    let sync = &rows[0];
    let over = &rows[1];
    assert_eq!((sync.prefetch, over.prefetch), (0, 4));
    let c = over.compute_ms_per_step;
    let i = over.input_ms_per_step;
    let mut t = Table::new(&[
        "prefetch", "step ms", "C ms", "I ms", "stall frac", "eff io ms",
    ]);
    for r in [sync, over] {
        t.row(&[
            r.prefetch.to_string(),
            format!("{:.3}", r.step_ms),
            format!("{:.3}", r.compute_ms_per_step),
            format!("{:.3}", r.input_ms_per_step),
            format!("{:.3}", r.stall_frac),
            format!("{:.3}", r.eff_io_ms_per_step),
        ]);
    }
    print!("{}", t.render());
    println!(
        "target: depth-4 step <= 1.05x max(C, I) with stall frac <= \
         0.05; synchronous step >= 0.9x (C + I)"
    );
    assert!(c > i, "gate cell must be compute-bound: C {c} vs I {i}");
    assert!(
        over.step_ms <= 1.05 * c.max(i),
        "overlapped step {:.4} ms exceeds 1.05x max(C, I) = {:.4} ms",
        over.step_ms,
        1.05 * c.max(i)
    );
    assert!(
        over.stall_frac <= 0.05,
        "overlapped stall fraction {:.4} above 0.05",
        over.stall_frac
    );
    assert!(
        sync.step_ms >= 0.9 * (c + i),
        "synchronous step {:.4} ms below 0.9x (C + I) = {:.4} ms — \
         prefetch 0 must pay the input cost additively",
        sync.step_ms,
        0.9 * (c + i)
    );

    // ---- 16. cost-aware placement under Zipf capacity pressure ----
    // The calibrated 2-tier preset (per-block-size latency tables on
    // both devices — the numbers the cost model prices with) under a
    // moderately skewed read-hot Zipf stream whose working set is 12x
    // tier-0 capacity: the small-cache/long-tail regime where recency
    // and frequency rankings genuinely diverge.  After the tail has
    // been touched a few times every block clears freq's count
    // threshold, so freq promotes on essentially every miss — LRU
    // churn that evicts head-set members and queues copy-read +
    // demotion-write pairs behind ingest on the slow device.  Cost
    // only swaps when the candidate is hotter than the victim it
    // displaces AND the modelled gain exceeds the migration cost, so
    // the head set freezes in tier 0 and the slow queue stays short.
    // (A discrete-event model of this cell puts cost's hit fraction
    // at >= 1.3x freq and its slow-device load at <= 0.55x across
    // seeds and promotion-landing delays — comfortable margin over
    // the 1.1x / 0.9x gates below.)
    let zipf_cfg = |tag: &str| {
        let mut cfg = tier_sweep::TierSweepConfig::smoke(
            workdir(&format!("costgate-{tag}"))
                .to_string_lossy()
                .into_owned(),
            8.0,
        );
        cfg.hierarchies = vec!["calibrated-tiered".into()];
        cfg.policies = vec!["freq".into(), "cost".into()];
        cfg.workloads = vec!["zipf:0.8".into()];
        cfg.files = 128;
        cfg.file_bytes = 32 * 1024;
        cfg.reads = 2880;
        cfg.warmup_reads = 960;
        cfg.rw_ratio = 1.0; // read-hot: invalidation churn is a wash
        cfg.shards = 2;
        cfg.window = 4;
        cfg.tier0_cap = 0;
        cfg.ws_ratio = 12.0; // tier 0 holds ~10 of 128 blocks
        cfg
    };
    let zipf_cells = |tag: &str| -> anyhow::Result<(f64, f64, f64, f64, f64, u64)> {
        let cells = tier_sweep::run(&zipf_cfg(tag))?;
        let freq = cells
            .iter()
            .find(|c| c.policy == "freq")
            .expect("freq cell");
        let cost = cells
            .iter()
            .find(|c| c.policy == "cost")
            .expect("cost cell");
        Ok((
            freq.t0_hit_frac,
            freq.ingest_p99_ms,
            cost.t0_hit_frac,
            cost.ingest_p99_ms,
            cost.cost_accuracy,
            cost.rejected_by_cost,
        ))
    };
    let (f_hit_a, f_p99_a, c_hit_a, c_p99_a, acc_a, rej_a) = zipf_cells("a")?;
    let (f_hit_b, f_p99_b, c_hit_b, c_p99_b, acc_b, rej_b) = zipf_cells("b")?;
    let (freq_hit, freq_p99) = (f_hit_a.max(f_hit_b), f_p99_a.min(f_p99_b));
    let (cost_hit, cost_p99) = (c_hit_a.max(c_hit_b), c_p99_a.min(c_p99_b));
    let (cost_acc, cost_rej) = (acc_a.max(acc_b), rej_a.max(rej_b));

    let mut t = Table::new(&[
        "policy", "tier-0 hit frac", "ingest p99 queue ms",
        "rejected-by-cost",
    ]);
    t.row(&["freq".into(), format!("{freq_hit:.2}"),
            format!("{freq_p99:.2}"), "-".into()]);
    t.row(&["cost".into(), format!("{cost_hit:.2}"),
            format!("{cost_p99:.2}"), cost_rej.to_string()]);
    print!("{}", t.render());
    println!("target: cost ingest p99 <= 0.9x freq, cost hit frac >= \
              1.1x freq, on the Zipf(0.8) read stream at 12x capacity \
              pressure");
    assert!(
        cost_p99 <= 0.9 * freq_p99,
        "cost policy did not unload the slow queue: cost p99 \
         {cost_p99:.2} ms !<= 0.9 * freq {freq_p99:.2} ms"
    );
    assert!(
        cost_hit >= 1.1 * freq_hit,
        "cost policy did not hold the head set: cost hit frac \
         {cost_hit:.2} !>= 1.1 * freq {freq_hit:.2}"
    );
    assert!(
        cost_rej > 0,
        "pressure cell never rejected a migration on cost — the veto \
         is not engaging"
    );
    assert!(
        cost_acc > 0.0,
        "cost model priced no migrations (accuracy column empty)"
    );

    println!("\nengine acceptance: PASS");
    Ok(())
}
