//! IoEngine acceptance bench (DESIGN.md §9): the two properties the
//! request-level engine buys over the old blocking facade.
//!
//! 1. **Overlapped checkpoint save** — the saver submits the
//!    meta/index/data triple through one doorbell, so even a
//!    single-channel HDD sees the burst and its elevator gain cuts the
//!    per-file seek cost.  Target: >= 1.5x over the serial three-write
//!    baseline on the Blackdog HDD profile.
//! 2. **Bounded drain memory** — a burst-buffer style cross-device
//!    copy streams chunks through a bounded window; peak buffered
//!    bytes are a function of the chunk size, not the file size.
//!
//! No PJRT artifacts needed.

use std::sync::Arc;

use dlio::checkpoint::Saver;
use dlio::metrics::{median, Table};
use dlio::model::ModelState;
use dlio::runtime::meta::{ParamSpec, ProfileMeta};
use dlio::storage::engine::{DEFAULT_CHUNK, STREAM_WINDOW};
use dlio::storage::{profiles, SimPath, StorageSim};

fn small_profile() -> ProfileMeta {
    // ~26 KB data payload: seek-dominated on an HDD, which is the
    // regime where overlapping the triple matters most.
    ProfileMeta {
        name: "bench".into(),
        input_size: 8,
        num_classes: 4,
        num_params: 32 * 64 + 64,
        params: vec![
            ParamSpec { name: "fc1/kernel".into(), shape: vec![32, 64] },
            ParamSpec { name: "fc1/bias".into(), shape: vec![64] },
        ],
    }
}

fn workdir(tag: &str) -> std::path::PathBuf {
    let base = if std::path::Path::new("/dev/shm").is_dir() {
        std::path::PathBuf::from("/dev/shm")
    } else {
        std::env::temp_dir()
    };
    let dir = base.join(format!("dlio-bench-engine-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn main() -> anyhow::Result<()> {
    println!("\n=== engine: request-level I/O engine acceptance ===");

    // ---- 1. overlapped checkpoint triple vs serial, HDD profile ----
    // Unscaled HDD (8 ms write latency) so the modelled seeks dwarf
    // host noise.
    let sim = Arc::new(StorageSim::cold(
        workdir("overlap"),
        vec![profiles::blackdog_hdd(1.0)],
    )?);
    let profile = small_profile();
    let state = ModelState::init(&profile, 1);

    let reps = 5;
    let mut serial_times = Vec::new();
    let mut overlap_times = Vec::new();
    for rep in 0..=reps {
        // Serial baseline: the pre-engine behaviour — three blocking
        // whole-file writes, one after another.
        let h_base = format!("serial/m{rep}");
        let data = state.to_bytes();
        let t0 = std::time::Instant::now();
        sim.write(&SimPath::new("hdd", format!("{h_base}.meta")), b"{}")?;
        sim.write(&SimPath::new("hdd", format!("{h_base}.index")), b"{}")?;
        sim.write(&SimPath::new("hdd", format!("{h_base}.data")), &data)?;
        let t_serial = t0.elapsed().as_secs_f64();

        // Overlapped: the saver's batched submissions.
        let mut saver = Saver::new(
            Arc::clone(&sim),
            profile.clone(),
            "hdd",
            &format!("overlap/m{rep}"),
            2,
        );
        saver.sync_on_save = false;
        let t0 = std::time::Instant::now();
        saver.save(&state, 1)?;
        let t_overlap = t0.elapsed().as_secs_f64();

        if rep > 0 {
            // First rep is warm-up (paper protocol).
            serial_times.push(t_serial);
            overlap_times.push(t_overlap);
        }
    }
    let t_serial = median(&mut serial_times);
    let t_overlap = median(&mut overlap_times);
    let speedup = t_serial / t_overlap;

    let mut t = Table::new(&["save strategy", "median ms", "speedup"]);
    t.row(&["serial 3-write (old facade)".into(),
            format!("{:.2}", t_serial * 1e3), "1.00x".into()]);
    t.row(&["overlapped engine triple".into(),
            format!("{:.2}", t_overlap * 1e3), format!("{speedup:.2}x")]);
    print!("{}", t.render());
    println!("target: >= 1.5x on the HDD profile (elevator gain over the \
              co-queued burst)");
    assert!(
        speedup >= 1.5,
        "overlapped save speedup {speedup:.2}x below the 1.5x target"
    );

    // ---- 2. drain memory bounded by chunk size, not file size ----
    // Accelerated devices: the 32 MB copy finishes in ms while the
    // stream accounting is time-scale independent.
    let sim = Arc::new(StorageSim::cold(
        workdir("drainmem"),
        vec![profiles::blackdog_optane(500.0), profiles::blackdog_hdd(500.0)],
    )?);
    let file_bytes = 32usize << 20;
    let src = SimPath::new("optane", "stage/ck.data");
    let dst = SimPath::new("hdd", "archive/ck.data");
    let payload: Vec<u8> = (0..file_bytes).map(|i| (i % 251) as u8).collect();
    sim.write(&src, &payload)?;

    sim.engine().reset_peak_stream_bytes();
    let copied = sim.copy(&src, &dst)?;
    assert_eq!(copied, file_bytes as u64);
    assert_eq!(sim.read(&dst)?, payload, "copy must be bit-exact");
    let peak = sim.engine().peak_stream_bytes();
    let bound = (DEFAULT_CHUNK * (STREAM_WINDOW + 1)) as u64;

    let mut t = Table::new(&["quantity", "bytes"]);
    t.row(&["file size".into(), format!("{file_bytes}")]);
    t.row(&["chunk size".into(), format!("{DEFAULT_CHUNK}")]);
    t.row(&["peak stream buffer".into(), format!("{peak}")]);
    t.row(&["bound (chunk * (window+1))".into(), format!("{bound}")]);
    print!("{}", t.render());
    assert!(peak <= bound, "peak {peak} exceeds chunked bound {bound}");
    assert!(
        peak < (file_bytes / 4) as u64,
        "peak {peak} scales with file size, not chunk size"
    );

    // ---- 3. per-request queue/service metrics surface ----
    let mut t = Table::new(&[
        "Device", "reqs", "mean queue ms", "mean service ms",
        "max depth", "MB read", "MB written",
    ]);
    for s in sim.engine().stats() {
        t.row(&[
            s.device.clone(),
            s.completed.to_string(),
            format!("{:.3}", s.mean_queue_secs() * 1e3),
            format!("{:.3}", s.mean_service_secs() * 1e3),
            s.max_queue_depth.to_string(),
            format!("{:.1}", s.bytes_read as f64 / 1e6),
            format!("{:.1}", s.bytes_written as f64 / 1e6),
        ]);
    }
    print!("{}", t.render());
    println!("\nengine acceptance: PASS");
    Ok(())
}
