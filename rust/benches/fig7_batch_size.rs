//! Fig. 7: effect of batch size on mini-app training time (8 map
//! threads, with and without prefetch).
//!
//! Paper shape: execution time for a fixed number of images decreases
//! as batch size grows (better accelerator utilization), for both
//! prefetch settings.

use std::sync::Arc;

use dlio::bench;
use dlio::config::MiniAppConfig;
use dlio::coordinator::{ensure_corpus, miniapp};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;

fn main() -> anyhow::Result<()> {
    bench::banner(
        "Fig. 7",
        "mini-app runtime vs batch size (8 threads)",
        "larger batches -> shorter time for the same image count \
         (higher accelerator utilization, §V-B)",
    );
    let env = bench::env("fig7", None)?;
    let total_images = bench::pick(256usize, 512, 9088);
    let spec = CorpusSpec::caltech101(total_images);
    let manifest = ensure_corpus(&env.sim, "ssd", &spec)?;

    let mut table = Table::new(&[
        "Batch", "iters", "prefetch=0 s", "prefetch=1 s",
        "imgs/s (pf=1)",
    ]);
    for batch in [16usize, 32, 64, 128] {
        let iterations = total_images / batch;
        if iterations == 0 {
            continue;
        }
        let mut totals = [0.0f64; 2];
        for (i, prefetch) in [0usize, 1].into_iter().enumerate() {
            let cfg = MiniAppConfig {
                device: "ssd".into(),
                threads: 8,
                batch,
                prefetch,
                iterations,
                profile: "micro".into(),
                seed: 5,
            };
            env.sim.drop_caches();
            let r = miniapp::run(
                Arc::clone(&env.sim), &env.rt, &manifest, &cfg)?;
            totals[i] = r.total_secs;
        }
        table.row(&[
            batch.to_string(),
            iterations.to_string(),
            format!("{:.2}", totals[0]),
            format!("{:.2}", totals[1]),
            format!("{:.0}", (iterations * batch) as f64 / totals[1]),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
