//! End-to-end runtime tests against the real AOT artifacts.
//!
//! These are the cross-language integration proof: the HLO text emitted
//! by `python/compile/aot.py` (JAX L2 + Pallas L1) loads, compiles and
//! executes correctly from rust via PJRT, and the training loop built
//! on it learns.  Requires `make artifacts` (skipped otherwise).

use dlio::model::Trainer;
use dlio::pipeline::ImageBatch;
use dlio::runtime::executable::lit;
use dlio::runtime::Runtime;
use dlio::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = std::env::var("DLIO_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(_) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

/// Reference normalize+resize for a constant image: every output pixel
/// of channel c is (v/255 - mean[c]) / std[c] regardless of resampling
/// (rows of the interpolation matrices sum to 1).
fn expected_constant(v: u8) -> [f32; 3] {
    const MEAN: [f32; 3] = [0.485, 0.456, 0.406];
    const STD: [f32; 3] = [0.229, 0.224, 0.225];
    let x = v as f32 / 255.0;
    [
        (x - MEAN[0]) / STD[0],
        (x - MEAN[1]) / STD[1],
        (x - MEAN[2]) / STD[2],
    ]
}

#[test]
fn preprocess_kernel_executes_and_matches_oracle() {
    let Some(rt) = runtime() else { return };
    let spec = rt.preprocess(96, 64).expect("96->64 bucket");
    let exe = spec.get().expect("compile preprocess");

    // Constant image: closed-form expected output.
    let raw = vec![128u8; 96 * 96 * 3];
    let out = dlio::coordinator::workload::run_preprocess(&exe, &raw, 96, 64)
        .expect("run preprocess");
    assert_eq!(out.len(), 64 * 64 * 3);
    let want = expected_constant(128);
    for (i, v) in out.iter().enumerate() {
        let c = i % 3;
        assert!(
            (v - want[c]).abs() < 1e-4,
            "pixel {i} channel {c}: {v} vs {}", want[c]
        );
    }
}

#[test]
fn preprocess_interpolates_gradients_monotonically() {
    let Some(rt) = runtime() else { return };
    let exe = rt.preprocess(96, 64).unwrap().get().unwrap();
    // Horizontal ramp: resized rows must stay monotonically increasing.
    let mut raw = vec![0u8; 96 * 96 * 3];
    for y in 0..96 {
        for x in 0..96 {
            for c in 0..3 {
                raw[(y * 96 + x) * 3 + c] = ((x * 255) / 95) as u8;
            }
        }
    }
    let out = dlio::coordinator::workload::run_preprocess(&exe, &raw, 96, 64)
        .unwrap();
    for x in 1..64 {
        let prev = out[(32 * 64 + (x - 1)) * 3];
        let cur = out[(32 * 64 + x) * 3];
        assert!(cur >= prev - 1e-5, "x={x}: {cur} < {prev}");
    }
}

#[test]
fn preprocess_runs_concurrently_from_many_threads() {
    // The map fan-out executes the kernel from `num_parallel_calls`
    // threads, each with its own thread-local client (see
    // runtime::executable docs).  This must be race-free and correct.
    let Some(rt) = runtime() else { return };
    let rt = std::sync::Arc::new(rt);
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let rt = std::sync::Arc::clone(&rt);
            std::thread::spawn(move || {
                let exe = rt.preprocess(96, 64).unwrap().get().unwrap();
                for i in 0..8 {
                    let v = (t * 40 + i * 5) as u8;
                    let raw = vec![v; 96 * 96 * 3];
                    let out = dlio::coordinator::workload::run_preprocess(
                        &exe, &raw, 96, 64).unwrap();
                    let want = expected_constant(v);
                    assert!((out[0] - want[0]).abs() < 1e-4);
                    assert!((out[1] - want[1]).abs() < 1e-4);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("worker panicked");
    }
}

fn synthetic_batch(rng: &mut Rng, size: usize, batch: usize,
                   classes: u32) -> ImageBatch {
    let samples = (0..batch)
        .map(|_| dlio::pipeline::ProcessedImage {
            pixels: (0..size * size * 3)
                .map(|_| rng.next_f32() * 2.0 - 1.0)
                .collect(),
            size: size as u32,
            label: rng.next_below(classes as u64) as u32,
            bytes_read: 0,
        })
        .collect();
    ImageBatch::assemble(samples, classes).unwrap()
}

#[test]
fn train_step_decreases_loss_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let mut trainer = Trainer::new(&rt, "micro", 16, 1).expect("trainer");
    let prof = trainer.profile().clone();
    let mut rng = Rng::new(3);
    let batch = synthetic_batch(&mut rng, prof.input_size, 16,
                                prof.num_classes as u32);
    let mut losses = Vec::new();
    for _ in 0..8 {
        losses.push(trainer.step(&batch).expect("step"));
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
    assert_eq!(trainer.step_count(), 8);
    assert!(trainer.state().max_abs_param().is_finite());
}

#[test]
fn train_step_rejects_wrong_batch_size() {
    let Some(rt) = runtime() else { return };
    let mut trainer = Trainer::new(&rt, "micro", 16, 1).unwrap();
    let prof = trainer.profile().clone();
    let mut rng = Rng::new(4);
    let batch = synthetic_batch(&mut rng, prof.input_size, 8,
                                prof.num_classes as u32);
    assert!(trainer.step(&batch).is_err());
}

#[test]
fn trainer_restore_roundtrip_continues_from_step() {
    let Some(rt) = runtime() else { return };
    let mut t1 = Trainer::new(&rt, "micro", 16, 1).unwrap();
    let prof = t1.profile().clone();
    let mut rng = Rng::new(5);
    let batch = synthetic_batch(&mut rng, prof.input_size, 16,
                                prof.num_classes as u32);
    for _ in 0..3 {
        t1.step(&batch).unwrap();
    }
    let snapshot = t1.state().clone();

    let mut t2 = Trainer::new(&rt, "micro", 16, 99).unwrap();
    t2.restore(snapshot).unwrap();
    assert_eq!(t2.step_count(), 3);
    // Both trainers take the same next step -> identical loss.
    let l1 = t1.step(&batch).unwrap();
    let l2 = t2.step(&batch).unwrap();
    assert!((l1 - l2).abs() < 1e-6, "{l1} vs {l2}");
}

#[test]
fn all_default_artifacts_compile_and_have_right_arity() {
    let Some(rt) = runtime() else { return };
    // Preprocess buckets: execute with a zero image and check shape.
    for (src, out) in [(96usize, 32usize), (256, 32), (96, 64), (256, 64)] {
        let exe = rt.preprocess(src, out).unwrap().get().unwrap();
        let raw = vec![0u8; src * src * 3];
        let r = dlio::coordinator::workload::run_preprocess(
            &exe, &raw, src, out).unwrap();
        assert_eq!(r.len(), out * out * 3, "bucket {src}->{out}");
    }
    // Train artifacts: run one step at each batch size for micro.
    for batch in [16usize, 32] {
        let mut trainer = Trainer::new(&rt, "micro", batch, 1).unwrap();
        let prof = trainer.profile().clone();
        let mut rng = Rng::new(batch as u64);
        let b = synthetic_batch(&mut rng, prof.input_size, batch,
                                prof.num_classes as u32);
        let loss = trainer.step(&b).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
    }
}

#[test]
fn scalar_literal_roundtrip() {
    // Marshalling sanity for the step counter.
    let l = lit::scalar_f32(12.5);
    assert_eq!(l.to_vec::<f32>().unwrap(), vec![12.5]);
}
