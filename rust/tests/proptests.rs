//! Property-based tests over coordinator invariants.
//!
//! The vendored offline crate set has no `proptest`, so this file uses
//! a minimal in-repo harness: each property runs against many cases
//! generated from a deterministic seed sweep (failures print the
//! offending seed; re-running with that seed reproduces exactly).

use std::collections::BTreeMap;

use dlio::model::ModelState;
use dlio::pipeline::{from_vec, DatasetExt};
use dlio::runtime::meta::{ParamSpec, ProfileMeta};
use dlio::storage::device::{DeviceModel, Dir};
use dlio::storage::page_cache::PageCache;
use dlio::storage::profiles::analytic_throughput;
use dlio::util::json::{to_string, Json};
use dlio::util::Rng;

/// Run `prop` for `cases` deterministic seeds.
fn forall(cases: u64, mut prop: impl FnMut(&mut Rng, u64)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xD110 ^ seed.wrapping_mul(0x9E3779B9));
        prop(&mut rng, seed);
    }
}

// ---------------------------------------------------------------------------
// Pipeline invariants (the paper's §II-A machinery)
// ---------------------------------------------------------------------------

#[test]
fn prop_full_pipeline_loses_and_duplicates_nothing() {
    forall(40, |rng, seed| {
        let n = rng.index(300) + 1;
        let threads = rng.index(8) + 1;
        let batch = rng.index(16) + 1;
        let shuffle_buf = rng.index(n) + 1;
        let prefetch = rng.index(4);
        let items: Vec<u64> = (0..n as u64).collect();
        let ds = from_vec(items.clone())
            .shuffle(shuffle_buf, rng.fork())
            .parallel_map(threads, Ok)
            .ignore_errors()
            .batch(batch, false)
            .prefetch(prefetch);
        let out: Vec<u64> = dlio::pipeline::collect(ds)
            .unwrap()
            .into_iter()
            .flatten()
            .collect();
        let mut sorted = out.clone();
        sorted.sort();
        assert_eq!(sorted, items, "seed {seed}: lost/duplicated elements");
    });
}

#[test]
fn prop_parallel_map_preserves_order_any_thread_count() {
    forall(30, |rng, seed| {
        let n = rng.index(200) + 1;
        let threads = rng.index(12) + 1;
        let items: Vec<u64> = (0..n as u64).collect();
        let ds = from_vec(items.clone())
            .parallel_map(threads, |x| Ok(x * 3));
        let out = dlio::pipeline::collect(ds).unwrap();
        assert_eq!(
            out,
            items.iter().map(|x| x * 3).collect::<Vec<_>>(),
            "seed {seed}"
        );
    });
}

#[test]
fn prop_batch_geometry() {
    forall(50, |rng, seed| {
        let n = rng.index(500);
        let batch = rng.index(32) + 1;
        let drop_rem = rng.next_f64() < 0.5;
        let ds = from_vec((0..n).collect::<Vec<_>>()).batch(batch, drop_rem);
        let out = dlio::pipeline::collect(ds).unwrap();
        let expected_batches =
            if drop_rem { n / batch } else { n.div_ceil(batch) };
        assert_eq!(out.len(), expected_batches, "seed {seed}");
        for (i, b) in out.iter().enumerate() {
            if i + 1 < out.len() || drop_rem {
                assert_eq!(b.len(), batch, "seed {seed} batch {i}");
            } else {
                assert!(b.len() <= batch && !b.is_empty());
            }
        }
        // Flattened content preserved in order (minus a dropped tail).
        let kept = if drop_rem { (n / batch) * batch } else { n };
        let flat: Vec<usize> = out.into_iter().flatten().collect();
        assert_eq!(flat, (0..kept).collect::<Vec<_>>(), "seed {seed}");
    });
}

#[test]
fn prop_shuffle_displacement_bounded_by_buffer() {
    forall(30, |rng, seed| {
        let n = rng.index(300) + 2;
        let buf = rng.index(n) + 1;
        let ds = from_vec((0..n as i64).collect::<Vec<_>>())
            .shuffle(buf, rng.fork());
        let out = dlio::pipeline::collect(ds).unwrap();
        // tf.data reservoir property: element v cannot be emitted
        // before position v - buf.
        for (pos, &v) in out.iter().enumerate() {
            assert!(
                v <= (pos + buf) as i64,
                "seed {seed}: v={v} at pos={pos} buf={buf}"
            );
        }
    });
}

#[test]
fn prop_ignore_errors_keeps_exactly_the_ok_subset() {
    forall(30, |rng, seed| {
        let n = rng.index(200) + 1;
        let fail_mod = rng.index(7) + 2;
        let ds = from_vec((0..n as u64).collect::<Vec<_>>())
            .parallel_map(rng.index(6) + 1, move |x| {
                if x % fail_mod as u64 == 0 {
                    Err(anyhow::anyhow!("x"))
                } else {
                    Ok(x)
                }
            })
            .ignore_errors();
        let out = dlio::pipeline::collect(ds).unwrap();
        let expect: Vec<u64> = (0..n as u64)
            .filter(|x| x % fail_mod as u64 != 0)
            .collect();
        assert_eq!(out, expect, "seed {seed}");
    });
}

// ---------------------------------------------------------------------------
// Storage model invariants
// ---------------------------------------------------------------------------

fn random_model(rng: &mut Rng) -> DeviceModel {
    let mut elevator = vec![(1u32, 1.0f64)];
    let mut k = 1u32;
    let mut g = 1.0f64;
    for _ in 0..rng.index(4) {
        k += 1 + rng.index(4) as u32;
        g += rng.next_f64() * 0.8;
        elevator.push((k, g));
    }
    DeviceModel {
        name: "p".into(),
        read_bw: 1e6 + rng.next_f64() * 2e9,
        write_bw: 1e6 + rng.next_f64() * 1e9,
        read_lat: rng.next_f64() * 0.02,
        write_lat: rng.next_f64() * 0.02,
        channels: rng.index(32) + 1,
        elevator,
        time_scale: 1.0,
        lat_tables: None,
    }
}

#[test]
fn prop_throughput_monotone_in_threads_and_capped() {
    forall(200, |rng, seed| {
        let m = random_model(rng);
        let size = 1024 + rng.next_below(1 << 20);
        let mut prev = 0.0;
        for k in 1..=16u32 {
            let t = analytic_throughput(&m, Dir::Read, size, k);
            assert!(t > 0.0, "seed {seed}");
            assert!(
                t >= prev - 1e-6,
                "seed {seed}: k={k} throughput dropped {prev} -> {t}"
            );
            assert!(t <= m.read_bw + 1e-6, "seed {seed}: exceeds cap");
            prev = t;
        }
    });
}

#[test]
fn prop_elevator_gain_monotone_and_clamped() {
    forall(200, |rng, seed| {
        let m = random_model(rng);
        let mut prev = 0.0;
        for k in 1..=64u32 {
            let g = m.elevator_gain(k);
            assert!(g >= prev - 1e-9, "seed {seed}: gain dropped at {k}");
            prev = g;
        }
        let last = m.elevator.last().unwrap().1;
        assert!((m.elevator_gain(1000) - last).abs() < 1e-9, "seed {seed}");
    });
}

#[test]
fn prop_bigger_requests_never_slower_throughput() {
    // Amortizing latency: per-byte cost must not increase with size.
    forall(100, |rng, seed| {
        let m = random_model(rng);
        let k = rng.index(8) as u32 + 1;
        let s1 = 1024 + rng.next_below(1 << 18);
        let s2 = s1 * 2;
        let t1 = analytic_throughput(&m, Dir::Write, s1, k);
        let t2 = analytic_throughput(&m, Dir::Write, s2, k);
        assert!(t2 >= t1 - 1e-6, "seed {seed}: {t1} -> {t2}");
    });
}

#[test]
fn prop_page_cache_resident_never_exceeds_capacity() {
    forall(60, |rng, seed| {
        let cap = 1 + rng.next_below(10_000);
        let cache = PageCache::new(cap);
        for i in 0..200 {
            let path = format!("f{}", rng.index(40));
            let size = 1 + rng.next_below(cap * 2);
            cache.access(&path, size);
            assert!(
                cache.resident_bytes() <= cap,
                "seed {seed} step {i}: resident {} > cap {cap}",
                cache.resident_bytes()
            );
        }
        let (h, m) = cache.stats();
        assert_eq!(h + m, 200, "seed {seed}");
    });
}

// ---------------------------------------------------------------------------
// Serialization invariants
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.index(4) } else { rng.index(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.next_f64() * 2e6).round() / 8.0),
        3 => {
            let n = rng.index(12);
            Json::Str(
                (0..n)
                    .map(|_| {
                        char::from_u32(32 + rng.next_below(500) as u32)
                            .unwrap_or('x')
                    })
                    .collect(),
            )
        }
        4 => Json::Arr(
            (0..rng.index(5))
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..rng.index(5))
                .map(|i| {
                    (format!("k{i}"), random_json(rng, depth - 1))
                })
                .collect::<BTreeMap<_, _>>(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    forall(300, |rng, seed| {
        let v = random_json(rng, 3);
        let text = to_string(&v);
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        assert_eq!(back, v, "seed {seed}: {text}");
    });
}

fn random_profile(rng: &mut Rng) -> ProfileMeta {
    let n = rng.index(6) + 1;
    let params: Vec<ParamSpec> = (0..n)
        .map(|i| {
            let dims = rng.index(3) + 1;
            let shape: Vec<usize> =
                (0..dims).map(|_| rng.index(6) + 1).collect();
            ParamSpec {
                name: if i % 2 == 0 {
                    format!("l{i}/kernel")
                } else {
                    format!("l{i}/bias")
                },
                shape,
            }
        })
        .collect();
    let num_params = params.iter().map(|p| p.num_elements()).sum();
    ProfileMeta {
        name: "p".into(),
        input_size: 8,
        num_classes: 4,
        num_params,
        params,
    }
}

#[test]
fn prop_model_state_bytes_roundtrip() {
    forall(80, |rng, seed| {
        let profile = random_profile(rng);
        let mut state = ModelState::init(&profile, rng.next_u64());
        state.step = rng.index(10_000) as f32;
        // Perturb moments.
        if !state.m.is_empty() && !state.m[0].is_empty() {
            state.m[0][0] = rng.next_f32();
            state.v[0][0] = rng.next_f32();
        }
        let bytes = state.to_bytes();
        assert_eq!(bytes.len() as u64, state.data_bytes(), "seed {seed}");
        let back = ModelState::from_bytes(&profile, &bytes).unwrap();
        assert_eq!(back.params, state.params, "seed {seed}");
        assert_eq!(back.m, state.m, "seed {seed}");
        assert_eq!(back.v, state.v, "seed {seed}");
        assert_eq!(back.step, state.step, "seed {seed}");
    });
}

#[test]
fn prop_manifest_text_roundtrip() {
    forall(60, |rng, seed| {
        let n = rng.index(40);
        let m = dlio::data::Manifest {
            samples: (0..n)
                .map(|i| dlio::data::Sample {
                    path: dlio::storage::SimPath::new(
                        "ssd",
                        format!("c/{i:05}.simg"),
                    ),
                    label: rng.next_below(102) as u32,
                })
                .collect(),
            num_classes: 102,
            src_size: 96,
        };
        let back =
            dlio::data::Manifest::from_text(&m.to_text()).unwrap();
        assert_eq!(back.samples, m.samples, "seed {seed}");
    });
}

#[test]
fn prop_simg_roundtrip_random_geometry() {
    forall(40, |rng, seed| {
        let w = rng.index(48) as u32 + 1;
        let h = rng.index(48) as u32 + 1;
        let label = rng.next_below(1000) as u32;
        let mut pixels = vec![0u8; (w * h * 3) as usize];
        rng.fill_bytes(&mut pixels);
        let img = dlio::data::Image {
            width: w,
            height: h,
            channels: 3,
            label,
            pixels,
        };
        let target = if rng.next_f64() < 0.5 {
            Some(rng.index(100_000) + 32)
        } else {
            None
        };
        let bytes =
            dlio::data::encode(&img, target, rng.next_u64()).unwrap();
        if let Some(t) = target {
            assert!(bytes.len() >= t.min(bytes.len()), "seed {seed}");
        }
        let back = dlio::data::decode(&bytes).unwrap();
        assert_eq!(back, img, "seed {seed}");
    });
}
