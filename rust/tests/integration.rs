//! Cross-module integration tests: sim + corpus + pipeline +
//! checkpointing together (no PJRT needed except where noted).

use std::sync::Arc;

use dlio::checkpoint::{BurstBuffer, Saver};
use dlio::config::Testbed;
use dlio::coordinator::fixtures::{ensure_corpus, make_sim};
use dlio::data::{format, CorpusSpec};
use dlio::model::ModelState;
use dlio::pipeline::{from_manifest, DatasetExt};
use dlio::runtime::meta::{ParamSpec, ProfileMeta};
use dlio::storage::{SimPath, StorageSim};
use dlio::trace::Dstat;
use dlio::util::Rng;

/// Pacing-sensitive tests hold this lock so they never run
/// concurrently with each other (cargo runs tests in parallel;
/// concurrent sleeps + real I/O skew wall-clock assertions).
static PACING: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn pacing_lock() -> std::sync::MutexGuard<'static, ()> {
    PACING.lock().unwrap_or_else(|e| e.into_inner())
}

/// Wall-clock assertions can be perturbed by sibling tests competing
/// for CPU; retry the measurement a few times before declaring failure.
fn retry_timing(attempts: usize, mut f: impl FnMut() -> Result<(), String>) {
    let mut last = String::new();
    for i in 0..attempts {
        match f() {
            Ok(()) => return,
            Err(e) => {
                eprintln!("timing attempt {}/{} failed: {e}", i + 1, attempts);
                last = e;
                std::thread::sleep(std::time::Duration::from_millis(200));
            }
        }
    }
    panic!("timing property failed after {attempts} attempts: {last}");
}

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    // tmpfs when available: the sim credits real I/O time against the
    // modelled pacing, so backing storage must be fast.
    let base = if std::path::Path::new("/dev/shm").is_dir() {
        std::path::PathBuf::from("/dev/shm")
    } else {
        std::env::temp_dir()
    };
    base.join(format!("dlio-int-{tag}-{}", std::process::id()))
}

fn fast_testbed(tag: &str) -> Testbed {
    let dir = scratch_dir(tag);
    let _ = std::fs::remove_dir_all(&dir);
    // Paper devices, hugely accelerated so tests run in ms while
    // preserving every ratio.
    let mut tb = Testbed::paper(2000.0);
    tb.workdir = dir.to_string_lossy().into_owned();
    tb
}

/// Testbed at a moderate speed-up: modelled service times stay well
/// above OS sleep resolution so pacing-sensitive assertions hold.
fn paced_testbed(tag: &str, time_scale: f64) -> Testbed {
    let dir = scratch_dir(tag);
    let _ = std::fs::remove_dir_all(&dir);
    let mut tb = Testbed::paper(time_scale);
    tb.workdir = dir.to_string_lossy().into_owned();
    tb
}

fn small_profile() -> ProfileMeta {
    ProfileMeta {
        name: "t".into(),
        input_size: 8,
        num_classes: 4,
        num_params: 4 * 3 + 3,
        params: vec![
            ParamSpec { name: "fc1/kernel".into(), shape: vec![4, 3] },
            ParamSpec { name: "fc1/bias".into(), shape: vec![3] },
        ],
    }
}

#[test]
fn pipeline_reads_full_corpus_through_sim() {
    let tb = fast_testbed("pipe");
    let sim = make_sim(&tb, None).unwrap();
    let spec = CorpusSpec {
        name: "c".into(),
        num_files: 120,
        num_classes: 7,
        src_size: 16,
        median_bytes: 2048,
        sigma: 0.3,
        corrupt_frac: 0.0,
        seed: 2,
    };
    let m = ensure_corpus(&sim, "ssd", &spec).unwrap();
    let sim2 = Arc::clone(&sim);
    let ds = from_manifest(&m)
        .shuffle(m.len(), Rng::new(1))
        .parallel_map(4, move |s| {
            let bytes = sim2.read(&s.path)?;
            let img = format::decode(&bytes)?;
            anyhow::ensure!(img.label == s.label, "label mismatch");
            Ok(img.label)
        })
        .ignore_errors()
        .batch(16, false)
        .prefetch(2);
    let batches = dlio::pipeline::collect(ds).unwrap();
    let total: usize = batches.iter().map(Vec::len).sum();
    assert_eq!(total, 120);
    assert_eq!(batches.len(), 8); // 7 full + partial 8
}

#[test]
fn corrupt_files_are_dropped_not_fatal() {
    let tb = fast_testbed("corrupt");
    let sim = make_sim(&tb, None).unwrap();
    let spec = CorpusSpec {
        name: "c".into(),
        num_files: 80,
        num_classes: 4,
        src_size: 16,
        median_bytes: 2048,
        sigma: 0.2,
        corrupt_frac: 0.25,
        seed: 3,
    };
    let m = ensure_corpus(&sim, "ssd", &spec).unwrap();
    let sim2 = Arc::clone(&sim);
    let ds = from_manifest(&m)
        .parallel_map(4, move |s| {
            let bytes = sim2.read(&s.path)?;
            format::decode(&bytes).map(|i| i.label)
        })
        .ignore_errors();
    let counter = ds.dropped_counter();
    let out = dlio::pipeline::collect(ds).unwrap();
    let dropped = counter.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(out.len() + dropped as usize, 80);
    assert!(dropped > 5, "dropped={dropped}");
}

#[test]
fn thread_scaling_shapes_hold_end_to_end() {
    // Fig. 4's shape, measured through the real pipeline + device sim:
    // HDD scales sub-linearly and flattens; Lustre scales near-linearly.
    // Scale 5: lustre per-op latency stays ~0.4 ms, well above sleep
    // jitter, so the near-linear RPC-bound scaling is measurable.
    let _serial = pacing_lock();
    let tb = paced_testbed("scaling", 5.0);
    let sim = make_sim(&tb, None).unwrap();
    let spec = CorpusSpec {
        name: "c".into(),
        num_files: 192,
        num_classes: 4,
        src_size: 16,
        median_bytes: 112 * 1024, // paper's median
        sigma: 0.0,
        corrupt_frac: 0.0,
        seed: 4,
    };
    retry_timing(3, || {
        let mut bw = std::collections::HashMap::new();
        for dev in ["hdd", "lustre"] {
            let m = ensure_corpus(&sim, dev, &spec).unwrap();
            for threads in [1usize, 8] {
                let sim2 = Arc::clone(&sim);
                let ds = from_manifest(&m)
                    .parallel_map(threads, move |s| {
                        sim2.read(&s.path).map(|b| b.len() as u64)
                    })
                    .batch(64, false);
                let t0 = std::time::Instant::now();
                let batches = dlio::pipeline::collect(ds).unwrap();
                let total: u64 = batches.iter().flatten().sum();
                bw.insert((dev, threads),
                          total as f64 / t0.elapsed().as_secs_f64());
            }
        }
        let hdd_scale = bw[&("hdd", 8)] / bw[&("hdd", 1)];
        let lustre_scale = bw[&("lustre", 8)] / bw[&("lustre", 1)];
        if !(hdd_scale > 1.3 && hdd_scale < 4.0) {
            return Err(format!("hdd {hdd_scale}"));
        }
        if lustre_scale <= 4.0 {
            return Err(format!("lustre {lustre_scale}"));
        }
        if lustre_scale <= hdd_scale {
            return Err("lustre !> hdd".into());
        }
        Ok(())
    });
}

#[test]
fn saver_writes_triple_syncs_and_retains_five() {
    let tb = fast_testbed("saver");
    let sim = make_sim(&tb, None).unwrap();
    let profile = small_profile();
    let state = ModelState::init(&profile, 1);
    let mut saver =
        Saver::new(Arc::clone(&sim), profile.clone(), "ssd", "ck/m", 5);
    for step in 1..=8u64 {
        let h = saver.save(&state, step * 10).unwrap();
        for f in h.files() {
            assert!(sim.exists(&f), "{f} missing");
        }
    }
    // Keep-5: steps 40..80 retained, 10..30 cleaned up.
    let retained: Vec<u64> =
        saver.retained().iter().map(|h| h.step).collect();
    assert_eq!(retained, vec![40, 50, 60, 70, 80]);
    assert!(!sim.exists(&SimPath::new("ssd", "ck/m-10.data")));
    // Latest discovery matches.
    let latest = Saver::latest(&sim, "ssd", "ck/m").unwrap().unwrap();
    assert_eq!(latest.step, 80);
}

#[test]
fn checkpoint_restore_roundtrip_through_sim() {
    let tb = fast_testbed("restore");
    let sim = make_sim(&tb, None).unwrap();
    let profile = small_profile();
    let mut state = ModelState::init(&profile, 9);
    state.step = 30.0;
    state.m[0][2] = 0.5;
    let mut saver =
        Saver::new(Arc::clone(&sim), profile.clone(), "optane", "ck/m", 5);
    let h = saver.save(&state, 30).unwrap();
    let back = Saver::restore(&sim, &profile, &h).unwrap();
    assert_eq!(back.params, state.params);
    assert_eq!(back.m, state.m);
    assert_eq!(back.step, 30.0);
}

#[test]
fn restore_rejects_wrong_profile() {
    let tb = fast_testbed("wrongprof");
    let sim = make_sim(&tb, None).unwrap();
    let profile = small_profile();
    let state = ModelState::init(&profile, 1);
    let mut saver =
        Saver::new(Arc::clone(&sim), profile.clone(), "ssd", "ck/m", 5);
    let h = saver.save(&state, 1).unwrap();
    let mut other = profile.clone();
    other.name = "other".into();
    assert!(Saver::restore(&sim, &other, &h).is_err());
}

#[test]
fn burst_buffer_drains_to_slow_device_and_restores_from_both() {
    let tb = fast_testbed("bb");
    let sim = make_sim(&tb, None).unwrap();
    let profile = small_profile();
    let state = ModelState::init(&profile, 5);
    let mut bb = BurstBuffer::new(
        Arc::clone(&sim), profile.clone(), "optane", "hdd", "ck/m", 5)
        .unwrap();
    let h1 = bb.save(&state, 20).unwrap();
    let h2 = bb.save(&state, 40).unwrap();
    assert_eq!(h1.device, "optane");
    bb.wait_drained();
    assert_eq!(bb.drained_count(), 2);
    assert_eq!(bb.drain_error_count(), 0);
    // Slow copies exist and restore identically.
    let slow = dlio::checkpoint::CheckpointHandle {
        device: "hdd".into(),
        prefix: "ck/m".into(),
        step: 40,
    };
    let from_fast = Saver::restore(&sim, &profile, &h2).unwrap();
    let from_slow = Saver::restore(&sim, &profile, &slow).unwrap();
    assert_eq!(from_fast.params, from_slow.params);
}

#[test]
fn burst_buffer_save_latency_beats_direct_hdd() {
    // The paper's headline mechanism: staging to fast NVM returns much
    // faster than checkpointing straight to slow storage.  Custom
    // device models (20 vs 600 MB/s writes, no time scaling) keep the
    // modelled service times far above real-I/O noise on the backing
    // tmpfs, so the wall-clock assertion is robust.
    let _serial = pacing_lock();
    let dir = scratch_dir("bblat");
    let _ = std::fs::remove_dir_all(&dir);
    let mk = |name: &str, write_bw: f64| dlio::storage::DeviceModel {
        name: name.into(),
        read_bw: 1e9,
        write_bw,
        read_lat: 0.0,
        write_lat: 0.0,
        channels: 4,
        elevator: vec![(1, 1.0)],
        time_scale: 1.0,
        lat_tables: None,
    };
    let sim = Arc::new(
        StorageSim::cold(dir, vec![mk("slow", 20e6), mk("fast", 600e6)])
            .unwrap(),
    );
    let profile = ProfileMeta {
        name: "big".into(),
        input_size: 8,
        num_classes: 4,
        num_params: 700_000,
        params: vec![ParamSpec {
            name: "fc1/kernel".into(),
            shape: vec![700, 1000],
        }],
    };
    let state = ModelState::init(&profile, 1); // ~8.4 MB triple

    let mut direct = Saver::new(
        Arc::clone(&sim), profile.clone(), "slow", "d/m", 5);
    direct.sync_on_save = false; // isolate device pacing
    let t0 = std::time::Instant::now();
    direct.save(&state, 1).unwrap();
    let t_slow = t0.elapsed().as_secs_f64();

    let mut bb = BurstBuffer::new(
        Arc::clone(&sim), profile.clone(), "fast", "slow", "b/m", 5)
        .unwrap();
    bb.saver_mut().sync_on_save = false;
    let t0 = std::time::Instant::now();
    bb.save(&state, 1).unwrap();
    let t_bb = t0.elapsed().as_secs_f64();
    bb.wait_drained();
    assert_eq!(bb.drained_count(), 1);

    // Modelled: 8.4 MB at 20 MB/s = 420 ms vs 600 MB/s = 14 ms.
    assert!(t_slow > 0.25, "direct save suspiciously fast: {t_slow}");
    assert!(t_bb < t_slow / 2.5, "bb {t_bb:.4}s vs slow {t_slow:.4}s");
}

#[test]
fn dstat_trace_captures_checkpoint_writes_per_device() {
    let tb = fast_testbed("trace");
    let tracer = Arc::new(Dstat::new(10.0));
    let sim = Arc::new(
        StorageSim::new(
            tb.workdir.clone(),
            tb.devices.clone(),
            0,
            tracer.clone(),
        )
        .unwrap(),
    );
    let profile = small_profile();
    let state = ModelState::init(&profile, 1);
    let mut bb = BurstBuffer::new(
        Arc::clone(&sim), profile.clone(), "optane", "hdd", "ck/m", 5)
        .unwrap();
    bb.save(&state, 1).unwrap();
    bb.wait_drained();
    drop(bb);
    let (opt_r, opt_w) = tracer.totals("optane");
    let (_hdd_r, hdd_w) = tracer.totals("hdd");
    assert!(opt_w > 0, "optane writes traced");
    assert!(opt_r > 0, "drain reads from optane traced");
    assert!(hdd_w > 0, "drain writes to hdd traced");
    assert_eq!(opt_w, hdd_w, "full triple drained");
    // CSV renders with both devices.
    let csv = tracer.to_csv();
    assert!(csv.contains("optane") && csv.contains("hdd"));
}

#[test]
fn page_cache_warm_epoch_avoids_device_traffic() {
    // §IV: "after the first epoch all samples will be seen by the OS
    // and potentially cached" — reproduce both regimes.
    let dir = scratch_dir("cache");
    let _ = std::fs::remove_dir_all(&dir);
    let tracer = Arc::new(Dstat::new(10.0));
    let mut tb = Testbed::paper(2000.0);
    tb.workdir = dir.to_string_lossy().into_owned();
    let sim = Arc::new(
        StorageSim::new(tb.workdir.clone(), tb.devices.clone(),
                        1 << 30, tracer.clone()).unwrap(),
    );
    let spec = CorpusSpec {
        name: "c".into(),
        num_files: 40,
        num_classes: 4,
        src_size: 16,
        median_bytes: 4096,
        sigma: 0.0,
        corrupt_frac: 0.0,
        seed: 5,
    };
    let m = ensure_corpus(&sim, "ssd", &spec).unwrap();
    let read_all = || {
        for s in &m.samples {
            sim.read(&s.path).unwrap();
        }
    };
    read_all(); // epoch 1: cold
    let (r1, _) = tracer.totals("ssd");
    read_all(); // epoch 2: warm
    let (r2, _) = tracer.totals("ssd");
    assert!(r1 > 0);
    assert_eq!(r2, r1, "warm epoch must add no device reads");
    sim.drop_caches();
    read_all(); // epoch 3: dropped caches -> cold again
    let (r3, _) = tracer.totals("ssd");
    assert_eq!(r3, 2 * r1);
}

#[test]
fn ior_table1_ordering_holds() {
    let _serial = pacing_lock();
    let tb = paced_testbed("ior", 4.0);
    let sim = make_sim(&tb, None).unwrap();
    let cfg = dlio::storage::ior::IorConfig {
        file_bytes: 16_000_000,
        reps: 3,
    };
    retry_timing(3, || {
        let rows = dlio::storage::ior::run_all(&sim, &cfg).unwrap();
        let get = |n: &str| {
            rows.iter().find(|r| r.device == n).unwrap().clone()
        };
        // Table I ordering on reads.  (lustre vs optane differ by only
        // ~20% in the table — below live-pacing resolution — so we
        // assert the robust orderings.)
        let checks = [
            (get("lustre").max_read_mbs > get("ssd").max_read_mbs,
             "lustre read !> ssd"),
            (get("optane").max_read_mbs > get("ssd").max_read_mbs,
             "optane read !> ssd"),
            (get("ssd").max_read_mbs > get("hdd").max_read_mbs,
             "ssd read !> hdd"),
            (get("ssd").max_write_mbs > get("hdd").max_write_mbs,
             "ssd write !> hdd"),
        ];
        for (ok, msg) in checks {
            if !ok {
                return Err(msg.into());
            }
        }
        Ok(())
    });
}

#[test]
fn device_write_ordering_via_transfer_times() {
    // Direct check of Fig. 9's mechanism at the device level.
    // Low speed-up + large payload: modelled write times (optane 62ms
    // / ssd 164ms / hdd 240ms at 1.5x) dominate real-backing noise.
    let _serial = pacing_lock();
    let tb = paced_testbed("wr", 1.5);
    let sim = make_sim(&tb, None).unwrap();
    let data = vec![0u8; 48_000_000];
    retry_timing(3, || {
        let mut times = std::collections::HashMap::new();
        for dev in ["hdd", "ssd", "optane"] {
            let p = SimPath::new(dev, "x.bin");
            let t0 = std::time::Instant::now();
            sim.write(&p, &data).unwrap();
            times.insert(dev, t0.elapsed().as_secs_f64());
        }
        if times["optane"] >= times["ssd"] {
            return Err(format!("optane {} !< ssd {}",
                               times["optane"], times["ssd"]));
        }
        if times["ssd"] >= times["hdd"] {
            return Err(format!("ssd {} !< hdd {}",
                               times["ssd"], times["hdd"]));
        }
        Ok(())
    });
}

#[test]
fn elevator_gain_observable_under_concurrency() {
    // HDD small-read throughput with 8 streams must beat 1 stream by
    // roughly the paper's 2.3x (elevator model), measured live.
    let _serial = pacing_lock();
    let tb = paced_testbed("elev", 20.0);
    let sim = make_sim(&tb, None).unwrap();
    let spec = CorpusSpec {
        name: "c".into(),
        num_files: 160,
        num_classes: 2,
        src_size: 16,
        median_bytes: 112 * 1024,
        sigma: 0.0,
        corrupt_frac: 0.0,
        seed: 6,
    };
    let m = ensure_corpus(&sim, "hdd", &spec).unwrap();
    let run = |threads: usize| {
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let sim = Arc::clone(&sim);
                let m = m.clone();
                std::thread::spawn(move || {
                    for s in m.samples.iter().skip(t).step_by(threads) {
                        sim.read(&s.path).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        m.len() as f64 / t0.elapsed().as_secs_f64()
    };
    retry_timing(3, || {
        let r1 = run(1);
        let r8 = run(8);
        let scale = r8 / r1;
        if scale > 1.5 && scale < 3.5 {
            Ok(())
        } else {
            Err(format!("hdd 8-thread scale {scale}"))
        }
    });
}

#[test]
fn trace_dir_read_write_separation() {
    let tb = fast_testbed("dirsep");
    let tracer = Arc::new(Dstat::new(10.0));
    let sim = Arc::new(StorageSim::new(
        tb.workdir.clone(), tb.devices.clone(), 0, tracer.clone())
        .unwrap());
    sim.write(&SimPath::new("ssd", "a.bin"), &[0u8; 1000]).unwrap();
    sim.drop_caches(); // written data is page-cached; force device read
    sim.read(&SimPath::new("ssd", "a.bin")).unwrap();
    let rows = tracer.rows();
    let ssd: Vec<_> = rows.iter().filter(|r| r.device == "ssd").collect();
    let reads: u64 = ssd.iter().map(|r| r.read_bytes).sum();
    let writes: u64 = ssd.iter().map(|r| r.write_bytes).sum();
    assert_eq!(reads, 1000);
    assert_eq!(writes, 1000);
}
