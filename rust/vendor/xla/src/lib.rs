//! The `xla` binding surface `dlio::runtime` compiles against.
//!
//! Two halves:
//!
//! * **Host-side literals** ([`Literal`], [`ElementType`]) are fully
//!   functional — the marshalling layer and its tests run everywhere.
//! * **Device paths** ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`HloModuleProto`]) are stubs that return a clear error on hosts
//!   without the vendored XLA/PJRT toolchain.  Every caller already
//!   handles runtime-unavailable gracefully (the e2e suite skips when
//!   artifacts are missing; benches print "skipping PJRT rows"), so
//!   the offline build runs the full non-PJRT test suite.
//!
//! Swapping this crate for the real PJRT binding (same API) re-enables
//! kernel execution without touching `dlio` itself.

use std::fmt;
use std::path::Path;

/// Binding-level error (Display-able; `dlio` wraps it in anyhow).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime not available in this build \
         (offline xla stub; vendor the XLA toolchain to enable)"
    ))
}

/// Element dtypes used by the dlio artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
}

impl ElementType {
    fn byte_width(self) -> usize {
        match self {
            ElementType::F32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Sized {
    const TY: ElementType;
    fn read_le(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn read_le(bytes: &[u8]) -> Vec<f32> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn read_le(bytes: &[u8]) -> Vec<u8> {
        bytes.to_vec()
    }
}

/// A host-side tensor: dtype + dims + packed little-endian data.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, Error> {
        let want = dims.iter().product::<usize>() * ty.byte_width();
        if data.len() != want {
            return Err(Error(format!(
                "literal data {} bytes does not match shape {dims:?} \
                 ({want} bytes)",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            ty: ElementType::F32,
            dims: Vec::new(),
            data: v.to_le_bytes().to_vec(),
        }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Read the packed data back as `T` values.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(T::read_le(&self.data))
    }

    /// Decompose a tuple literal (only produced by executions, which
    /// the stub cannot perform).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("to_tuple"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!("parse {}", path.display())))
    }
}

/// An XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("to_literal_sync"))
    }
}

/// PJRT client (stub: construction reports the missing toolchain).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute"))
    }

    pub fn execute_b<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vals);
        assert_eq!(l.dims(), &[3]);
    }

    #[test]
    fn literal_rejects_shape_mismatch() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 4],
        )
        .is_err());
    }

    #[test]
    fn scalar_reads_back() {
        assert_eq!(Literal::scalar(12.5).to_vec::<f32>().unwrap(), vec![12.5]);
    }

    #[test]
    fn device_paths_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/x")).is_err());
    }
}
