//! Minimal `libc` surface for the offline build: only the symbols the
//! storage sim needs (`syncfs`, used after checkpoint saves, §III-C).
//! Links directly against the system C library.

#![allow(non_camel_case_types)]

pub type c_int = i32;

extern "C" {
    /// Flush the filesystem containing the file referred to by `fd`.
    pub fn syncfs(fd: c_int) -> c_int;
}
