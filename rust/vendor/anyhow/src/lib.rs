//! Offline mini-reimplementation of the `anyhow` surface this project
//! uses (see `dlio::util` module docs: the vendored crate set covers
//! only the xla closure, so ecosystem crates are re-implemented at the
//! scale needed).
//!
//! Covered: [`Error`], [`Result`], the [`Context`] extension trait on
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! An [`Error`] is a context chain of rendered messages: `{e}` prints
//! the outermost message, `{e:#}` the full `outer: ...: root` chain,
//! exactly like the real crate.  Not covered (unused here): downcasts,
//! backtraces, `source()` typing.

use std::fmt;

/// A rendered error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands
    /// to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes the blanket conversions
// below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;
    use std::fmt::Display;

    /// Private extension implemented for both [`Error`] and std errors
    /// so [`super::Context`] has a single blanket impl (the real
    /// anyhow's `ext::StdError` pattern).
    pub trait IntoChain {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl IntoChain for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }

    impl<E> IntoChain for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }
}

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::IntoChain> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading x").context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: reading x: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x != 1, "one is bad: {x}");
            if x == 2 {
                bail!("two is worse");
            }
            if x == 3 {
                return Err(anyhow!("three"));
            }
            Ok(x)
        }
        assert_eq!(format!("{}", f(1).unwrap_err()), "one is bad: 1");
        assert_eq!(format!("{}", f(2).unwrap_err()), "two is worse");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three");
        assert_eq!(f(4).unwrap(), 4);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
