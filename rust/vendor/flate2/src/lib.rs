//! Offline `flate2` API shim.
//!
//! The SIMG container compresses pixel payloads and decodes them with
//! *this same crate* — the stream format is internal to the repo, so
//! an RFC 1951 bitstream is not required, only (a) exact round-trips,
//! (b) real compression on structured pixel data, and (c) real
//! entropy-decoding CPU work per byte (the JPEG-Huffman-stage stand-in
//! the paper's decode cost models).
//!
//! This shim therefore implements a self-contained **stride-3 delta
//! filter + order-0 canonical Huffman codec** (PNG's Sub predictor
//! feeding the entropy core of DEFLATE, minus LZ77).  The delta makes
//! smooth RGB pixel fields low-entropy exactly like an image codec's
//! predictor stage:
//!
//! ```text
//! [0..4)    original length N, u32 LE  (0 = empty stream, nothing else)
//! [4..260)  canonical code length per delta byte value (u8, 0 = unused)
//! [260..]   bitstream: each symbol's code emitted MSB-first into
//!           LSB-first-filled bytes (RFC 1951 bit order)
//! ```
//!
//! Swapping in the real `flate2` crate (same `DeflateEncoder` /
//! `DeflateDecoder` / `Compression` surface) only changes the byte
//! format, which nothing outside this crate inspects.

use std::io::{self, Read, Write};

/// Compression level knob (accepted for API compatibility; the
/// canonical-Huffman codec has a single operating point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub fn fast() -> Compression {
        Compression(1)
    }

    pub fn best() -> Compression {
        Compression(9)
    }

    pub fn none() -> Compression {
        Compression(0)
    }

    pub fn level(&self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

const MAX_BITS: usize = 64;
/// Decoded-size guard against corrupt headers.
const MAX_DECODED: u32 = 1 << 30;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

// ---------------------------------------------------------------------------
// Huffman table construction
// ---------------------------------------------------------------------------

/// Code length per symbol for an order-0 Huffman code over `freq`.
fn build_lengths(freq: &[u64; 256]) -> [u8; 256] {
    let mut lens = [0u8; 256];
    let live: Vec<usize> = (0..256).filter(|&s| freq[s] > 0).collect();
    match live.len() {
        0 => return lens,
        1 => {
            lens[live[0]] = 1;
            return lens;
        }
        _ => {}
    }

    // Node arena: leaves first, then internal nodes.
    struct Node {
        freq: u64,
        left: usize,
        right: usize, // usize::MAX marks a leaf
        parent: usize,
    }
    let mut nodes: Vec<Node> = live
        .iter()
        .map(|&s| Node {
            freq: freq[s],
            left: usize::MAX,
            right: usize::MAX,
            parent: usize::MAX,
        })
        .collect();

    // O(n^2) two-smallest merge: 256 symbols max, negligible cost.
    let mut active: Vec<usize> = (0..nodes.len()).collect();
    while active.len() > 1 {
        let mut a = 0usize; // index into `active` of smallest
        let mut b = 1usize; // second smallest
        if nodes[active[b]].freq < nodes[active[a]].freq {
            std::mem::swap(&mut a, &mut b);
        }
        for i in 2..active.len() {
            let f = nodes[active[i]].freq;
            if f < nodes[active[a]].freq {
                b = a;
                a = i;
            } else if f < nodes[active[b]].freq {
                b = i;
            }
        }
        let (ia, ib) = (active[a], active[b]);
        let merged = Node {
            freq: nodes[ia].freq + nodes[ib].freq,
            left: ia,
            right: ib,
            parent: usize::MAX,
        };
        let mi = nodes.len();
        nodes.push(merged);
        nodes[ia].parent = mi;
        nodes[ib].parent = mi;
        // Remove the two (larger active-index first) and add merged.
        let (hi, lo) = if a > b { (a, b) } else { (b, a) };
        active.swap_remove(hi);
        active.swap_remove(lo);
        active.push(mi);
    }

    // Depth of each leaf = walk to root.
    for (leaf, &sym) in live.iter().enumerate() {
        let mut depth = 0u8;
        let mut n = leaf;
        while nodes[n].parent != usize::MAX {
            n = nodes[n].parent;
            depth += 1;
        }
        lens[sym] = depth;
    }
    lens
}

/// RFC 1951 canonical code assignment from lengths.
fn assign_codes(lens: &[u8; 256]) -> ([u64; 256], [u32; MAX_BITS + 1]) {
    let mut bl_count = [0u32; MAX_BITS + 1];
    for &l in lens.iter() {
        bl_count[l as usize] += 1;
    }
    bl_count[0] = 0;
    let mut next_code = [0u64; MAX_BITS + 2];
    let mut code = 0u64;
    for bits in 1..=MAX_BITS {
        code = (code + bl_count[bits - 1] as u64) << 1;
        next_code[bits] = code;
    }
    let mut codes = [0u64; 256];
    for sym in 0..256 {
        let l = lens[sym] as usize;
        if l > 0 {
            codes[sym] = next_code[l];
            next_code[l] += 1;
        }
    }
    (codes, bl_count)
}

/// Reject oversubscribed (garbage) length tables.
fn check_kraft(bl_count: &[u32; MAX_BITS + 1]) -> io::Result<()> {
    let mut left: i128 = 1;
    for &count in bl_count.iter().skip(1) {
        left <<= 1;
        left -= count as i128;
        if left < 0 {
            return Err(bad("oversubscribed code length table"));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Bit I/O (RFC 1951 order: bytes filled LSB-first)
// ---------------------------------------------------------------------------

struct BitWriter {
    out: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    fn new(out: Vec<u8>) -> BitWriter {
        BitWriter { out, cur: 0, nbits: 0 }
    }

    fn push_bit(&mut self, bit: u8) {
        self.cur |= bit << self.nbits;
        self.nbits += 1;
        if self.nbits == 8 {
            self.out.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Emit `len` bits of `code`, MSB first.
    fn push_code(&mut self, code: u64, len: u8) {
        for i in (0..len).rev() {
            self.push_bit(((code >> i) & 1) as u8);
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push(self.cur);
        }
        self.out
    }
}

struct BitReader<'a> {
    data: &'a [u8],
    byte: usize,
    bit: u8,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> BitReader<'a> {
        BitReader { data, byte: 0, bit: 0 }
    }

    fn read_bit(&mut self) -> io::Result<u64> {
        let b = *self
            .data
            .get(self.byte)
            .ok_or_else(|| bad("bitstream exhausted"))?;
        let v = (b >> self.bit) & 1;
        self.bit += 1;
        if self.bit == 8 {
            self.bit = 0;
            self.byte += 1;
        }
        Ok(v as u64)
    }
}

// ---------------------------------------------------------------------------
// Whole-buffer codec
// ---------------------------------------------------------------------------

/// RGB channel stride for the delta predictor (SIMG payloads are
/// interleaved 3-channel pixels; for other data the transform is still
/// a bijection, merely less compressive).
const DELTA_STRIDE: usize = 3;

fn delta_filter(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len());
    for (i, &b) in input.iter().enumerate() {
        if i < DELTA_STRIDE {
            out.push(b);
        } else {
            out.push(b.wrapping_sub(input[i - DELTA_STRIDE]));
        }
    }
    out
}

fn delta_unfilter(data: &mut [u8]) {
    for i in DELTA_STRIDE..data.len() {
        data[i] = data[i].wrapping_add(data[i - DELTA_STRIDE]);
    }
}

fn compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 261);
    out.extend_from_slice(&(input.len() as u32).to_le_bytes());
    if input.is_empty() {
        return out;
    }
    let deltas = delta_filter(input);
    let mut freq = [0u64; 256];
    for &b in &deltas {
        freq[b as usize] += 1;
    }
    let lens = build_lengths(&freq);
    let (codes, _) = assign_codes(&lens);
    out.extend_from_slice(&lens);
    let mut bw = BitWriter::new(out);
    for &b in &deltas {
        bw.push_code(codes[b as usize], lens[b as usize]);
    }
    bw.finish()
}

fn decompress(input: &[u8]) -> io::Result<Vec<u8>> {
    if input.len() < 4 {
        return Err(bad("truncated stream header"));
    }
    let n = u32::from_le_bytes([input[0], input[1], input[2], input[3]]);
    if n == 0 {
        return Ok(Vec::new());
    }
    if n > MAX_DECODED {
        return Err(bad("implausible decoded length"));
    }
    if input.len() < 4 + 256 {
        return Err(bad("truncated code length table"));
    }
    let mut lens = [0u8; 256];
    lens.copy_from_slice(&input[4..260]);
    // Symbols sorted by (length, value) — canonical decode order.
    let mut symbols: Vec<u8> = Vec::new();
    let mut bl_count = [0u32; MAX_BITS + 1];
    for &l in lens.iter() {
        if l as usize > MAX_BITS {
            return Err(bad("code length out of range"));
        }
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    check_kraft(&bl_count)?;
    for want in 1..=MAX_BITS {
        for (sym, &l) in lens.iter().enumerate() {
            if l as usize == want {
                symbols.push(sym as u8);
            }
        }
    }
    if symbols.is_empty() {
        return Err(bad("no symbols in code table"));
    }

    // puff-style canonical decoding.
    let mut br = BitReader::new(&input[260..]);
    let mut out = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let mut code: u64 = 0;
        let mut first: u64 = 0;
        let mut index: usize = 0;
        let mut matched = false;
        for len in 1..=MAX_BITS {
            code |= br.read_bit()?;
            let count = bl_count[len] as u64;
            if code < first + count {
                out.push(symbols[index + (code - first) as usize]);
                matched = true;
                break;
            }
            index += count as usize;
            first = (first + count) << 1;
            code <<= 1;
        }
        if !matched {
            return Err(bad("invalid code in bitstream"));
        }
    }
    delta_unfilter(&mut out);
    Ok(out)
}

// ---------------------------------------------------------------------------
// flate2-compatible surface
// ---------------------------------------------------------------------------

pub mod write {
    use super::*;

    /// Buffering encoder: bytes written are compressed on `finish()`,
    /// and the compressed stream is written to the inner writer.
    pub struct DeflateEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> DeflateEncoder<W> {
        pub fn new(inner: W, _level: Compression) -> DeflateEncoder<W> {
            DeflateEncoder { inner, buf: Vec::new() }
        }

        /// Compress, flush to the inner writer, and return it.
        pub fn finish(mut self) -> io::Result<W> {
            let packed = compress(&self.buf);
            self.inner.write_all(&packed)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use super::*;

    /// Decoder over any `Read`: decompresses lazily on first read.
    pub struct DeflateDecoder<R: Read> {
        inner: Option<R>,
        decoded: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> DeflateDecoder<R> {
        pub fn new(inner: R) -> DeflateDecoder<R> {
            DeflateDecoder { inner: Some(inner), decoded: Vec::new(), pos: 0 }
        }

        fn ensure_decoded(&mut self) -> io::Result<()> {
            if let Some(mut r) = self.inner.take() {
                let mut raw = Vec::new();
                r.read_to_end(&mut raw)?;
                self.decoded = decompress(&raw)?;
                self.pos = 0;
            }
            Ok(())
        }
    }

    impl<R: Read> Read for DeflateDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.ensure_decoded()?;
            let left = &self.decoded[self.pos..];
            let n = left.len().min(buf.len());
            buf[..n].copy_from_slice(&left[..n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::read::DeflateDecoder;
    use super::write::DeflateEncoder;
    use super::*;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        let packed = enc.finish().unwrap();
        let mut out = Vec::new();
        DeflateDecoder::new(&packed[..]).read_to_end(&mut out).unwrap();
        out
    }

    #[test]
    fn roundtrips_exactly() {
        for data in [
            &b""[..],
            &b"a"[..],
            &b"aaaaaaaaaab"[..],
            &[0u8, 255, 127, 128, 1, 2, 3, 3, 3][..],
        ] {
            assert_eq!(roundtrip(data), data);
        }
        // Larger structured buffer.
        let big: Vec<u8> = (0..100_000u32).map(|i| (i % 97) as u8).collect();
        assert_eq!(roundtrip(&big), big);
        // Pseudo-random buffer (all 256 symbols).
        let mut x = 0x12345678u32;
        let rnd: Vec<u8> = (0..50_000)
            .map(|_| {
                x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                (x >> 24) as u8
            })
            .collect();
        assert_eq!(roundtrip(&rnd), rnd);
    }

    #[test]
    fn compresses_smooth_pixel_fields() {
        // Gradient-like interleaved RGB (what SIMG payloads look
        // like): the delta filter must push it well below raw size.
        let (w, h) = (96usize, 96usize);
        let mut pixels = Vec::with_capacity(w * h * 3);
        for y in 0..h {
            for x in 0..w {
                for c in 0..3usize {
                    pixels.push(((x + y * 2 + c * 37) % 256) as u8);
                }
            }
        }
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&pixels).unwrap();
        let packed = enc.finish().unwrap();
        assert!(
            packed.len() < pixels.len() / 2,
            "gradient not compressed: {} vs {}",
            packed.len(),
            pixels.len()
        );
        let mut out = Vec::new();
        DeflateDecoder::new(&packed[..]).read_to_end(&mut out).unwrap();
        assert_eq!(out, pixels);
    }

    #[test]
    fn compresses_skewed_data() {
        // Low-entropy input must shrink well below raw size.
        let data: Vec<u8> =
            (0..30_000).map(|i| if i % 10 == 0 { 1u8 } else { 0u8 }).collect();
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&data).unwrap();
        let packed = enc.finish().unwrap();
        assert!(
            packed.len() < data.len() / 2,
            "no compression: {} vs {}",
            packed.len(),
            data.len()
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(decompress(&[1, 2]).is_err());
        // Claims 100 bytes but provides an all-zero length table.
        let mut junk = vec![0u8; 300];
        junk[0] = 100;
        assert!(decompress(&junk).is_err());
        // Oversubscribed table.
        let mut over = vec![0u8; 400];
        over[0] = 10;
        for slot in over.iter_mut().take(260).skip(4) {
            *slot = 1; // 256 codes of length 1
        }
        assert!(decompress(&over).is_err());
    }

    #[test]
    fn truncated_bitstream_errors() {
        let data = vec![7u8; 1000];
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&data).unwrap();
        let packed = enc.finish().unwrap();
        let cut = &packed[..packed.len() - 1];
        // Either fails outright or yields short output — never panics.
        let mut out = Vec::new();
        let res = DeflateDecoder::new(cut).read_to_end(&mut out);
        assert!(res.is_err() || out.len() < data.len());
    }

    #[test]
    fn compression_levels_accepted() {
        assert_eq!(Compression::fast().level(), 1);
        assert_eq!(Compression::best().level(), 9);
        assert_eq!(Compression::default().level(), 6);
        assert_eq!(Compression::new(3).level(), 3);
        assert_eq!(Compression::none().level(), 0);
    }
}
