//! # dlio — Deep-Learning I/O workload characterization in Rust
//!
//! A full reproduction of *"Characterizing Deep-Learning I/O Workloads
//! in TensorFlow"* (Chien et al., PDSW-DISCS 2018) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: a faithful `tf.data`-style
//!   input pipeline (shuffle / parallel map / batch / prefetch, plus an
//!   engine-backed readahead source), a calibrated storage-device
//!   simulator (HDD / SSD / Optane / Lustre) scheduled by a
//!   request-level submission/completion [`IoEngine`](storage::IoEngine),
//!   a `tf.train.Saver`-style checkpointer (overlapped triple writes)
//!   with a burst-buffer staging path, dstat-style tracing, and the
//!   experiment drivers regenerating every table and figure of the
//!   paper.
//! * **L2 (python/compile/model.py)** — AlexNet fwd/bwd + Adam in JAX,
//!   AOT-lowered to HLO text once at build time.
//! * **L1 (python/compile/kernels/)** — the per-image decode/normalize/
//!   resize hot spot as a fused Pallas kernel (matmul-form bilinear).
//!
//! Python never runs at request time: the rust binary loads the
//! `artifacts/*.hlo.txt` via PJRT (`runtime`) and is self-contained.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod bench;
pub mod checkpoint;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod model;
pub mod pipeline;
pub mod runtime;
pub mod storage;
pub mod trace;
pub mod util;
