//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component (shuffle buffers, corpus synthesis,
//! parameter init, device jitter) takes an explicit [`Rng`] so whole
//! experiments replay bit-identically from a single seed — the paper's
//! median-of-six protocol depends on run-to-run comparability.

/// xoshiro256++ generator (public-domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-thread rngs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xa0761d6478bd642f)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; unbiased via rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Log-normal sample with the given median and sigma (of the
    /// underlying normal).  Used for corpus file-size distributions.
    pub fn next_lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.next_normal()).exp()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.index(i + 1);
            slice.swap(i, j);
        }
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn lognormal_median_approx() {
        let mut r = Rng::new(13);
        let n = 20_001;
        let mut xs: Vec<f64> =
            (0..n).map(|_| r.next_lognormal(112_000.0, 0.5)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let med = xs[n / 2];
        assert!((med / 112_000.0 - 1.0).abs() < 0.1, "median={med}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(1);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn fill_bytes_handles_unaligned_len() {
        let mut r = Rng::new(9);
        let mut buf = vec![0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
