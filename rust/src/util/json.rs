//! Minimal JSON codec.
//!
//! Used for two ABI surfaces: parsing `artifacts/model_meta.json`
//! (written by `python/compile/aot.py`) and emitting experiment
//! reports / dstat traces.  Supports the full JSON value model; numbers
//! are kept as f64 (sufficient for shapes and hyper-parameters).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs: decode when followed by
                            // a low surrogate escape.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| {
                                self.err("invalid unicode escape")
                            })?);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Collect a UTF-8 run.
                    let start = self.i;
                    let len = utf8_len(c);
                    if self.i + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.i += len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Serialize a [`Json`] value compactly.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(e, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(e, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for object literals in reporting code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#)
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"adam":{"lr":0.0001},"arr":[1,2.5,"x",true,null]}"#;
        let v = Json::parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn parses_real_meta_shape() {
        let src = r#"{"profiles":{"micro":{"params":[{"name":"conv1/kernel","shape":[5,5,3,32]}]}}}"#;
        let v = Json::parse(src).unwrap();
        let shape: Vec<usize> = v
            .get("profiles").unwrap()
            .get("micro").unwrap()
            .get("params").unwrap()
            .as_arr().unwrap()[0]
            .get("shape").unwrap()
            .as_arr().unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![5, 5, 3, 32]);
    }

    #[test]
    fn writer_escapes_control_chars() {
        let s = to_string(&Json::Str("a\u{1}b".into()));
        assert_eq!(s, "\"a\\u0001b\"");
    }
}
