//! Small self-contained utilities shared by every subsystem.
//!
//! The build is fully offline against a vendored crate set that only
//! covers the `xla` dependency closure, so the usual ecosystem crates
//! (rand, serde, rayon, …) are re-implemented here at the scale this
//! project needs: a deterministic PRNG, a minimal JSON codec for the
//! artifact ABI, and a fixed thread pool.

pub mod bytes;
pub mod json;
pub mod pool;
pub mod rng;

pub use bytes::human_bytes;
pub use pool::ThreadPool;
pub use rng::Rng;
