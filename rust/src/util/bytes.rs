//! Byte-size formatting helpers for reports.

/// Render a byte count as a human-readable string (`1.5 MB`, `113 KB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else if v >= 100.0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// MB/s from bytes and seconds (decimal MB, as IOR reports).
pub fn mb_per_sec(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    bytes as f64 / 1e6 / secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_scales() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(999), "999 B");
        assert_eq!(human_bytes(112_000), "112 KB");
        assert_eq!(human_bytes(1_500_000), "1.50 MB");
        assert_eq!(human_bytes(5_000_000_000), "5.00 GB");
    }

    #[test]
    fn bandwidth_math() {
        assert!((mb_per_sec(163_000_000, 1.0) - 163.0).abs() < 1e-9);
        assert_eq!(mb_per_sec(100, 0.0), 0.0);
    }
}
