//! Fixed-size thread pool.
//!
//! Backs the `num_parallel_calls` worker set of [`crate::pipeline::map`]
//! and the burst-buffer drainer.  Plain `std::sync` implementation: a
//! shared `Mutex<VecDeque>` job queue with a condvar, matching the
//! TensorFlow runtime's own thread-pool granularity (one job = one
//! element-level map call).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

/// A fixed set of worker threads consuming a FIFO job queue.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dlio-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.available.notify_one();
    }

    /// Block until every enqueued job has completed.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        job();
        if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_lock.lock().unwrap();
            sh.done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn zero_size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let flag = Arc::new(AtomicBool::new(false));
        let f = Arc::clone(&flag);
        pool.execute(move || f.store(true, Ordering::SeqCst));
        pool.wait_idle();
        assert!(flag.load(Ordering::SeqCst));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn jobs_actually_run_concurrently() {
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            pool.execute(|| {
                std::thread::sleep(std::time::Duration::from_millis(50))
            });
        }
        pool.wait_idle();
        // 4 x 50 ms on 4 workers should take ~50 ms, not 200 ms.
        assert!(t0.elapsed() < std::time::Duration::from_millis(150));
    }
}
