//! The artifact ABI: a typed view of `artifacts/model_meta.json`.
//!
//! `python/compile/aot.py` emits this file alongside the HLO artifacts;
//! it pins the flat argument/result order of the train step
//! (`[params*, m*, v*, step, images, labels] -> (params*, m*, v*, step,
//! loss)`), parameter shapes for initialization, optimizer constants
//! and the preprocess bucket list.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One parameter tensor: name + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// He-init fan-in: product of all but the last dimension.
    pub fn fan_in(&self) -> usize {
        self.shape[..self.shape.len() - 1].iter().product::<usize>().max(1)
    }

    pub fn is_bias(&self) -> bool {
        self.name.ends_with("bias")
    }
}

/// One network profile (micro / mini / paper).
#[derive(Debug, Clone)]
pub struct ProfileMeta {
    pub name: String,
    pub input_size: usize,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
    pub num_params: usize,
}

impl ProfileMeta {
    /// Train-step input arity: 3 * |params| + step + images + labels.
    pub fn num_inputs(&self) -> usize {
        3 * self.params.len() + 3
    }

    /// Train-step output arity: 3 * |params| + step + loss.
    pub fn num_outputs(&self) -> usize {
        3 * self.params.len() + 2
    }

    /// Logical checkpoint payload (w + m + v as f32), bytes.
    pub fn checkpoint_bytes(&self) -> u64 {
        self.num_params as u64 * 3 * 4
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub enum ArtifactInfo {
    Preprocess { file: String, src_size: usize, out_size: usize,
                 batch: usize },
    Train { file: String, profile: String, batch: usize },
}

impl ArtifactInfo {
    pub fn file(&self) -> &str {
        match self {
            ArtifactInfo::Preprocess { file, .. } => file,
            ArtifactInfo::Train { file, .. } => file,
        }
    }
}

/// Adam hyper-parameters (mirrors `model.py`).
#[derive(Debug, Clone, Copy)]
pub struct AdamMeta {
    pub lr: f64,
    pub b1: f64,
    pub b2: f64,
    pub eps: f64,
}

/// Parsed model_meta.json.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub adam: AdamMeta,
    pub profiles: Vec<ProfileMeta>,
    pub artifacts: Vec<ArtifactInfo>,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let path = dir.join("model_meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ModelMeta> {
        let j = Json::parse(text).context("parsing model_meta.json")?;
        let req = |v: Option<&Json>, what: &str| {
            v.cloned().ok_or_else(|| anyhow!("meta missing {what}"))
        };

        let adam_j = req(j.get("adam"), "adam")?;
        let num = |o: &Json, k: &str| -> Result<f64> {
            o.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("adam missing {k}"))
        };
        let adam = AdamMeta {
            lr: num(&adam_j, "lr")?,
            b1: num(&adam_j, "b1")?,
            b2: num(&adam_j, "b2")?,
            eps: num(&adam_j, "eps")?,
        };

        let mut profiles = Vec::new();
        for (name, p) in req(j.get("profiles"), "profiles")?
            .as_obj()
            .ok_or_else(|| anyhow!("profiles not an object"))?
        {
            let params = p
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("profile {name} missing params"))?
                .iter()
                .map(|q| -> Result<ParamSpec> {
                    Ok(ParamSpec {
                        name: q
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape: q
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("param missing shape"))?
                            .iter()
                            .map(|d| {
                                d.as_usize()
                                    .ok_or_else(|| anyhow!("bad dim"))
                            })
                            .collect::<Result<Vec<_>>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let get_usize = |k: &str| -> Result<usize> {
                p.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("profile {name} missing {k}"))
            };
            let prof = ProfileMeta {
                name: name.clone(),
                input_size: get_usize("input_size")?,
                num_classes: get_usize("num_classes")?,
                num_params: get_usize("num_params")?,
                params,
            };
            // Cross-check the ABI arity recorded by python.
            if prof.num_inputs() != get_usize("num_inputs")?
                || prof.num_outputs() != get_usize("num_outputs")?
            {
                return Err(anyhow!("profile {name}: ABI arity mismatch"));
            }
            profiles.push(prof);
        }

        let mut artifacts = Vec::new();
        for a in req(j.get("artifacts"), "artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts not an array"))?
        {
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string();
            let kind = a
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing kind"))?;
            let info = match kind {
                "preprocess" => ArtifactInfo::Preprocess {
                    file,
                    src_size: a.get("src_size").and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("missing src_size"))?,
                    out_size: a.get("out_size").and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("missing out_size"))?,
                    batch: a.get("batch").and_then(Json::as_usize)
                        .unwrap_or(1),
                },
                "train" => ArtifactInfo::Train {
                    file,
                    profile: a.get("profile").and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("missing profile"))?
                        .to_string(),
                    batch: a.get("batch").and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("missing batch"))?,
                },
                other => return Err(anyhow!("unknown artifact kind {other}")),
            };
            artifacts.push(info);
        }

        Ok(ModelMeta { adam, profiles, artifacts })
    }

    pub fn profile(&self, name: &str) -> Result<&ProfileMeta> {
        self.profiles
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow!("unknown profile {name:?}"))
    }

    /// File name of the train artifact for (profile, batch).
    pub fn train_artifact(&self, profile: &str, batch: usize)
        -> Result<&str>
    {
        self.artifacts
            .iter()
            .find_map(|a| match a {
                ArtifactInfo::Train { file, profile: p, batch: b }
                    if p == profile && *b == batch => Some(file.as_str()),
                _ => None,
            })
            .ok_or_else(|| {
                anyhow!("no train artifact for {profile} batch {batch} \
                         (rebuild with `make artifacts`)")
            })
    }

    /// File name of the preprocess artifact for (src, out).
    pub fn preprocess_artifact(&self, src: usize, out: usize)
        -> Result<&str>
    {
        self.artifacts
            .iter()
            .find_map(|a| match a {
                ArtifactInfo::Preprocess { file, src_size, out_size, .. }
                    if *src_size == src && *out_size == out => {
                        Some(file.as_str())
                    }
                _ => None,
            })
            .ok_or_else(|| {
                anyhow!("no preprocess artifact {src}->{out} \
                         (rebuild with `make artifacts`)")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "adam": {"lr": 0.0001, "b1": 0.9, "b2": 0.999, "eps": 1e-08},
      "profiles": {
        "micro": {
          "name": "micro", "input_size": 32, "num_classes": 102,
          "num_param_tensors": 2, "num_params": 14,
          "params": [
            {"name": "conv1/kernel", "shape": [2, 2, 3, 1]},
            {"name": "conv1/bias", "shape": [2]}
          ],
          "num_inputs": 9, "num_outputs": 8
        }
      },
      "artifacts": [
        {"kind": "preprocess", "file": "p.hlo.txt",
         "src_size": 96, "out_size": 32, "batch": 1},
        {"kind": "train", "file": "t.hlo.txt",
         "profile": "micro", "batch": 64}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert!((m.adam.lr - 1e-4).abs() < 1e-12);
        let p = m.profile("micro").unwrap();
        assert_eq!(p.params.len(), 2);
        assert_eq!(p.num_inputs(), 9);
        assert_eq!(p.checkpoint_bytes(), 14 * 12);
        assert_eq!(m.train_artifact("micro", 64).unwrap(), "t.hlo.txt");
        assert_eq!(m.preprocess_artifact(96, 32).unwrap(), "p.hlo.txt");
    }

    #[test]
    fn missing_artifact_is_actionable_error() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        let err = m.train_artifact("micro", 7).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn arity_mismatch_rejected() {
        let bad = SAMPLE.replace("\"num_inputs\": 9", "\"num_inputs\": 10");
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn param_spec_helpers() {
        let p = ParamSpec { name: "fc1/kernel".into(), shape: vec![8, 4] };
        assert_eq!(p.num_elements(), 32);
        assert_eq!(p.fan_in(), 8);
        assert!(!p.is_bias());
        let b = ParamSpec { name: "fc1/bias".into(), shape: vec![4] };
        assert!(b.is_bias());
        assert_eq!(b.fan_in(), 1);
    }
}
