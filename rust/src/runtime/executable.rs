//! PJRT client + compiled-executable cache.
//!
//! ## Threading model
//!
//! The `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` are `!Send`
//! (they hold `Rc` refcounts), so XLA objects must never cross
//! threads.  We therefore keep **one PJRT client and executable cache
//! per thread** (`thread_local!`): each `parallel_map` worker that runs
//! the preprocess kernel owns its own client + compiled module, and
//! the training thread owns its own train-step module.  This mirrors
//! the TensorFlow runtime, where each inter-op thread executes kernels
//! against its own execution context.  Shareable handles ([`ExecSpec`])
//! are just paths + metadata and are freely `Send`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use super::meta::ModelMeta;

/// One compiled HLO module, owned by the current thread.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

thread_local! {
    /// Per-thread PJRT client (created on first use).
    static CLIENT: RefCell<Option<Rc<xla::PjRtClient>>> =
        const { RefCell::new(None) };
    /// Per-thread compiled-module cache, keyed by artifact path.
    static EXECUTABLES: RefCell<HashMap<PathBuf, Rc<Executable>>> =
        RefCell::new(HashMap::new());
}

/// This thread's PJRT CPU client.
pub fn thread_client() -> Result<Rc<xla::PjRtClient>> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if let Some(client) = slot.as_ref() {
            return Ok(Rc::clone(client));
        }
        // Quiet the TfrtCpuClient created/destroyed chatter unless the
        // user asked for verbose logs.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let client = Rc::new(
            xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT CPU client init: {e}"))?,
        );
        *slot = Some(Rc::clone(&client));
        Ok(client)
    })
}

/// Load+compile `path` on this thread (cached per thread).
pub fn thread_executable(path: &Path) -> Result<Rc<Executable>> {
    if let Some(e) =
        EXECUTABLES.with(|m| m.borrow().get(path).map(Rc::clone))
    {
        return Ok(e);
    }
    let exe = Rc::new(Executable::load(path)?);
    EXECUTABLES.with(|m| {
        m.borrow_mut().insert(path.to_path_buf(), Rc::clone(&exe));
    });
    Ok(exe)
}

impl Executable {
    /// Load HLO text from `path` and compile it on this thread's
    /// client.
    pub fn load(path: &Path) -> Result<Executable> {
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = thread_client()?
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        Ok(Executable { name, exe })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with literal inputs, returning the flattened outputs.
    /// Artifacts are lowered with `return_tuple=True`, so the single
    /// result buffer is a tuple that we decompose host-side.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let buffers = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        let out = buffers
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| anyhow!("execute {}: no outputs", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {}: {e}", self.name))?;
        out.to_tuple()
            .map_err(|e| anyhow!("untuple result of {}: {e}", self.name))
    }

    /// Execute with device-resident buffers (hot-loop path: keeps
    /// params on device, avoiding host round-trips).  Returns the raw
    /// output buffers.
    pub fn run_b(&self, args: &[&xla::PjRtBuffer])
        -> Result<Vec<xla::PjRtBuffer>>
    {
        let mut buffers = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow!("execute_b {}: {e}", self.name))?;
        if buffers.is_empty() || buffers[0].is_empty() {
            return Err(anyhow!("execute_b {}: no outputs", self.name));
        }
        Ok(buffers.swap_remove(0))
    }
}

/// A `Send + Sync` handle to an artifact: resolves to a compiled
/// [`Executable`] on whichever thread calls [`ExecSpec::get`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecSpec {
    path: PathBuf,
}

impl ExecSpec {
    pub fn new(path: PathBuf) -> ExecSpec {
        ExecSpec { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Compile (or fetch from this thread's cache) the executable.
    pub fn get(&self) -> Result<Rc<Executable>> {
        thread_executable(&self.path)
    }
}

/// Literal construction helpers (the L3-side marshalling layer).
pub mod lit {
    use super::*;

    /// f32 literal with the given dims from a host slice.
    pub fn f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(anyhow!("literal shape {dims:?} != len {}",
                               data.len()));
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32, dims, bytes)
            .map_err(|e| anyhow!("create f32 literal: {e}"))
    }

    /// u8 literal with the given dims.
    pub fn u8(dims: &[usize], data: &[u8]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(anyhow!("literal shape {dims:?} != len {}",
                               data.len()));
        }
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8, dims, data)
            .map_err(|e| anyhow!("create u8 literal: {e}"))
    }

    /// f32 scalar.
    pub fn scalar_f32(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// Extract a literal's f32 data.
    pub fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
        l.to_vec::<f32>().map_err(|e| anyhow!("literal to f32: {e}"))
    }
}

/// Artifact directory + parsed meta.  `Send + Sync`; executables are
/// materialized per thread via [`ExecSpec`].
pub struct Runtime {
    dir: PathBuf,
    meta: ModelMeta,
}

impl Runtime {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir = dir.into();
        let meta = ModelMeta::load(&dir).with_context(|| {
            format!(
                "loading artifact meta from {} (run `make artifacts`)",
                dir.display()
            )
        })?;
        Ok(Runtime { dir, meta })
    }

    /// Default artifact location: `$DLIO_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("DLIO_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Spec for an artifact by file name.
    pub fn spec(&self, file: &str) -> ExecSpec {
        ExecSpec::new(self.dir.join(file))
    }

    /// The preprocess executable spec for a (src, out) bucket.
    pub fn preprocess(&self, src: usize, out: usize) -> Result<ExecSpec> {
        Ok(self.spec(self.meta.preprocess_artifact(src, out)?))
    }

    /// The train-step executable spec for (profile, batch).
    pub fn train_step(&self, profile: &str, batch: usize)
        -> Result<ExecSpec>
    {
        Ok(self.spec(self.meta.train_artifact(profile, batch)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_validate_shape() {
        assert!(lit::f32(&[2, 2], &[1.0, 2.0, 3.0]).is_err());
        assert!(lit::u8(&[3], &[1, 2]).is_err());
        let l = lit::f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(lit::to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn runtime_open_missing_dir_is_actionable() {
        let Err(err) = Runtime::open("/nonexistent-dlio") else {
            panic!("open of missing dir succeeded");
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn exec_spec_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ExecSpec>();
        assert_send_sync::<Runtime>();
    }
}
