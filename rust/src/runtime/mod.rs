//! PJRT runtime: loads AOT artifacts and executes them on the hot path.
//!
//! This is the boundary between L3 (rust) and L1/L2 (python, build-time
//! only): `make artifacts` lowers the JAX/Pallas computations to HLO
//! *text* (see `python/compile/aot.py` for why text, not serialized
//! protos), and this module loads, compiles and runs them through the
//! `xla` crate's PJRT CPU client.  Python never executes at runtime.

pub mod executable;
pub mod meta;

pub use executable::{ExecSpec, Executable, Runtime};
pub use meta::{ArtifactInfo, ModelMeta, ParamSpec, ProfileMeta};
