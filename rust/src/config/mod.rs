//! Configuration: testbed setup (devices, cache, time scale) and
//! experiment parameter blocks, plus a tiny CLI argument parser used
//! by the `dlio` binary and the bench harnesses.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::storage::{profiles, DeviceModel, QosConfig};

/// Testbed description: which simulated devices exist and how fast the
/// simulation runs relative to the modelled hardware.
#[derive(Debug, Clone)]
pub struct Testbed {
    pub devices: Vec<DeviceModel>,
    /// Simulated page-cache capacity in bytes (0 = the paper's
    /// cold-cache protocol).
    pub cache_bytes: u64,
    /// Working directory for backing files.
    pub workdir: String,
    /// Engine scheduler: weighted per-class DRR by default;
    /// `QosConfig::fifo()` restores the single-queue baseline.
    pub qos: QosConfig,
}

impl Testbed {
    /// The paper's two environments, at a given simulation speed-up.
    /// `time_scale` > 1 accelerates devices uniformly — every ratio in
    /// every figure is preserved (see DESIGN.md §6).
    pub fn paper(time_scale: f64) -> Testbed {
        Testbed {
            devices: vec![
                profiles::blackdog_hdd(time_scale),
                profiles::blackdog_ssd(time_scale),
                profiles::blackdog_optane(time_scale),
                profiles::tegner_lustre(time_scale),
                // Calibrated per-block-size classes (DESIGN.md §17):
                // idle unless a hierarchy/workload names them, so the
                // paper experiments are unaffected.
                profiles::optane_class(time_scale),
                profiles::nvme_class(time_scale),
                profiles::hdd_class(time_scale),
            ],
            cache_bytes: 0,
            workdir: default_workdir(),
            qos: QosConfig::default(),
        }
    }
}

/// `$DLIO_WORKDIR`, else tmpfs (`/dev/shm`) when available, else the
/// system tmp dir.  Backing files *must* live on fast storage: the
/// simulator charges real I/O time against the modelled service time
/// (see `storage::device`), so slow real storage would flatten the
/// modelled device differences.
pub fn default_workdir() -> String {
    if let Ok(dir) = std::env::var("DLIO_WORKDIR") {
        return dir;
    }
    let shm = std::path::Path::new("/dev/shm");
    if shm.is_dir() {
        return shm.join("dlio-work").to_string_lossy().into_owned();
    }
    std::env::temp_dir()
        .join("dlio-work")
        .to_string_lossy()
        .into_owned()
}

/// Default simulation speed-up for benches: devices run 8x the modelled
/// speed, keeping every *ratio* intact while making a full figure sweep
/// take minutes instead of hours.  Override with `$DLIO_TIME_SCALE`.
pub fn default_time_scale() -> f64 {
    std::env::var("DLIO_TIME_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8.0)
}

/// Micro-benchmark parameters (§III-A / §IV-A).
#[derive(Debug, Clone)]
pub struct MicrobenchConfig {
    pub device: String,
    pub threads: usize,
    pub batch: usize,
    /// Batches to consume (paper: 256 x batch 64 = 16,384 images).
    pub iterations: usize,
    /// Full pipeline (read+decode+resize, Fig. 4) vs read-only (Fig. 5).
    pub preprocess: bool,
    /// Model input size the resize targets.
    pub out_size: usize,
    /// File reads kept in flight on the I/O engine ahead of the
    /// consumer, per shard (0 = classic blocking reads inside the map
    /// workers).
    pub readahead: usize,
    /// Reader shards the file list is partitioned across (each with
    /// its own `readahead` window; Fig. 4/8's parallelism knob).
    pub shards: usize,
}

/// Per-shard inflight window used when shards are requested without
/// an explicit readahead (sharding only exists on the engine-backed
/// source, so asking for shards implies it).
pub const DEFAULT_SHARD_WINDOW: usize = 4;

impl MicrobenchConfig {
    /// Per-shard engine read window actually in force: `shards > 1`
    /// with `readahead == 0` gets [`DEFAULT_SHARD_WINDOW`] instead of
    /// silently falling back to the blocking path.  Used by both the
    /// runner and the CLI's result line, so logged configurations
    /// always match what ran.
    pub fn effective_readahead(&self) -> usize {
        if self.readahead == 0 && self.shards.max(1) > 1 {
            DEFAULT_SHARD_WINDOW
        } else {
            self.readahead
        }
    }
}

impl Default for MicrobenchConfig {
    fn default() -> Self {
        MicrobenchConfig {
            device: "ssd".into(),
            threads: 4,
            batch: 64,
            iterations: 32,
            preprocess: true,
            out_size: 64,
            readahead: 0,
            shards: 1,
        }
    }
}

/// Mini-application parameters (§III-B / §IV-B).
#[derive(Debug, Clone)]
pub struct MiniAppConfig {
    pub device: String,
    pub threads: usize,
    pub batch: usize,
    /// Batches to prefetch (paper: 0 or 1).
    pub prefetch: usize,
    /// Training iterations (paper: 142 = one epoch of Caltech-101@64).
    pub iterations: usize,
    /// Model profile: micro / mini / paper.
    pub profile: String,
    pub seed: u64,
}

impl Default for MiniAppConfig {
    fn default() -> Self {
        MiniAppConfig {
            device: "ssd".into(),
            threads: 4,
            batch: 64,
            prefetch: 1,
            iterations: 20,
            profile: "micro".into(),
            seed: 42,
        }
    }
}

/// Where checkpoints go (§III-C / §IV-C).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointTarget {
    /// No checkpointing (Fig. 9's gray baseline).
    None,
    /// Synchronous save straight to a device.
    Direct(String),
    /// Burst buffer: save to `fast`, drain asynchronously to `slow`.
    BurstBuffer { fast: String, slow: String },
}

impl CheckpointTarget {
    pub fn parse(s: &str) -> Result<CheckpointTarget> {
        match s {
            "none" => Ok(CheckpointTarget::None),
            _ if s.starts_with("bb:") => {
                let rest = &s[3..];
                let (fast, slow) = rest.split_once(':').ok_or_else(|| {
                    anyhow!("burst buffer spec must be bb:<fast>:<slow>")
                })?;
                Ok(CheckpointTarget::BurstBuffer {
                    fast: fast.to_string(),
                    slow: slow.to_string(),
                })
            }
            dev => Ok(CheckpointTarget::Direct(dev.to_string())),
        }
    }

    pub fn label(&self) -> String {
        match self {
            CheckpointTarget::None => "none".into(),
            CheckpointTarget::Direct(d) => d.clone(),
            CheckpointTarget::BurstBuffer { fast, slow } => {
                format!("bb:{fast}:{slow}")
            }
        }
    }
}

/// Checkpoint study parameters (§IV-C).
#[derive(Debug, Clone)]
pub struct CkptStudyConfig {
    pub mini: MiniAppConfig,
    pub target: CheckpointTarget,
    /// Save every N iterations (paper: 20).
    pub interval: usize,
    pub max_to_keep: usize,
}

impl Default for CkptStudyConfig {
    fn default() -> Self {
        CkptStudyConfig {
            mini: MiniAppConfig {
                device: "ssd".into(), // paper: images on SSD, prefetch on
                prefetch: 1,
                iterations: 20,       // paper: 100 (bench-scaled)
                ..Default::default()
            },
            target: CheckpointTarget::Direct("hdd".into()),
            interval: 5,              // paper: 20 (bench-scaled)
            max_to_keep: 5,
        }
    }
}

/// Tiny `--key value` / `--flag` argument parser for the binary and
/// bench harnesses.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map_or(false, |n| !n.starts_with("--"))
                {
                    out.options
                        .insert(key.to_string(), iter.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    /// Comma-separated list option: `--modes fifo,static` →
    /// `Some(["fifo", "static"])`; `None` when absent.  Empty items
    /// (trailing commas) are dropped.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }

    /// Comma-separated usize list: `--shards 1,2,4`.
    pub fn get_usize_list(
        &self,
        key: &str,
        default: &[usize],
    ) -> Result<Vec<usize>> {
        match self.get_list(key) {
            None => Ok(default.to_vec()),
            Some(items) => items
                .iter()
                .map(|s| s.parse().map_err(|e| anyhow!("--{key}: {e}")))
                .collect(),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_forms() {
        let a = Args::parse(
            ["run", "--threads", "8", "--device=ssd", "--verbose"]
                .into_iter()
                .map(String::from),
        );
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.get("device"), Some("ssd"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_usize("threads", 1).unwrap(), 8);
        assert_eq!(a.get_usize("missing", 3).unwrap(), 3);
        assert!(a.get_usize("device", 1).is_err());
    }

    #[test]
    fn args_parse_lists() {
        let a = Args::parse(
            ["sweep", "--modes", "fifo, static,", "--shards", "1,2,4"]
                .into_iter()
                .map(String::from),
        );
        assert_eq!(
            a.get_list("modes").unwrap(),
            vec!["fifo".to_string(), "static".to_string()]
        );
        assert_eq!(a.get_usize_list("shards", &[8]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_usize_list("missing", &[8]).unwrap(), vec![8]);
        assert!(a.get_usize_list("modes", &[1]).is_err());
    }

    #[test]
    fn checkpoint_target_parse() {
        assert_eq!(CheckpointTarget::parse("none").unwrap(),
                   CheckpointTarget::None);
        assert_eq!(CheckpointTarget::parse("hdd").unwrap(),
                   CheckpointTarget::Direct("hdd".into()));
        assert_eq!(
            CheckpointTarget::parse("bb:optane:hdd").unwrap(),
            CheckpointTarget::BurstBuffer {
                fast: "optane".into(),
                slow: "hdd".into()
            }
        );
        assert!(CheckpointTarget::parse("bb:only").is_err());
        assert_eq!(
            CheckpointTarget::parse("bb:optane:hdd").unwrap().label(),
            "bb:optane:hdd"
        );
    }

    #[test]
    fn testbed_paper_has_all_devices() {
        let t = Testbed::paper(1.0);
        let names: Vec<_> =
            t.devices.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "hdd",
                "ssd",
                "optane",
                "lustre",
                "optane-class",
                "nvme-class",
                "hdd-class"
            ]
        );
        assert_eq!(t.cache_bytes, 0);
    }
}
