//! The modelled training loop: consume a batch, occupy the
//! accelerator, optionally stall on a checkpoint — one
//! [`StepRecord`] per iteration.
//!
//! This is the structure of the paper's mini-app with the XLA step
//! replaced by [`AccelModel::execute`]: the input pipeline fills a
//! bounded [`SimPrefetch`] queue ahead of the consumer, so with
//! sufficient depth the step time converges to
//! `max(compute, input)` — the paper's "complete overlap" — while
//! `prefetch == 0` pays `compute + input` additively.

use anyhow::Result;

use crate::pipeline::{Dataset, SimPrefetch};

use super::accel::AccelModel;
use super::step::{StepRecord, StepSummary};

/// Knobs for [`run_loop`].
#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Prefetch queue depth (0 = synchronous).
    pub prefetch: usize,
    /// Stop after this many steps (0 = run until the source ends).
    pub max_steps: usize,
    /// Checkpoint every N steps (0 = never).
    pub ckpt_interval: usize,
}

/// A finished loop: the per-step records and their roll-up.
#[derive(Debug, Clone)]
pub struct LoopOutcome {
    pub records: Vec<StepRecord>,
    pub summary: StepSummary,
}

/// Drive the loop over `batches` (each element = images in one batch).
///
/// Registers the calling thread with the accelerator's clock for the
/// duration, so virtual-clock runs advance in discrete-event time.
/// `on_ckpt` runs synchronously on the step thread every
/// `ckpt_interval` steps; its clock-time cost is recorded as that
/// step's checkpoint stall.
pub fn run_loop<D>(
    batches: D,
    accel: &AccelModel,
    cfg: &LoopConfig,
    mut on_ckpt: Option<&mut dyn FnMut(u64) -> Result<()>>,
) -> Result<LoopOutcome>
where
    D: Dataset<Item = u64> + 'static,
{
    let clock = accel.clock().clone();
    let _reg = clock.enter();
    let mut src = SimPrefetch::new(batches, cfg.prefetch, &clock);
    let run0 = clock.now();
    let mut records: Vec<StepRecord> = Vec::new();
    let mut step = 0u64;
    loop {
        if cfg.max_steps > 0 && step >= cfg.max_steps as u64 {
            break;
        }
        let w0 = clock.now();
        let Some(batch) = src.next() else { break };
        let images = batch?;
        let input_wait_secs = clock.now() - w0;
        let compute_secs = accel.execute(step);
        let mut ckpt_stall_secs = 0.0;
        if cfg.ckpt_interval > 0 && (step + 1) % cfg.ckpt_interval as u64 == 0
        {
            if let Some(f) = on_ckpt.as_mut() {
                let k0 = clock.now();
                f(step + 1)?;
                ckpt_stall_secs = clock.now() - k0;
            }
        }
        records.push(StepRecord {
            step,
            start_secs: w0 - run0,
            input_wait_secs,
            compute_secs,
            ckpt_stall_secs,
            images,
        });
        step += 1;
    }
    let summary = StepSummary::from_records(&records);
    Ok(LoopOutcome { records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::accel::{AccelTier, ComputeProfile};
    use crate::storage::Clock;

    /// A batch source costing `secs` of clock time per batch.
    struct TimedBatches {
        left: usize,
        secs: f64,
        images: u64,
        clock: Clock,
    }

    impl Dataset for TimedBatches {
        type Item = u64;

        fn next(&mut self) -> Option<Result<u64>> {
            if self.left == 0 {
                return None;
            }
            self.left -= 1;
            self.clock.sleep_secs(self.secs);
            Some(Ok(self.images))
        }
    }

    fn accel(clock: &Clock, profile: &str, batch: usize) -> AccelModel {
        AccelModel::new(
            ComputeProfile::by_name(profile).unwrap(),
            AccelTier::by_name("k80").unwrap(),
            batch,
            1.0,
            clock.clone(),
        )
        .unwrap()
    }

    fn timed(clock: &Clock, n: usize, secs: f64) -> TimedBatches {
        TimedBatches { left: n, secs, images: 16, clock: clock.clone() }
    }

    #[test]
    fn prefetch_overlaps_and_sync_is_additive() {
        // micro @ batch 16: step C = 0.0005 + 16*0.00005 = 1.3 ms.
        // Input I = 1.0 ms/batch (compute-bound cell, C > I).
        let run = |prefetch: usize| -> LoopOutcome {
            let clock = Clock::virt();
            let a = accel(&clock, "micro", 16);
            let cfg =
                LoopConfig { prefetch, max_steps: 0, ckpt_interval: 0 };
            run_loop(timed(&clock, 20, 0.001), &a, &cfg, None).unwrap()
        };
        let sync = run(0);
        let over = run(4);
        assert_eq!(sync.summary.steps, 20);
        assert_eq!(over.summary.steps, 20);
        assert_eq!(sync.summary.images, 20 * 16);
        let c = accel(&Clock::virt(), "micro", 16).steady_step_secs();
        // Synchronous: every step pays C + I.
        let sync_steady = StepSummary::steady_mean_step_secs(&sync.records, 2);
        assert!(
            sync_steady >= 0.999 * (c + 0.001),
            "sync steady {sync_steady} < C+I {}",
            c + 0.001
        );
        // Prefetched: steady step converges to max(C, I) = C and the
        // stall fraction collapses.
        let over_steady = StepSummary::steady_mean_step_secs(&over.records, 2);
        assert!(
            over_steady <= 1.01 * c,
            "overlap steady {over_steady} > C {c}"
        );
        assert!(
            over.summary.stall_frac < 0.05,
            "stall_frac {}",
            over.summary.stall_frac
        );
        assert!(over.summary.total_secs < sync.summary.total_secs);
    }

    #[test]
    fn max_steps_truncates_and_ckpt_stall_is_attributed() {
        let clock = Clock::virt();
        let a = accel(&clock, "micro", 16);
        let cfg =
            LoopConfig { prefetch: 2, max_steps: 9, ckpt_interval: 4 };
        let ckpt_clock = clock.clone();
        let mut saved: Vec<u64> = Vec::new();
        let mut on_ckpt = |step: u64| -> Result<()> {
            ckpt_clock.sleep_secs(0.01);
            saved.push(step);
            Ok(())
        };
        let out =
            run_loop(timed(&clock, 100, 0.0002), &a, &cfg, Some(&mut on_ckpt))
                .unwrap();
        assert_eq!(out.summary.steps, 9);
        assert_eq!(saved, vec![4, 8]);
        for r in &out.records {
            if (r.step + 1) % 4 == 0 {
                assert!(
                    (r.ckpt_stall_secs - 0.01).abs() < 1e-9,
                    "step {}: {}",
                    r.step,
                    r.ckpt_stall_secs
                );
            } else {
                assert_eq!(r.ckpt_stall_secs, 0.0, "step {}", r.step);
            }
        }
        assert!(out.summary.ckpt_stall_secs > 0.019);
    }

    #[test]
    fn records_are_bit_identical_across_virtual_runs() {
        let run = || {
            let clock = Clock::virt();
            let a = accel(&clock, "alexnet", 8);
            let cfg =
                LoopConfig { prefetch: 3, max_steps: 12, ckpt_interval: 5 };
            let ckpt_clock = clock.clone();
            let mut on_ckpt = |_| {
                ckpt_clock.sleep_secs(0.002);
                Ok(())
            };
            run_loop(
                timed(&clock, 50, 0.0007),
                &a,
                &cfg,
                Some(&mut on_ckpt),
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        // Bit-identical f64s, not tolerances: the determinism contract.
        assert_eq!(a.records, b.records);
        assert_eq!(a.summary, b.summary);
    }

    #[test]
    fn source_errors_propagate() {
        struct Bad;
        impl Dataset for Bad {
            type Item = u64;
            fn next(&mut self) -> Option<Result<u64>> {
                Some(Err(anyhow::anyhow!("torn file")))
            }
        }
        let clock = Clock::virt();
        let a = accel(&clock, "micro", 4);
        let cfg = LoopConfig { prefetch: 1, max_steps: 5, ckpt_interval: 0 };
        assert!(run_loop(Bad, &a, &cfg, None).is_err());
    }
}
