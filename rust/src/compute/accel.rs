//! The calibrated accelerator compute model.
//!
//! `rust/vendor/xla` is a stub, so the repo cannot run real AlexNet
//! steps — but the paper's headline result (prefetching completely
//! overlaps accelerator compute with the CPU input pipeline,
//! eliminating the effective cost of I/O) is about *durations*, not
//! gradients.  [`AccelModel`] closes that loop with a discrete-event
//! stand-in: a per-layer cost table calibrated to the paper's
//! AlexNet-like mini-app, scaled by batch size and device tier, and
//! executed as a [`Clock`] sleep so virtual-clock runs are exact and
//! bit-deterministic.
//!
//! Step time composes as
//!
//! ```text
//! step(b) = warmup(step) * sum_layers(fixed + per_image * b) / tier_speedup
//!           / time_scale
//! ```
//!
//! `fixed` captures per-launch overhead (kernel launches, host sync),
//! `per_image` the throughput term; early steps pay a linearly
//! decaying warm-up multiplier (JIT compilation, autotuning) exactly
//! like the first TensorFlow steps the paper excludes from its
//! averages.  `time_scale` matches the storage models' time
//! compression, so compute-vs-I/O ratios survive scaled runs.

use anyhow::{bail, Result};

use crate::storage::Clock;

/// One layer's cost contribution: a fixed per-step term plus a
/// per-image term, both in microseconds at tier speedup 1.0.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCost {
    pub name: &'static str,
    pub fixed_us: f64,
    pub per_image_us: f64,
}

/// A named per-layer cost table (the pluggable part: add a profile,
/// get a new modelled network).
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeProfile {
    pub name: &'static str,
    pub layers: Vec<LayerCost>,
    /// Steps paying the warm-up multiplier (JIT / autotune).
    pub warmup_steps: u64,
    /// Multiplier at step 0, decaying linearly to 1.0 across
    /// `warmup_steps`.
    pub warmup_factor: f64,
}

/// Valid compute-profile names, in [`ComputeProfile::by_name`] order.
pub const PROFILE_NAMES: [&str; 4] = ["alexnet", "resnet50", "micro", "none"];

impl ComputeProfile {
    /// The paper's AlexNet-like mini-app, calibrated to a K80-class
    /// accelerator (tier speedup 1.0): forward + backward per layer,
    /// ~1.4 ms/image throughput term and ~8 ms/step launch overhead
    /// — ~100 ms/step at the paper's batch size of 64.
    pub fn alexnet() -> ComputeProfile {
        let l = |name, fixed_us, per_image_us| LayerCost {
            name,
            fixed_us,
            per_image_us,
        };
        ComputeProfile {
            name: "alexnet",
            layers: vec![
                l("conv1", 1200.0, 190.0),
                l("conv2", 1100.0, 340.0),
                l("conv3", 900.0, 180.0),
                l("conv4", 900.0, 140.0),
                l("conv5", 800.0, 90.0),
                l("fc6", 1400.0, 300.0),
                l("fc7", 1000.0, 130.0),
                l("fc8", 400.0, 30.0),
                l("optimizer", 300.0, 0.0),
            ],
            warmup_steps: 2,
            warmup_factor: 3.0,
        }
    }

    /// A ResNet-50-shaped table: the four residual stages (3/4/6/3
    /// bottleneck blocks) folded into one layer row each, calibrated
    /// to K80-class throughput of roughly 50 images/s — ~20 ms/image,
    /// an order of magnitude more compute per byte read than AlexNet.
    /// Under the `step = max(compute, input)` overlap regime this is
    /// the compute-bound end of the paper's spectrum: the same input
    /// pipeline that bottlenecks AlexNet hides completely behind
    /// ResNet compute, with proportionally lower prefetcher pressure.
    pub fn resnet50() -> ComputeProfile {
        let l = |name, fixed_us, per_image_us| LayerCost {
            name,
            fixed_us,
            per_image_us,
        };
        ComputeProfile {
            name: "resnet50",
            layers: vec![
                l("conv1+pool", 800.0, 900.0),
                l("stage1(3x)", 2400.0, 3600.0),
                l("stage2(4x)", 3200.0, 4400.0),
                l("stage3(6x)", 4800.0, 6200.0),
                l("stage4(3x)", 2400.0, 3800.0),
                l("pool+fc", 600.0, 120.0),
                l("optimizer", 900.0, 0.0),
            ],
            // Deeper graph: more kernels to JIT/autotune than AlexNet.
            warmup_steps: 3,
            warmup_factor: 3.5,
        }
    }

    /// A deliberately tiny network for smoke cells and unit tests.
    pub fn micro() -> ComputeProfile {
        ComputeProfile {
            name: "micro",
            layers: vec![
                LayerCost { name: "conv", fixed_us: 300.0, per_image_us: 30.0 },
                LayerCost { name: "fc", fixed_us: 200.0, per_image_us: 20.0 },
            ],
            warmup_steps: 1,
            warmup_factor: 2.0,
        }
    }

    /// Zero compute: the input-drain profile.  A loop run with `none`
    /// measures the pure input-pipeline cost of a cell — the `I` in
    /// the paper's `step = max(compute, input)` overlap regime.
    pub fn none() -> ComputeProfile {
        ComputeProfile {
            name: "none",
            layers: Vec::new(),
            warmup_steps: 0,
            warmup_factor: 1.0,
        }
    }

    /// Resolve a profile by name; the error lists the valid set.
    pub fn by_name(name: &str) -> Result<ComputeProfile> {
        match name {
            "alexnet" => Ok(ComputeProfile::alexnet()),
            "resnet50" | "resnet" => Ok(ComputeProfile::resnet50()),
            "micro" => Ok(ComputeProfile::micro()),
            "none" => Ok(ComputeProfile::none()),
            other => bail!(
                "unknown compute profile '{other}' (valid: {})",
                PROFILE_NAMES.join(", ")
            ),
        }
    }

    /// Post-warm-up step seconds at tier speedup 1.0 and time scale
    /// 1.0 for a given batch size.
    pub fn step_secs(&self, batch: usize) -> f64 {
        self.layers
            .iter()
            .map(|l| l.fixed_us + l.per_image_us * batch as f64)
            .sum::<f64>()
            * 1e-6
    }

    /// Warm-up multiplier for `step` (1.0 once warmed up).
    pub fn warmup_mult(&self, step: u64) -> f64 {
        if step >= self.warmup_steps || self.warmup_steps == 0 {
            return 1.0;
        }
        let remaining =
            (self.warmup_steps - step) as f64 / self.warmup_steps as f64;
        1.0 + (self.warmup_factor - 1.0) * remaining
    }
}

/// A device tier: speedup relative to the K80-class baseline the
/// tables are calibrated against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccelTier {
    pub name: &'static str,
    pub speedup: f64,
}

/// Valid tier names, in [`AccelTier::by_name`] order.
pub const TIER_NAMES: [&str; 4] = ["cpu", "k80", "p100", "v100"];

impl AccelTier {
    /// Resolve a tier by name; the error lists the valid set.
    pub fn by_name(name: &str) -> Result<AccelTier> {
        let speedup = match name {
            "cpu" => 0.1,
            "k80" => 1.0,
            "p100" => 2.2,
            "v100" => 4.5,
            other => bail!(
                "unknown accelerator tier '{other}' (valid: {})",
                TIER_NAMES.join(", ")
            ),
        };
        Ok(AccelTier {
            name: TIER_NAMES.iter().find(|n| **n == name).unwrap(),
            speedup,
        })
    }
}

/// The discrete-event accelerator: occupies the [`Clock`] for the
/// modelled step duration.  Pure state — `step_secs` is a function of
/// (profile, tier, batch, time scale, step index) only, which is what
/// makes virtual-clock runs bit-deterministic.
#[derive(Debug, Clone)]
pub struct AccelModel {
    profile: ComputeProfile,
    tier: AccelTier,
    batch: usize,
    time_scale: f64,
    clock: Clock,
}

impl AccelModel {
    pub fn new(
        profile: ComputeProfile,
        tier: AccelTier,
        batch: usize,
        time_scale: f64,
        clock: Clock,
    ) -> Result<AccelModel> {
        if batch == 0 {
            bail!("batch size must be positive");
        }
        if !(time_scale > 0.0) {
            bail!("time scale must be positive, got {time_scale}");
        }
        Ok(AccelModel { profile, tier, batch, time_scale, clock })
    }

    /// Modelled duration of `step` in clock seconds.
    pub fn step_secs(&self, step: u64) -> f64 {
        self.profile.warmup_mult(step) * self.profile.step_secs(self.batch)
            / self.tier.speedup
            / self.time_scale
    }

    /// Post-warm-up step duration — the `C` term of the paper's
    /// `step = max(C, I)` overlap regime.
    pub fn steady_step_secs(&self) -> f64 {
        self.step_secs(self.profile.warmup_steps)
    }

    /// Exact modelled compute total for `steps` steps.
    pub fn total_secs(&self, steps: u64) -> f64 {
        (0..steps).map(|s| self.step_secs(s)).sum()
    }

    /// Occupy the accelerator for `step`'s modelled duration (a clock
    /// sleep; exact under the virtual clock).  Returns the duration.
    pub fn execute(&self, step: u64) -> f64 {
        let secs = self.step_secs(step);
        if secs > 0.0 {
            self.clock.sleep_secs(secs);
        }
        secs
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn profile_name(&self) -> &'static str {
        self.profile.name
    }

    pub fn tier_name(&self) -> &'static str {
        self.tier.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_and_rejects_with_the_valid_list() {
        for n in PROFILE_NAMES {
            assert_eq!(ComputeProfile::by_name(n).unwrap().name, n);
        }
        // "resnet" is an accepted alias for the canonical "resnet50".
        assert_eq!(ComputeProfile::by_name("resnet").unwrap().name, "resnet50");
        let err = ComputeProfile::by_name("vgg").unwrap_err().to_string();
        for n in PROFILE_NAMES {
            assert!(err.contains(n), "{err} missing {n}");
        }
        for n in TIER_NAMES {
            assert_eq!(AccelTier::by_name(n).unwrap().name, n);
        }
        let err = AccelTier::by_name("tpu").unwrap_err().to_string();
        for n in TIER_NAMES {
            assert!(err.contains(n), "{err} missing {n}");
        }
    }

    #[test]
    fn step_time_scales_with_batch_tier_and_time_scale() {
        let p = ComputeProfile::alexnet();
        // Fixed cost means batch 64 is less than 2x batch 32.
        let b32 = p.step_secs(32);
        let b64 = p.step_secs(64);
        assert!(b64 > b32 && b64 < 2.0 * b32, "{b32} vs {b64}");
        // Calibration anchor: ~100 ms/step at the paper's batch 64.
        assert!((0.05..0.2).contains(&b64), "batch-64 step {b64}");

        let clock = Clock::virt();
        let k80 = AccelModel::new(
            p.clone(),
            AccelTier::by_name("k80").unwrap(),
            64,
            1.0,
            clock.clone(),
        )
        .unwrap();
        let v100 = AccelModel::new(
            p.clone(),
            AccelTier::by_name("v100").unwrap(),
            64,
            1.0,
            clock.clone(),
        )
        .unwrap();
        let scaled = AccelModel::new(
            p,
            AccelTier::by_name("k80").unwrap(),
            64,
            8.0,
            clock,
        )
        .unwrap();
        let s = k80.steady_step_secs();
        assert!((v100.steady_step_secs() - s / 4.5).abs() < 1e-12);
        assert!((scaled.steady_step_secs() - s / 8.0).abs() < 1e-12);
    }

    #[test]
    fn resnet_is_the_compute_bound_end_of_the_spectrum() {
        let r = ComputeProfile::resnet50();
        let a = ComputeProfile::alexnet();
        // Calibration anchor: ~50 images/s on the K80 baseline at
        // batch 64 — roughly 1.3 s/step, an order of magnitude above
        // AlexNet's ~100 ms.
        let step = r.step_secs(64);
        assert!((0.8..2.0).contains(&step), "batch-64 step {step}");
        assert!(
            step > 5.0 * a.step_secs(64),
            "resnet ({step}s) must dwarf alexnet ({}s)",
            a.step_secs(64)
        );
        // The model executes like any other profile: virtual-clock
        // smoke of one warm-up and one steady step.
        let clock = Clock::virt();
        let accel = AccelModel::new(
            r,
            AccelTier::by_name("v100").unwrap(),
            32,
            8.0,
            clock.clone(),
        )
        .unwrap();
        let _reg = clock.enter();
        let t0 = clock.now();
        let d0 = accel.execute(0);
        let d3 = accel.execute(3);
        assert!(d0 > d3, "warm-up step must be slower");
        assert!((clock.now() - t0 - (d0 + d3)).abs() < 1e-12);
    }

    #[test]
    fn warmup_decays_to_steady_state() {
        let p = ComputeProfile::alexnet();
        assert_eq!(p.warmup_mult(0), p.warmup_factor);
        assert!(p.warmup_mult(1) > 1.0);
        assert!(p.warmup_mult(1) < p.warmup_factor);
        assert_eq!(p.warmup_mult(p.warmup_steps), 1.0);
        assert_eq!(p.warmup_mult(1000), 1.0);
        // `none` has no warm-up and zero cost.
        let none = ComputeProfile::none();
        assert_eq!(none.warmup_mult(0), 1.0);
        assert_eq!(none.step_secs(1024), 0.0);
    }

    #[test]
    fn execute_advances_the_virtual_clock_exactly() {
        let clock = Clock::virt();
        let accel = AccelModel::new(
            ComputeProfile::micro(),
            AccelTier::by_name("k80").unwrap(),
            16,
            1.0,
            clock.clone(),
        )
        .unwrap();
        let _reg = clock.enter();
        let t0 = clock.now();
        let d0 = accel.execute(0);
        let d1 = accel.execute(1);
        assert!((clock.now() - t0 - (d0 + d1)).abs() < 1e-12);
        assert!(d0 > d1, "warm-up step must be slower");
        assert_eq!(accel.total_secs(2), d0 + d1);
        // Zero-cost profile: no sleep, no time.
        let none = AccelModel::new(
            ComputeProfile::none(),
            AccelTier::by_name("k80").unwrap(),
            16,
            1.0,
            clock.clone(),
        )
        .unwrap();
        let t1 = clock.now();
        assert_eq!(none.execute(0), 0.0);
        assert_eq!(clock.now(), t1);
    }

    #[test]
    fn rejects_degenerate_parameters() {
        let clock = Clock::virt();
        assert!(AccelModel::new(
            ComputeProfile::micro(),
            AccelTier::by_name("k80").unwrap(),
            0,
            1.0,
            clock.clone(),
        )
        .is_err());
        assert!(AccelModel::new(
            ComputeProfile::micro(),
            AccelTier::by_name("k80").unwrap(),
            8,
            0.0,
            clock,
        )
        .is_err());
    }
}
