//! Per-step phase records and their roll-up summary.
//!
//! Each training step decomposes into the three phases the paper's
//! instrumentation separates: waiting on the input pipeline, occupying
//! the accelerator, and stalling on a synchronous checkpoint.  One
//! [`StepRecord`] per step flows into trace files (schema v4 lines
//! tagged `"rec":"step"`, appended after the request events) and into
//! the [`StepSummary`] printed by `--engine-stats`-style reports:
//! stall fraction, overlap fraction, and the effective I/O cost per
//! step — the quantity the paper shows the prefetcher driving to
//! zero.

use anyhow::{anyhow, Context, Result};

use crate::util::json::{obj, to_string, Json};

/// JSONL discriminator key/value marking a step-record line in a
/// trace file (request-event lines have no `rec` key).
pub const STEP_REC_KEY: &str = "rec";
pub const STEP_REC_VALUE: &str = "step";

/// One training step's phase breakdown, in clock seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    pub step: u64,
    /// Step start, relative to the loop start.
    pub start_secs: f64,
    /// Time blocked waiting for the input pipeline to produce a batch.
    pub input_wait_secs: f64,
    /// Modelled (or measured) accelerator occupancy.
    pub compute_secs: f64,
    /// Synchronous checkpoint pause attributed to this step.
    pub ckpt_stall_secs: f64,
    /// Images consumed by this step.
    pub images: u64,
}

impl StepRecord {
    /// Total step duration (the phases are serial on the step thread).
    pub fn step_secs(&self) -> f64 {
        self.input_wait_secs + self.compute_secs + self.ckpt_stall_secs
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            (STEP_REC_KEY, Json::Str(STEP_REC_VALUE.into())),
            ("i", Json::Num(self.step as f64)),
            ("t", Json::Num(self.start_secs)),
            ("w", Json::Num(self.input_wait_secs)),
            ("c", Json::Num(self.compute_secs)),
            ("k", Json::Num(self.ckpt_stall_secs)),
            ("n", Json::Num(self.images as f64)),
        ])
    }

    pub fn to_jsonl(&self) -> String {
        to_string(&self.to_json())
    }

    /// Whether a parsed trace line is a step record.
    pub fn is_step_line(v: &Json) -> bool {
        v.get(STEP_REC_KEY).and_then(Json::as_str) == Some(STEP_REC_VALUE)
    }

    pub fn from_json(v: &Json) -> Result<StepRecord> {
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("step record missing '{key}'"))
        };
        Ok(StepRecord {
            step: num("i")? as u64,
            start_secs: num("t")?,
            input_wait_secs: num("w")?,
            compute_secs: num("c")?,
            ckpt_stall_secs: num("k").context("step record")?,
            images: num("n")? as u64,
        })
    }
}

/// Aggregates over a run's [`StepRecord`]s — the per-step analogue of
/// the engine's `--engine-stats` block.
#[derive(Debug, Clone, PartialEq)]
pub struct StepSummary {
    pub steps: u64,
    pub images: u64,
    /// Sum of step durations (== loop wall time on the step thread).
    pub total_secs: f64,
    pub input_wait_secs: f64,
    pub compute_secs: f64,
    pub ckpt_stall_secs: f64,
    pub mean_step_secs: f64,
    /// Fraction of the loop NOT overlapped with compute: (input wait
    /// + checkpoint stall) / total.  The paper's prefetcher drives
    /// this to ~0.
    pub stall_frac: f64,
    /// Fraction of the loop the accelerator was busy: compute / total.
    pub overlap_frac: f64,
    /// Stall time amortized per step — the *effective* cost of I/O
    /// after overlap, in seconds.
    pub effective_io_secs_per_step: f64,
    pub images_per_sec: f64,
}

impl StepSummary {
    pub fn from_records(records: &[StepRecord]) -> StepSummary {
        let steps = records.len() as u64;
        let images: u64 = records.iter().map(|r| r.images).sum();
        let input_wait_secs: f64 =
            records.iter().map(|r| r.input_wait_secs).sum();
        let compute_secs: f64 = records.iter().map(|r| r.compute_secs).sum();
        let ckpt_stall_secs: f64 =
            records.iter().map(|r| r.ckpt_stall_secs).sum();
        let total_secs = input_wait_secs + compute_secs + ckpt_stall_secs;
        let stall = input_wait_secs + ckpt_stall_secs;
        let frac = |num: f64| if total_secs > 0.0 { num / total_secs } else { 0.0 };
        StepSummary {
            steps,
            images,
            total_secs,
            input_wait_secs,
            compute_secs,
            ckpt_stall_secs,
            mean_step_secs: if steps > 0 {
                total_secs / steps as f64
            } else {
                0.0
            },
            stall_frac: frac(stall),
            overlap_frac: frac(compute_secs),
            effective_io_secs_per_step: if steps > 0 {
                stall / steps as f64
            } else {
                0.0
            },
            images_per_sec: if total_secs > 0.0 {
                images as f64 / total_secs
            } else {
                0.0
            },
        }
    }

    /// Mean step duration over the post-warm-up tail (`skip` leading
    /// steps excluded) — what the paper averages after discarding the
    /// first steps.
    pub fn steady_mean_step_secs(records: &[StepRecord], skip: usize) -> f64 {
        let tail = &records[skip.min(records.len())..];
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().map(StepRecord::step_secs).sum::<f64>()
            / tail.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, w: f64, c: f64, k: f64) -> StepRecord {
        StepRecord {
            step,
            start_secs: step as f64 * 0.1,
            input_wait_secs: w,
            compute_secs: c,
            ckpt_stall_secs: k,
            images: 32,
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let r = rec(7, 0.012345678901, 0.1, 0.00025);
        let line = r.to_jsonl();
        let v = Json::parse(&line).unwrap();
        assert!(StepRecord::is_step_line(&v));
        assert_eq!(StepRecord::from_json(&v).unwrap(), r);
        // Request-event-shaped lines are not step lines.
        let ev = Json::parse(r#"{"seq":0,"dev":"ssd","bytes":10}"#).unwrap();
        assert!(!StepRecord::is_step_line(&ev));
        // Missing keys are an error, not a default.
        let bad = Json::parse(r#"{"rec":"step","i":1}"#).unwrap();
        assert!(StepRecord::from_json(&bad).is_err());
    }

    #[test]
    fn summary_fractions_partition_the_loop() {
        let records =
            vec![rec(0, 0.02, 0.08, 0.0), rec(1, 0.0, 0.08, 0.02)];
        let s = StepSummary::from_records(&records);
        assert_eq!(s.steps, 2);
        assert_eq!(s.images, 64);
        assert!((s.total_secs - 0.2).abs() < 1e-12);
        assert!((s.mean_step_secs - 0.1).abs() < 1e-12);
        assert!((s.stall_frac - 0.2).abs() < 1e-12);
        assert!((s.overlap_frac - 0.8).abs() < 1e-12);
        assert!((s.stall_frac + s.overlap_frac - 1.0).abs() < 1e-12);
        assert!((s.effective_io_secs_per_step - 0.02).abs() < 1e-12);
        assert!((s.images_per_sec - 320.0).abs() < 1e-9);
    }

    #[test]
    fn empty_and_steady_tail_edges() {
        let s = StepSummary::from_records(&[]);
        assert_eq!(s.steps, 0);
        assert_eq!(s.mean_step_secs, 0.0);
        assert_eq!(s.stall_frac, 0.0);
        let records = vec![rec(0, 0.5, 0.1, 0.0), rec(1, 0.0, 0.1, 0.0)];
        let steady = StepSummary::steady_mean_step_secs(&records, 1);
        assert!((steady - 0.1).abs() < 1e-12);
        assert_eq!(StepSummary::steady_mean_step_secs(&records, 10), 0.0);
    }
}
