//! The accelerator compute model (DESIGN.md §16).
//!
//! Closes the loop the stubbed XLA runtime leaves open: a calibrated
//! discrete-event [`AccelModel`] occupies the [`Clock`](crate::storage::Clock)
//! for each training step's modelled duration, the
//! [`run_loop`] driver couples it to the input pipeline through a
//! clock-aware bounded prefetch queue, and every step emits a
//! [`StepRecord`] (input wait / compute / checkpoint stall) that
//! flows into trace files (schema v4) and stall/overlap summaries.
//! This is the machinery behind `dlio train --compute model`,
//! `dlio ckpt-study --compute model`, and `dlio overlap-sweep` — and
//! the bench gate reproducing the paper's prefetcher-overlap result.

pub mod accel;
pub mod step;
pub mod train_loop;

pub use accel::{
    AccelModel, AccelTier, ComputeProfile, LayerCost, PROFILE_NAMES,
    TIER_NAMES,
};
pub use step::{StepRecord, StepSummary};
pub use train_loop::{run_loop, LoopConfig, LoopOutcome};
