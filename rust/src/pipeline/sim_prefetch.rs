//! Clock-aware bounded prefetch — `tf.data.Dataset.prefetch(n)` for
//! simulated time.
//!
//! [`Prefetch`](super::prefetch::Prefetch) blocks its producer thread
//! on a std `Condvar` the [`Clock`] cannot see, so a virtual-clock
//! run would stall (the clock only advances when every registered
//! thread is parked *through the clock*).  [`SimPrefetch`] is the
//! same bounded producer/consumer queue rebuilt on the clock seam:
//! the producer registers via [`Clock::enter`] and both sides block
//! on [`SimCondvar`], which makes prefetch overlap exact and
//! bit-deterministic under `--clock virtual` while behaving like the
//! std prefetcher on the wall clock.
//!
//! Depth semantics match tf.data: `depth` completed elements may sit
//! in the queue while the producer works on one more.  `depth == 0`
//! is fully synchronous — no thread, the consumer pulls upstream
//! directly (the `--prefetch 0` baseline that pays compute + input
//! additively).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::storage::{Clock, SimCondvar};

use super::dataset::Dataset;

struct State<T> {
    queue: VecDeque<Option<Result<T>>>,
    /// Producer exhausted upstream (after draining `queue`, `next`
    /// returns `None`).
    done: bool,
    /// Consumer dropped; producer must exit without pushing.
    shutdown: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    capacity: usize,
    /// Signalled when the queue gains an element (or `done` flips).
    filled: SimCondvar,
    /// Signalled when the queue loses an element (or on shutdown).
    drained: SimCondvar,
}

enum Mode<T: Send + 'static> {
    /// `depth == 0`: pull upstream on the consumer thread.
    Passthrough(Box<dyn Dataset<Item = T>>),
    Threaded {
        shared: Arc<Shared<T>>,
        handle: Option<JoinHandle<()>>,
    },
}

/// Clock-aware `prefetch(depth)` — see the module docs.
pub struct SimPrefetch<T: Send + 'static> {
    clock: Clock,
    mode: Mode<T>,
}

impl<T: Send + 'static> SimPrefetch<T> {
    /// Spawn the producer over `upstream`.  Blocks until the producer
    /// thread is *registered* with the clock: without the handshake a
    /// registered consumer could park and let virtual time advance
    /// while the producer is still spawning, serializing the very
    /// overlap this queue exists to model (and breaking run-to-run
    /// determinism).
    pub fn new<D>(upstream: D, depth: usize, clock: &Clock) -> SimPrefetch<T>
    where
        D: Dataset<Item = T> + 'static,
    {
        if depth == 0 {
            return SimPrefetch {
                clock: clock.clone(),
                mode: Mode::Passthrough(Box::new(upstream)),
            };
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queue: VecDeque::with_capacity(depth),
                done: false,
                shutdown: false,
            }),
            capacity: depth,
            filled: SimCondvar::new(),
            drained: SimCondvar::new(),
        });
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let handle = {
            let shared = Arc::clone(&shared);
            let clock = clock.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let _reg = clock.enter();
                barrier.wait();
                producer_loop(upstream, &shared, &clock);
            })
        };
        barrier.wait();
        SimPrefetch {
            clock: clock.clone(),
            mode: Mode::Threaded { shared, handle: Some(handle) },
        }
    }

    /// Completed elements currently buffered (0 for passthrough).
    pub fn buffered(&self) -> usize {
        match &self.mode {
            Mode::Passthrough(_) => 0,
            Mode::Threaded { shared, .. } => {
                shared.state.lock().unwrap().queue.len()
            }
        }
    }
}

fn producer_loop<D: Dataset>(
    mut upstream: D,
    shared: &Shared<D::Item>,
    clock: &Clock,
) {
    loop {
        // Pull outside the lock — this is the fill-ahead: the element
        // in the producer's hand is the `+1` of the depth semantics.
        let item = upstream.next();
        let exhausted = item.is_none();
        let mut st = shared.state.lock().unwrap();
        while st.queue.len() >= shared.capacity && !st.shutdown {
            st = shared.drained.wait(clock, &shared.state, st);
        }
        if st.shutdown {
            return;
        }
        if exhausted {
            st.done = true;
            shared.filled.notify_all(clock);
            return;
        }
        st.queue.push_back(item);
        shared.filled.notify_one(clock);
    }
}

impl<T: Send + 'static> Dataset for SimPrefetch<T> {
    type Item = T;

    fn next(&mut self) -> Option<Result<T>> {
        match &mut self.mode {
            Mode::Passthrough(upstream) => upstream.next(),
            Mode::Threaded { shared, .. } => {
                let mut st = shared.state.lock().unwrap();
                while st.queue.is_empty() && !st.done {
                    st = shared.filled.wait(&self.clock, &shared.state, st);
                }
                match st.queue.pop_front() {
                    Some(item) => {
                        shared.drained.notify_one(&self.clock);
                        item
                    }
                    None => None, // done and drained
                }
            }
        }
    }
}

impl<T: Send + 'static> Drop for SimPrefetch<T> {
    fn drop(&mut self) {
        if let Mode::Threaded { shared, handle } = &mut self.mode {
            {
                let mut st = shared.state.lock().unwrap();
                st.shutdown = true;
                st.queue.clear();
                shared.drained.notify_all(&self.clock);
            }
            if let Some(h) = handle.take() {
                // Joining is a foreign block: drop this thread's
                // registration (if any) so virtual time keeps moving
                // while the producer finishes its in-flight pull.
                let _suspend = self.clock.suspend();
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::dataset::collect;
    use crate::pipeline::source::from_vec;

    /// A source that sleeps `secs` of clock time per element.
    struct Slow {
        left: usize,
        secs: f64,
        clock: Clock,
    }

    impl Dataset for Slow {
        type Item = u64;

        fn next(&mut self) -> Option<Result<u64>> {
            if self.left == 0 {
                return None;
            }
            self.left -= 1;
            self.clock.sleep_secs(self.secs);
            Some(Ok(self.left as u64))
        }
    }

    #[test]
    fn preserves_order_and_exhaustion() {
        let clock = Clock::wall();
        for depth in [0usize, 1, 3, 16] {
            let d =
                SimPrefetch::new(from_vec(vec![1, 2, 3, 4, 5]), depth, &clock);
            assert_eq!(collect(d).unwrap(), vec![1, 2, 3, 4, 5]);
        }
    }

    #[test]
    fn errors_flow_through_as_elements() {
        struct Failing(usize);
        impl Dataset for Failing {
            type Item = u32;
            fn next(&mut self) -> Option<Result<u32>> {
                self.0 += 1;
                match self.0 {
                    1 => Some(Ok(10)),
                    2 => Some(Err(anyhow::anyhow!("boom"))),
                    3 => Some(Ok(30)),
                    _ => None,
                }
            }
        }
        let clock = Clock::wall();
        let mut d = SimPrefetch::new(Failing(0), 2, &clock);
        assert_eq!(d.next().unwrap().unwrap(), 10);
        assert!(d.next().unwrap().is_err());
        assert_eq!(d.next().unwrap().unwrap(), 30);
        assert!(d.next().is_none());
    }

    #[test]
    fn overlaps_producer_and_consumer_on_the_virtual_clock() {
        // 8 elements at 10 ms production + 10 ms consumption: without
        // overlap 160 ms, with a depth-2 queue the steady state is
        // max(produce, consume) per element — expect ~90 ms (first
        // element's production is the only unoverlapped pull).
        let clock = Clock::virt();
        let _reg = clock.enter();
        let src = Slow { left: 8, secs: 0.01, clock: clock.clone() };
        let mut d = SimPrefetch::new(src, 2, &clock);
        let t0 = clock.now();
        let mut n = 0;
        while let Some(item) = d.next() {
            item.unwrap();
            clock.sleep_secs(0.01);
            n += 1;
        }
        let elapsed = clock.now() - t0;
        assert_eq!(n, 8);
        assert!(
            (elapsed - 0.09).abs() < 1e-9,
            "expected full overlap (~0.09 s), got {elapsed}"
        );
    }

    #[test]
    fn synchronous_depth_zero_pays_the_additive_cost() {
        let clock = Clock::virt();
        let _reg = clock.enter();
        let src = Slow { left: 4, secs: 0.01, clock: clock.clone() };
        let mut d = SimPrefetch::new(src, 0, &clock);
        let t0 = clock.now();
        while let Some(item) = d.next() {
            item.unwrap();
            clock.sleep_secs(0.01);
        }
        let elapsed = clock.now() - t0;
        assert!(
            (elapsed - 0.08).abs() < 1e-9,
            "expected additive (~0.08 s), got {elapsed}"
        );
    }

    #[test]
    fn virtual_clock_runs_are_bit_identical() {
        let run = || -> Vec<f64> {
            let clock = Clock::virt();
            let _reg = clock.enter();
            let src = Slow { left: 6, secs: 0.013, clock: clock.clone() };
            let mut d = SimPrefetch::new(src, 3, &clock);
            let mut stamps = Vec::new();
            while let Some(item) = d.next() {
                item.unwrap();
                clock.sleep_secs(0.007);
                stamps.push(clock.now());
            }
            stamps
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 6);
        // Bit-identical, not approximately equal.
        assert_eq!(a, b);
    }

    #[test]
    fn drop_mid_stream_joins_the_producer() {
        let clock = Clock::virt();
        let _reg = clock.enter();
        let src = Slow { left: 100, secs: 0.001, clock: clock.clone() };
        let mut d = SimPrefetch::new(src, 4, &clock);
        assert!(d.next().is_some());
        drop(d); // must not hang or leak the producer
    }
}
