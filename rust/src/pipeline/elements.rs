//! Element types flowing through the experiment pipelines.

use anyhow::{bail, Result};

/// One preprocessed training sample: normalized f32 pixels at the
/// model's input geometry, plus its label.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcessedImage {
    /// `[size][size][3]` row-major normalized pixels.
    pub pixels: Vec<f32>,
    pub size: u32,
    pub label: u32,
    /// Bytes read from storage to produce this sample (metrics).
    pub bytes_read: u64,
}

/// A batch assembled for the training step: contiguous NHWC images and
/// one-hot labels, the exact layouts the train-step HLO expects.
#[derive(Debug, Clone)]
pub struct ImageBatch {
    /// `[batch][size][size][3]`.
    pub images: Vec<f32>,
    /// `[batch][num_classes]` one-hot.
    pub labels: Vec<f32>,
    pub batch: usize,
    pub size: u32,
    pub num_classes: u32,
    pub bytes_read: u64,
}

impl ImageBatch {
    /// Assemble a batch from per-sample elements (the collection step
    /// the paper's `tf.dataset.batch()` performs).
    pub fn assemble(samples: Vec<ProcessedImage>, num_classes: u32)
        -> Result<ImageBatch>
    {
        if samples.is_empty() {
            bail!("cannot assemble an empty batch");
        }
        let size = samples[0].size;
        let per = (size * size * 3) as usize;
        let b = samples.len();
        let mut images = Vec::with_capacity(b * per);
        let mut labels = vec![0f32; b * num_classes as usize];
        let mut bytes_read = 0;
        for (i, s) in samples.into_iter().enumerate() {
            if s.size != size {
                bail!("mixed sizes in batch: {} vs {}", s.size, size);
            }
            if s.pixels.len() != per {
                bail!("bad pixel count {} (want {per})", s.pixels.len());
            }
            if s.label >= num_classes {
                bail!("label {} out of range {num_classes}", s.label);
            }
            images.extend_from_slice(&s.pixels);
            labels[i * num_classes as usize + s.label as usize] = 1.0;
            bytes_read += s.bytes_read;
        }
        Ok(ImageBatch { images, labels, batch: b, size, num_classes,
                        bytes_read })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(label: u32, size: u32, fill: f32) -> ProcessedImage {
        ProcessedImage {
            pixels: vec![fill; (size * size * 3) as usize],
            size,
            label,
            bytes_read: 100,
        }
    }

    #[test]
    fn assembles_contiguous_nhwc_and_onehot() {
        let b = ImageBatch::assemble(
            vec![sample(1, 4, 0.5), sample(3, 4, -0.5)], 5).unwrap();
        assert_eq!(b.batch, 2);
        assert_eq!(b.images.len(), 2 * 4 * 4 * 3);
        assert_eq!(b.images[0], 0.5);
        assert_eq!(b.images[4 * 4 * 3], -0.5);
        assert_eq!(b.labels.len(), 10);
        assert_eq!(b.labels[1], 1.0);
        assert_eq!(b.labels[5 + 3], 1.0);
        assert_eq!(b.labels.iter().sum::<f32>(), 2.0);
        assert_eq!(b.bytes_read, 200);
    }

    #[test]
    fn rejects_empty() {
        assert!(ImageBatch::assemble(vec![], 5).is_err());
    }

    #[test]
    fn rejects_mixed_sizes() {
        assert!(
            ImageBatch::assemble(vec![sample(0, 4, 0.0), sample(0, 8, 0.0)], 5)
                .is_err()
        );
    }

    #[test]
    fn rejects_out_of_range_label() {
        assert!(ImageBatch::assemble(vec![sample(7, 4, 0.0)], 5).is_err());
    }

    #[test]
    fn rejects_bad_pixel_count() {
        let mut s = sample(0, 4, 0.0);
        s.pixels.pop();
        assert!(ImageBatch::assemble(vec![s], 5).is_err());
    }
}
