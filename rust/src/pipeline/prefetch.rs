//! `tf.data.Dataset.prefetch(n)` — the paper's key optimization
//! (§II-A.2, Figs. 6-8).
//!
//! Implemented exactly as the paper describes TensorFlow's runtime:
//! *"a background thread and a consumption function.  The thread
//! maintains a buffer which stores elements that are prefetched from
//! the upstream operation.  The buffer uses a double ended queue ...
//! The thread itself contains an infinite loop which waits for a
//! condition variable.  When a Tensor is consumed from the buffer ...
//! the thread is notified through the condition variable and wakes up
//! to fetch another element from upstream."*
//!
//! `buffer_size` = number of elements kept ready; `prefetch(0)` is a
//! no-op passthrough (the paper's "prefetch disabled" arm).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::dataset::{BoxedDataset, Dataset};

struct PrefetchState<T> {
    buffer: VecDeque<Option<Result<T>>>, // None = upstream exhausted
    shutdown: bool,
}

struct Shared<T> {
    state: Mutex<PrefetchState<T>>,
    /// Consumer waits here for elements.
    filled: Condvar,
    /// Producer thread waits here for buffer space.
    drained: Condvar,
    capacity: usize,
}

/// Background-thread prefetcher.  With `buffer_size == 0` it degrades
/// to a synchronous passthrough (no thread).
pub struct Prefetch<T: Send + 'static> {
    shared: Option<Arc<Shared<T>>>,
    /// Passthrough upstream when disabled.
    passthrough: Option<BoxedDataset<T>>,
    producer: Option<JoinHandle<()>>,
    exhausted: bool,
}

impl<T: Send + 'static> Prefetch<T> {
    pub fn new<D>(upstream: D, buffer_size: usize) -> Self
    where
        D: Dataset<Item = T> + 'static,
    {
        if buffer_size == 0 {
            return Prefetch {
                shared: None,
                passthrough: Some(Box::new(upstream)),
                producer: None,
                exhausted: false,
            };
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(PrefetchState {
                buffer: VecDeque::with_capacity(buffer_size + 1),
                shutdown: false,
            }),
            filled: Condvar::new(),
            drained: Condvar::new(),
            capacity: buffer_size,
        });
        let sh = Arc::clone(&shared);
        let mut upstream: BoxedDataset<T> = Box::new(upstream);
        let producer = std::thread::Builder::new()
            .name("dlio-prefetch".into())
            .spawn(move || {
                // The paper's "infinite loop which waits for a
                // condition variable".
                loop {
                    // Pull outside the lock so the consumer can drain
                    // concurrently with upstream work.
                    let item = upstream.next();
                    let is_end = item.is_none();
                    let mut st = sh.state.lock().unwrap();
                    while st.buffer.len() >= sh.capacity && !st.shutdown {
                        st = sh.drained.wait(st).unwrap();
                    }
                    if st.shutdown {
                        return;
                    }
                    st.buffer.push_back(item);
                    drop(st);
                    sh.filled.notify_one();
                    if is_end {
                        return;
                    }
                }
            })
            .expect("spawn prefetch thread");
        Prefetch {
            shared: Some(shared),
            passthrough: None,
            producer: Some(producer),
            exhausted: false,
        }
    }

    /// Elements currently buffered and ready (for tests/metrics).
    pub fn buffered(&self) -> usize {
        match &self.shared {
            Some(sh) => sh.state.lock().unwrap().buffer.len(),
            None => 0,
        }
    }
}

impl<T: Send + 'static> Dataset for Prefetch<T> {
    type Item = T;

    fn next(&mut self) -> Option<Result<T>> {
        if let Some(up) = self.passthrough.as_mut() {
            return up.next();
        }
        if self.exhausted {
            return None;
        }
        let sh = self.shared.as_ref().expect("enabled prefetcher");
        let mut st = sh.state.lock().unwrap();
        loop {
            if let Some(slot) = st.buffer.pop_front() {
                drop(st);
                // "the thread is notified through the condition
                // variable and wakes up to fetch another element".
                sh.drained.notify_one();
                match slot {
                    None => {
                        self.exhausted = true;
                        return None;
                    }
                    Some(item) => return Some(item),
                }
            }
            st = sh.filled.wait(st).unwrap();
        }
    }
}

impl<T: Send + 'static> Drop for Prefetch<T> {
    fn drop(&mut self) {
        if let Some(sh) = &self.shared {
            let mut st = sh.state.lock().unwrap();
            st.shutdown = true;
            drop(st);
            sh.drained.notify_all();
        }
        if let Some(p) = self.producer.take() {
            let _ = p.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{collect, Dataset, DatasetExt};
    use super::super::source::from_vec;
    use std::time::Duration;

    #[test]
    fn passthrough_when_disabled() {
        let d = from_vec(vec![1, 2, 3]).prefetch(0);
        assert_eq!(collect(d).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn preserves_order_and_completeness() {
        let d = from_vec((0..500).collect::<Vec<i32>>()).prefetch(4);
        assert_eq!(collect(d).unwrap(), (0..500).collect::<Vec<i32>>());
    }

    #[test]
    fn buffer_fills_ahead_of_consumption() {
        let d = from_vec((0..10).collect::<Vec<i32>>()).prefetch(3);
        // Give the producer time to fill the buffer.
        std::thread::sleep(Duration::from_millis(100));
        assert!(d.buffered() >= 3, "buffered={}", d.buffered());
        drop(d);
    }

    #[test]
    fn overlaps_production_with_consumption() {
        // Producer takes 30 ms/item; consumer takes 30 ms/item.
        // With prefetch(1) the two must overlap: total ≈ n*30, not n*60.
        let n = 10u64;
        let produce = from_vec((0..n).collect::<Vec<u64>>())
            .parallel_map(1, |x| {
                std::thread::sleep(Duration::from_millis(30));
                Ok(x)
            });
        let mut d = produce.prefetch(1);
        std::thread::sleep(Duration::from_millis(50)); // warm the buffer
        let t0 = std::time::Instant::now();
        while let Some(item) = d.next() {
            item.unwrap();
            std::thread::sleep(Duration::from_millis(30)); // "compute"
        }
        let total = t0.elapsed().as_millis() as u64;
        // Serial would be ≈ 600 ms; overlapped ≈ 330 ms.
        assert!(total < 480, "no overlap: {total} ms");
    }

    #[test]
    fn drop_mid_stream_shuts_down_producer() {
        let mut d = from_vec((0..1000).collect::<Vec<i32>>()).prefetch(2);
        let _ = d.next();
        drop(d); // must not hang
    }

    #[test]
    fn empty_upstream() {
        let d = from_vec(Vec::<i32>::new()).prefetch(2);
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn next_after_exhaustion_stays_none() {
        let mut d = from_vec(vec![1]).prefetch(2);
        assert!(d.next().is_some());
        assert!(d.next().is_none());
        assert!(d.next().is_none());
    }
}
