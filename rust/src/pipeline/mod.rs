//! A faithful rust port of the `tf.data` input pipeline (§II-A):
//! source → shuffle → parallel map → ignore_errors → batch → prefetch,
//! plus the element/batch types the experiments flow through it.

pub mod batch;
pub mod dataset;
pub mod elements;
pub mod ignore_errors;
pub mod map;
pub mod prefetch;
pub mod shuffle;
pub mod sim_prefetch;
pub mod source;

pub use dataset::{collect, BoxedDataset, Dataset, DatasetExt};
pub use sim_prefetch::SimPrefetch;
pub use elements::{ImageBatch, ProcessedImage};
pub use source::{
    from_manifest, from_vec, read_ahead, sharded_reader,
    sharded_reader_hier, LoadedSample, ShardedReader,
};
