//! `tf.contrib.data.ignore_errors()` (§III-A).
//!
//! The paper applies this after the map *"to avoid exceptions in the
//! mapped function from terminating all execution ... useful in
//! processing large amounts of data where data completeness is not
//! guaranteed"*.  Failed elements are silently dropped (with a counter
//! for observability).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::dataset::Dataset;

pub struct IgnoreErrors<D: Dataset> {
    inner: D,
    dropped: Arc<AtomicU64>,
}

impl<D: Dataset> IgnoreErrors<D> {
    pub fn new(inner: D) -> Self {
        IgnoreErrors { inner, dropped: Arc::new(AtomicU64::new(0)) }
    }

    /// Shared counter of dropped elements.
    pub fn dropped_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.dropped)
    }
}

impl<D: Dataset> Dataset for IgnoreErrors<D> {
    type Item = D::Item;

    fn next(&mut self) -> Option<Result<D::Item>> {
        loop {
            match self.inner.next() {
                None => return None,
                Some(Ok(x)) => return Some(Ok(x)),
                Some(Err(_)) => {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{collect, DatasetExt};
    use super::super::source::from_vec;
    use anyhow::anyhow;
    use std::sync::atomic::Ordering;

    #[test]
    fn drops_errors_keeps_order() {
        let d = from_vec((0..10).collect::<Vec<i32>>())
            .parallel_map(2, |x| {
                if x % 3 == 0 {
                    Err(anyhow!("bad {x}"))
                } else {
                    Ok(x)
                }
            })
            .ignore_errors();
        let counter = d.dropped_counter();
        let out = collect(d).unwrap();
        assert_eq!(out, vec![1, 2, 4, 5, 7, 8]);
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn all_errors_yields_empty() {
        let d = from_vec(vec![1, 2, 3])
            .parallel_map(1, |_| Err::<i32, _>(anyhow!("x")))
            .ignore_errors();
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn no_errors_is_identity() {
        let d = from_vec(vec![1, 2, 3]).parallel_map(1, Ok).ignore_errors();
        assert_eq!(collect(d).unwrap(), vec![1, 2, 3]);
    }
}
