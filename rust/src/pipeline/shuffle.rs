//! `tf.data.Dataset.shuffle(buffer_size)` — reservoir shuffling.
//!
//! tf.data semantics: keep a buffer of `buffer_size` elements; on each
//! pull, emit a uniformly random buffered element and refill from
//! upstream.  `buffer_size >= dataset` gives a perfect shuffle; smaller
//! buffers trade randomness for memory, exactly as in TensorFlow.

use anyhow::Result;

use super::dataset::Dataset;
use crate::util::Rng;

pub struct Shuffle<D: Dataset> {
    inner: D,
    buffer: Vec<D::Item>,
    capacity: usize,
    rng: Rng,
    filled: bool,
    upstream_done: bool,
}

impl<D: Dataset> Shuffle<D> {
    pub fn new(inner: D, buffer_size: usize, rng: Rng) -> Self {
        Shuffle {
            inner,
            buffer: Vec::with_capacity(buffer_size.max(1)),
            capacity: buffer_size.max(1),
            rng,
            filled: false,
            upstream_done: false,
        }
    }

    fn fill(&mut self) -> Option<Result<()>> {
        while !self.upstream_done && self.buffer.len() < self.capacity {
            match self.inner.next() {
                Some(Ok(item)) => self.buffer.push(item),
                Some(Err(e)) => return Some(Err(e)),
                None => self.upstream_done = true,
            }
        }
        Some(Ok(()))
    }
}

impl<D: Dataset> Dataset for Shuffle<D> {
    type Item = D::Item;

    fn next(&mut self) -> Option<Result<D::Item>> {
        if !self.filled {
            if let Some(Err(e)) = self.fill() {
                return Some(Err(e));
            }
            self.filled = true;
        }
        if self.buffer.is_empty() {
            return None;
        }
        let idx = self.rng.index(self.buffer.len());
        let item = self.buffer.swap_remove(idx);
        // Refill the slot from upstream.
        if !self.upstream_done {
            match self.inner.next() {
                Some(Ok(x)) => self.buffer.push(x),
                Some(Err(e)) => return Some(Err(e)),
                None => self.upstream_done = true,
            }
        }
        Some(Ok(item))
    }
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{collect, DatasetExt};
    use super::super::source::from_vec;
    use super::*;

    #[test]
    fn is_a_permutation() {
        let src: Vec<u32> = (0..500).collect();
        let d = from_vec(src.clone()).shuffle(64, Rng::new(1));
        let mut out = collect(d).unwrap();
        out.sort();
        assert_eq!(out, src);
    }

    #[test]
    fn full_buffer_shuffles_uniformly_enough() {
        // First emitted element over many seeds should vary.
        let mut firsts = std::collections::HashSet::new();
        for seed in 0..20 {
            let d = from_vec((0..50).collect::<Vec<_>>())
                .shuffle(50, Rng::new(seed));
            let out = collect(d).unwrap();
            firsts.insert(out[0]);
        }
        assert!(firsts.len() > 5, "only {} distinct firsts", firsts.len());
    }

    #[test]
    fn buffer_one_is_identity() {
        // A 1-element reservoir cannot reorder.
        let d = from_vec(vec![1, 2, 3, 4]).shuffle(1, Rng::new(9));
        assert_eq!(collect(d).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn small_buffer_limits_displacement() {
        // With buffer B, element i cannot appear before pull i - B.
        let n = 200;
        let b = 8;
        let d = from_vec((0..n).collect::<Vec<_>>()).shuffle(b, Rng::new(3));
        let out = collect(d).unwrap();
        for (pos, &v) in out.iter().enumerate() {
            assert!(v <= (pos + b) as i32, "v={v} at pos={pos}");
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let mk = || from_vec((0..100).collect::<Vec<_>>())
            .shuffle(32, Rng::new(77));
        assert_eq!(collect(mk()).unwrap(), collect(mk()).unwrap());
    }

    #[test]
    fn empty_upstream() {
        let d = from_vec(Vec::<i32>::new()).shuffle(16, Rng::new(0));
        assert!(collect(d).unwrap().is_empty());
    }
}
