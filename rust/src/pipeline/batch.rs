//! `tf.data.Dataset.batch(batch_size)` (§II-A.1).
//!
//! *"This operation accumulates the number of training samples from
//! the upstream operation and forms a batch."*  Emits `Vec<Item>` of
//! length `batch_size`; the trailing partial batch is emitted or
//! dropped per `drop_remainder`, as in TensorFlow.

use anyhow::Result;

use super::dataset::Dataset;

pub struct BatchDataset<D: Dataset> {
    inner: D,
    batch_size: usize,
    drop_remainder: bool,
    done: bool,
}

impl<D: Dataset> BatchDataset<D> {
    pub fn new(inner: D, batch_size: usize, drop_remainder: bool) -> Self {
        BatchDataset {
            inner,
            batch_size: batch_size.max(1),
            drop_remainder,
            done: false,
        }
    }
}

impl<D: Dataset> Dataset for BatchDataset<D> {
    type Item = Vec<D::Item>;

    fn next(&mut self) -> Option<Result<Vec<D::Item>>> {
        if self.done {
            return None;
        }
        let mut batch = Vec::with_capacity(self.batch_size);
        while batch.len() < self.batch_size {
            match self.inner.next() {
                Some(Ok(x)) => batch.push(x),
                // An error inside batch assembly surfaces as a batch-
                // level error (TF fails the whole get_next too).
                Some(Err(e)) => return Some(Err(e)),
                None => {
                    self.done = true;
                    if batch.is_empty()
                        || (self.drop_remainder
                            && batch.len() < self.batch_size)
                    {
                        return None;
                    }
                    return Some(Ok(batch));
                }
            }
        }
        Some(Ok(batch))
    }
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{collect, DatasetExt};
    use super::super::source::from_vec;
    use anyhow::anyhow;

    #[test]
    fn exact_batches() {
        let d = from_vec((0..6).collect::<Vec<i32>>()).batch(2, false);
        assert_eq!(
            collect(d).unwrap(),
            vec![vec![0, 1], vec![2, 3], vec![4, 5]]
        );
    }

    #[test]
    fn partial_tail_kept_by_default() {
        let d = from_vec((0..5).collect::<Vec<i32>>()).batch(2, false);
        assert_eq!(collect(d).unwrap(), vec![vec![0, 1], vec![2, 3], vec![4]]);
    }

    #[test]
    fn partial_tail_dropped_when_requested() {
        let d = from_vec((0..5).collect::<Vec<i32>>()).batch(2, true);
        assert_eq!(collect(d).unwrap(), vec![vec![0, 1], vec![2, 3]]);
    }

    #[test]
    fn empty_upstream_yields_nothing() {
        let d = from_vec(Vec::<i32>::new()).batch(4, false);
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn error_fails_the_batch() {
        let d = from_vec(vec![1, 2, 3, 4])
            .parallel_map(1, |x| {
                if x == 2 {
                    Err(anyhow!("bad"))
                } else {
                    Ok(x)
                }
            })
            .batch(2, false);
        assert!(collect(d).is_err());
    }

    #[test]
    fn batch_zero_clamped_to_one() {
        let d = from_vec(vec![1, 2]).batch(0, false);
        assert_eq!(collect(d).unwrap(), vec![vec![1], vec![2]]);
    }
}
