//! Source datasets (`tf.data.Dataset.from_tensor_slices`) and the
//! engine-backed [`ReadAhead`] source that keeps N file reads in
//! flight ahead of the consumer.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use super::dataset::Dataset;
use crate::data::manifest::{Manifest, Sample};
use crate::storage::{PendingRead, StorageSim};

/// A dataset yielding the elements of a vector in order.
pub struct VecSource<T> {
    items: std::vec::IntoIter<T>,
}

/// `from_tensor_slices` over any vector.
pub fn from_vec<T: Send + 'static>(items: Vec<T>) -> VecSource<T> {
    VecSource { items: items.into_iter() }
}

/// The paper's source dataset: the (file path, label) list (Fig. 2).
pub fn from_manifest(m: &Manifest) -> VecSource<Sample> {
    from_vec(m.samples.clone())
}

impl<T: Send + 'static> Dataset for VecSource<T> {
    type Item = T;

    fn next(&mut self) -> Option<Result<T>> {
        self.items.next().map(Ok)
    }
}

/// A sample whose file contents have been fetched.
pub struct LoadedSample {
    pub sample: Sample,
    pub bytes: Vec<u8>,
}

enum ReadSlot {
    /// Read submitted to the engine (or served warm from the cache).
    Submitted(Sample, PendingRead),
    /// Upstream or submission failed; delivered in order as an
    /// element error.
    Failed(anyhow::Error),
}

/// Engine-backed readahead: pulls samples from `upstream` and keeps up
/// to `depth` whole-file reads in flight on the storage engine,
/// yielding (sample, bytes) pairs in input order.
///
/// Unlike `parallel_map(read)`, no OS thread is parked per outstanding
/// read — the requests queue on the per-device engine, which also
/// deepens the device queue the elevator model rewards (§V-A's
/// thread-scaling effect without the threads).
pub struct ReadAhead<D: Dataset<Item = Sample>> {
    upstream: D,
    sim: Arc<StorageSim>,
    depth: usize,
    pending: VecDeque<ReadSlot>,
    upstream_done: bool,
}

/// Keep `depth` reads of `upstream`'s samples in flight (min 1).
pub fn read_ahead<D: Dataset<Item = Sample>>(
    upstream: D,
    sim: Arc<StorageSim>,
    depth: usize,
) -> ReadAhead<D> {
    ReadAhead {
        upstream,
        sim,
        depth: depth.max(1),
        pending: VecDeque::new(),
        upstream_done: false,
    }
}

impl<D: Dataset<Item = Sample>> ReadAhead<D> {
    fn top_up(&mut self) {
        while !self.upstream_done && self.pending.len() < self.depth {
            match self.upstream.next() {
                None => self.upstream_done = true,
                Some(Err(e)) => self.pending.push_back(ReadSlot::Failed(e)),
                Some(Ok(sample)) => {
                    let slot = match self.sim.read_async(&sample.path) {
                        Ok(pr) => ReadSlot::Submitted(sample, pr),
                        Err(e) => ReadSlot::Failed(e),
                    };
                    self.pending.push_back(slot);
                }
            }
        }
    }

    /// Reads currently in flight (tests/metrics).
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }
}

impl<D: Dataset<Item = Sample>> Dataset for ReadAhead<D> {
    type Item = LoadedSample;

    fn next(&mut self) -> Option<Result<LoadedSample>> {
        self.top_up();
        let slot = self.pending.pop_front()?;
        // Refill behind the pop so the window stays full while the
        // caller processes this element.
        self.top_up();
        match slot {
            ReadSlot::Failed(e) => Some(Err(e)),
            ReadSlot::Submitted(sample, pr) => match pr.wait() {
                Ok(bytes) => Some(Ok(LoadedSample { sample, bytes })),
                Err(e) => Some(Err(e)),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::dataset::collect;
    use crate::storage::SimPath;

    #[test]
    fn yields_in_order() {
        let d = from_vec(vec!["a", "b", "c"]);
        assert_eq!(collect(d).unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn empty_source() {
        let d = from_vec(Vec::<u8>::new());
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn manifest_source_preserves_pairs() {
        let m = Manifest {
            samples: vec![
                Sample { path: SimPath::new("d", "0"), label: 5 },
                Sample { path: SimPath::new("d", "1"), label: 6 },
            ],
            num_classes: 10,
            src_size: 32,
        };
        let items = collect(from_manifest(&m)).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].label, 6);
    }

    mod read_ahead_tests {
        use super::super::{read_ahead, LoadedSample};
        use crate::pipeline::dataset::Dataset;
        use crate::pipeline::{from_vec, DatasetExt};
        use crate::data::manifest::Sample;
        use crate::storage::{DeviceModel, SimPath, StorageSim};
        use std::sync::Arc;

        fn sim(tag: &str) -> Arc<StorageSim> {
            let dir = std::env::temp_dir().join(format!(
                "dlio-readahead-test-{tag}-{}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let model = DeviceModel {
                name: "ssd".into(),
                read_bw: 1e9,
                write_bw: 1e9,
                read_lat: 0.0,
                write_lat: 0.0,
                channels: 8,
                elevator: vec![(1, 1.0)],
                time_scale: 1000.0,
            };
            Arc::new(StorageSim::cold(dir, vec![model]).unwrap())
        }

        fn corpus(sim: &StorageSim, n: usize) -> Vec<Sample> {
            (0..n)
                .map(|i| {
                    let p = SimPath::new("ssd", format!("f{i}.bin"));
                    sim.write(&p, &vec![i as u8; 512]).unwrap();
                    Sample { path: p, label: i as u32 }
                })
                .collect()
        }

        #[test]
        fn yields_all_samples_in_order_with_data() {
            let s = sim("order");
            let samples = corpus(&s, 40);
            s.drop_caches();
            let ds = read_ahead(from_vec(samples), Arc::clone(&s), 8);
            let out: Vec<LoadedSample> =
                crate::pipeline::collect(ds).unwrap();
            assert_eq!(out.len(), 40);
            for (i, ls) in out.iter().enumerate() {
                assert_eq!(ls.sample.label, i as u32);
                assert_eq!(ls.bytes, vec![i as u8; 512]);
            }
        }

        #[test]
        fn keeps_depth_reads_in_flight() {
            let s = sim("depth");
            let samples = corpus(&s, 30);
            s.drop_caches();
            let mut ds = read_ahead(from_vec(samples), Arc::clone(&s), 6);
            let first = ds.next().unwrap().unwrap();
            assert_eq!(first.sample.label, 0);
            // After one pop the window is topped back up.
            assert_eq!(ds.in_flight(), 6);
        }

        #[test]
        fn missing_file_is_element_error_not_fatal() {
            let s = sim("missing");
            let mut samples = corpus(&s, 6);
            samples.insert(
                3,
                Sample { path: SimPath::new("ssd", "nope.bin"), label: 99 },
            );
            s.drop_caches();
            let ds = read_ahead(from_vec(samples), Arc::clone(&s), 4)
                .ignore_errors();
            let counter = ds.dropped_counter();
            let out = crate::pipeline::collect(ds).unwrap();
            assert_eq!(out.len(), 6);
            assert_eq!(
                counter.load(std::sync::atomic::Ordering::Relaxed),
                1
            );
            // Order of survivors preserved.
            let labels: Vec<u32> =
                out.iter().map(|ls| ls.sample.label).collect();
            assert_eq!(labels, vec![0, 1, 2, 3, 4, 5]);
        }
    }
}
