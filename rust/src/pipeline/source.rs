//! Source datasets (`tf.data.Dataset.from_tensor_slices`) and the
//! engine-backed [`ShardedReader`] source that partitions a file list
//! across N reader shards, each keeping its own window of whole-file
//! reads in flight on the storage engine.
//!
//! The paper's Fig. 4/8 headline is that read bandwidth scales with
//! reader parallelism (2.3x-7.8x with threads).  The sharded reader
//! reproduces that scaling without parking an OS thread per read:
//! shard i owns every (i mod N)-th file, keeps `window` reads queued
//! on the engine (tagged [`IoClass::Ingest`] so the QoS scheduler
//! protects them from checkpoint traffic), and *steals* backlog from
//! the fullest shard when its own runs dry — a straggler shard can't
//! idle the others' windows.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use super::dataset::Dataset;
use crate::data::manifest::{Manifest, Sample};
use crate::storage::{
    with_origin, IoClass, PendingRead, StorageHierarchy, StorageSim,
};

/// A dataset yielding the elements of a vector in order.
pub struct VecSource<T> {
    items: std::vec::IntoIter<T>,
}

/// `from_tensor_slices` over any vector.
pub fn from_vec<T: Send + 'static>(items: Vec<T>) -> VecSource<T> {
    VecSource { items: items.into_iter() }
}

/// The paper's source dataset: the (file path, label) list (Fig. 2).
pub fn from_manifest(m: &Manifest) -> VecSource<Sample> {
    from_vec(m.samples.clone())
}

impl<T: Send + 'static> Dataset for VecSource<T> {
    type Item = T;

    fn next(&mut self) -> Option<Result<T>> {
        self.items.next().map(Ok)
    }
}

/// A sample whose file contents have been fetched.
pub struct LoadedSample {
    pub sample: Sample,
    pub bytes: Vec<u8>,
}

/// Backlog entry: a sample waiting to be submitted, or an upstream
/// error delivered in order as an element error.
enum PendingItem {
    Sample(Sample),
    Error(anyhow::Error),
}

enum ReadSlot {
    /// Read submitted to the engine (or served warm from the cache).
    Submitted(Sample, PendingRead),
    /// Upstream or submission failed; delivered as an element error.
    Failed(anyhow::Error),
}

impl ReadSlot {
    fn ready(&self) -> bool {
        match self {
            ReadSlot::Failed(_) => true,
            ReadSlot::Submitted(_, pr) => pr.ready(),
        }
    }
}

struct Shard {
    /// Samples not yet submitted (front = next to submit).
    backlog: VecDeque<PendingItem>,
    /// Reads in flight on the engine, in submission order.
    inflight: VecDeque<ReadSlot>,
}

/// Where a reader's submissions go: straight at the sim (a sample's
/// `path.device` is authoritative) or through a storage hierarchy
/// (the sample's `path.rel` is the key; whichever tier holds it
/// serves, and the placement policy sees every access — hot files
/// migrate toward tier 0 under a promotion policy).
enum ReadRoute {
    Sim(Arc<StorageSim>),
    Hier(Arc<StorageHierarchy>),
}

impl ReadRoute {
    fn submit(&self, sample: &Sample) -> Result<PendingRead> {
        // Tagged so trace events attribute these reads to the ingest
        // source.
        with_origin("sharded-reader", || match self {
            ReadRoute::Sim(sim) => {
                sim.read_async_class(&sample.path, IoClass::Ingest)
            }
            ReadRoute::Hier(h) => {
                h.read_async_class(&sample.path.rel, IoClass::Ingest)
            }
        })
    }
}

/// Engine-backed sharded reader: the file list is stride-partitioned
/// across `shards` independent readers, each holding up to `window`
/// whole-file reads in flight ([`IoClass::Ingest`]).  Total engine
/// queue depth is `shards * window` — the thread-scaling knob of
/// Figs. 4/8, without the threads.
///
/// Yield order is round-robin across shards, preferring a shard whose
/// head read has already completed (so one slow file never gates the
/// other shards' finished reads); within a shard, submission order is
/// preserved.  A shard whose backlog empties steals the back half of
/// the fullest backlog, keeping every window busy to the end.
pub struct ShardedReader {
    route: ReadRoute,
    shards: Vec<Shard>,
    window: usize,
    cursor: usize,
    steals: u64,
}

/// Build a [`ShardedReader`] over a concrete sample list.
pub fn sharded_reader(
    samples: Vec<Sample>,
    sim: Arc<StorageSim>,
    shards: usize,
    window: usize,
) -> ShardedReader {
    ShardedReader::new(
        samples.into_iter().map(PendingItem::Sample).collect(),
        ReadRoute::Sim(sim),
        shards,
        window,
    )
}

/// Build a [`ShardedReader`] whose reads route through a storage
/// hierarchy (tier-sweep cells, hot-set promotion studies).  Sample
/// paths are interpreted by their `rel` key; the hierarchy decides
/// which tier serves.
pub fn sharded_reader_hier(
    samples: Vec<Sample>,
    hier: Arc<StorageHierarchy>,
    shards: usize,
    window: usize,
) -> ShardedReader {
    ShardedReader::new(
        samples.into_iter().map(PendingItem::Sample).collect(),
        ReadRoute::Hier(hier),
        shards,
        window,
    )
}

/// Single-shard readahead over a **finite** upstream dataset: keeps
/// `depth` reads in flight.
///
/// Contract change vs the pre-sharding version: the upstream is
/// drained **eagerly at construction** (O(upstream) memory for the
/// sample list; an unbounded upstream will never return).  Every
/// in-repo caller feeds a materialized manifest slice, where this is
/// free; feed [`sharded_reader`] a `Vec` directly when that is what
/// you have.
pub fn read_ahead<D: Dataset<Item = Sample>>(
    mut upstream: D,
    sim: Arc<StorageSim>,
    depth: usize,
) -> ShardedReader {
    let mut items = Vec::new();
    while let Some(next) = upstream.next() {
        items.push(match next {
            Ok(s) => PendingItem::Sample(s),
            Err(e) => PendingItem::Error(e),
        });
    }
    ShardedReader::new(items, ReadRoute::Sim(sim), 1, depth)
}

impl ShardedReader {
    fn new(
        items: Vec<PendingItem>,
        route: ReadRoute,
        shards: usize,
        window: usize,
    ) -> ShardedReader {
        let n = shards.max(1);
        let mut parts: Vec<Shard> = (0..n)
            .map(|_| Shard {
                backlog: VecDeque::new(),
                inflight: VecDeque::new(),
            })
            .collect();
        // Stride partition: shard i owns items i, i+n, i+2n, ...
        for (i, item) in items.into_iter().enumerate() {
            parts[i % n].backlog.push_back(item);
        }
        // Lazy: no reads are submitted until the first `next()`, so a
        // consumer that brackets the reader with a timer (the
        // microbench) measures the first window too.
        ShardedReader {
            route,
            shards: parts,
            window: window.max(1),
            cursor: 0,
            steals: 0,
        }
    }

    /// Take the next backlog item for shard `i`, stealing the back
    /// half of the fullest other backlog when shard `i` has run dry.
    fn next_item(&mut self, i: usize) -> Option<PendingItem> {
        if let Some(item) = self.shards[i].backlog.pop_front() {
            return Some(item);
        }
        // Work stealing: find the straggler with the most backlog.
        let victim = (0..self.shards.len())
            .filter(|&j| j != i)
            .max_by_key(|&j| self.shards[j].backlog.len())?;
        let vlen = self.shards[victim].backlog.len();
        if vlen < 2 {
            // Nothing worth splitting (0 or 1 item: the owner's own
            // window handles the tail).
            return None;
        }
        let stolen = self.shards[victim].backlog.split_off(vlen - vlen / 2);
        self.shards[i].backlog = stolen;
        self.steals += 1;
        self.shards[i].backlog.pop_front()
    }

    /// Fill every shard's inflight window from its backlog.
    fn top_up(&mut self) {
        for i in 0..self.shards.len() {
            while self.shards[i].inflight.len() < self.window {
                let slot = match self.next_item(i) {
                    None => break,
                    Some(PendingItem::Error(e)) => ReadSlot::Failed(e),
                    Some(PendingItem::Sample(sample)) => {
                        match self.route.submit(&sample) {
                            Ok(pr) => ReadSlot::Submitted(sample, pr),
                            Err(e) => ReadSlot::Failed(e),
                        }
                    }
                };
                self.shards[i].inflight.push_back(slot);
            }
        }
    }

    /// Reads currently in flight across all shards (tests/metrics).
    pub fn in_flight(&self) -> usize {
        self.shards.iter().map(|s| s.inflight.len()).sum()
    }

    /// Number of work-stealing events so far.
    pub fn steal_count(&self) -> u64 {
        self.steals
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

impl Dataset for ShardedReader {
    type Item = LoadedSample;

    fn next(&mut self) -> Option<Result<LoadedSample>> {
        self.top_up();
        let n = self.shards.len();
        // Round-robin from the cursor, but prefer a shard whose head
        // has already completed — never block on shard A while shard
        // B's data sits ready.
        let mut pick = None;
        for off in 0..n {
            let i = (self.cursor + off) % n;
            if !self.shards[i].inflight.is_empty() {
                if pick.is_none() {
                    pick = Some(i);
                }
                if self.shards[i].inflight[0].ready() {
                    pick = Some(i);
                    break;
                }
            }
        }
        let i = pick?;
        self.cursor = (i + 1) % n;
        let slot = self.shards[i].inflight.pop_front()?;
        // Refill behind the pop so the windows stay full while the
        // caller processes this element.
        self.top_up();
        match slot {
            ReadSlot::Failed(e) => Some(Err(e)),
            ReadSlot::Submitted(sample, pr) => match pr.wait() {
                Ok(bytes) => Some(Ok(LoadedSample { sample, bytes })),
                Err(e) => Some(Err(e)),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::dataset::collect;
    use crate::storage::SimPath;

    #[test]
    fn yields_in_order() {
        let d = from_vec(vec!["a", "b", "c"]);
        assert_eq!(collect(d).unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn empty_source() {
        let d = from_vec(Vec::<u8>::new());
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn manifest_source_preserves_pairs() {
        let m = Manifest {
            samples: vec![
                Sample { path: SimPath::new("d", "0"), label: 5 },
                Sample { path: SimPath::new("d", "1"), label: 6 },
            ],
            num_classes: 10,
            src_size: 32,
        };
        let items = collect(from_manifest(&m)).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].label, 6);
    }

    mod sharded_reader_tests {
        use super::super::{read_ahead, sharded_reader, LoadedSample};
        use crate::data::manifest::Sample;
        use crate::pipeline::dataset::Dataset;
        use crate::pipeline::{from_vec, DatasetExt};
        use crate::storage::{DeviceModel, SimPath, StorageSim};
        use std::sync::Arc;

        fn sim(tag: &str) -> Arc<StorageSim> {
            let dir = std::env::temp_dir().join(format!(
                "dlio-shardedreader-test-{tag}-{}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let model = DeviceModel {
                name: "ssd".into(),
                read_bw: 1e9,
                write_bw: 1e9,
                read_lat: 0.0,
                write_lat: 0.0,
                channels: 8,
                elevator: vec![(1, 1.0)],
                time_scale: 1000.0,
                lat_tables: None,
            };
            Arc::new(StorageSim::cold(dir, vec![model]).unwrap())
        }

        fn corpus(sim: &StorageSim, n: usize) -> Vec<Sample> {
            (0..n)
                .map(|i| {
                    let p = SimPath::new("ssd", format!("f{i}.bin"));
                    sim.write(&p, &vec![i as u8; 512]).unwrap();
                    Sample { path: p, label: i as u32 }
                })
                .collect()
        }

        #[test]
        fn single_shard_yields_all_samples_in_order_with_data() {
            let s = sim("order");
            let samples = corpus(&s, 40);
            s.drop_caches();
            let ds = read_ahead(from_vec(samples), Arc::clone(&s), 8);
            let out: Vec<LoadedSample> =
                crate::pipeline::collect(ds).unwrap();
            assert_eq!(out.len(), 40);
            for (i, ls) in out.iter().enumerate() {
                assert_eq!(ls.sample.label, i as u32);
                assert_eq!(ls.bytes, vec![i as u8; 512]);
            }
        }

        #[test]
        fn keeps_depth_reads_in_flight() {
            let s = sim("depth");
            let samples = corpus(&s, 30);
            s.drop_caches();
            let mut ds = read_ahead(from_vec(samples), Arc::clone(&s), 6);
            let first = ds.next().unwrap().unwrap();
            assert_eq!(first.sample.label, 0);
            // After one pop the window is topped back up.
            assert_eq!(ds.in_flight(), 6);
        }

        #[test]
        fn sharded_yields_every_sample_exactly_once() {
            let s = sim("complete");
            let samples = corpus(&s, 41); // not divisible by 4
            s.drop_caches();
            let ds = sharded_reader(samples, Arc::clone(&s), 4, 3);
            let out = crate::pipeline::collect(ds).unwrap();
            assert_eq!(out.len(), 41);
            let mut labels: Vec<u32> =
                out.iter().map(|ls| ls.sample.label).collect();
            labels.sort_unstable();
            assert_eq!(labels, (0..41).collect::<Vec<u32>>());
            // Data integrity per element.
            for ls in &out {
                assert_eq!(ls.bytes, vec![ls.sample.label as u8; 512]);
            }
        }

        #[test]
        fn deep_windows_trigger_work_stealing() {
            let s = sim("steal");
            let samples = corpus(&s, 48);
            s.drop_caches();
            // Window (16) exceeds a shard's stride share (12), so the
            // first top_up (on the first next(): construction is
            // lazy) drains shard 0's own backlog and it must steal
            // from a straggler to keep its window full.
            let mut ds = sharded_reader(samples, Arc::clone(&s), 4, 16);
            assert_eq!(ds.in_flight(), 0, "construction must stay lazy");
            let mut n = 0;
            while let Some(item) = ds.next() {
                item.unwrap();
                n += 1;
            }
            assert_eq!(n, 48, "stealing lost or duplicated samples");
            assert!(
                ds.steal_count() > 0,
                "window > share but no steals happened"
            );
        }

        #[test]
        fn steal_accounting_is_exact_and_loses_nothing() {
            // Deterministic steal pinning (satellite): 2 shards x 6
            // items each, window 8 > 6.  The first top_up fills shard
            // 0 from its own backlog (6 items), then steals the back
            // half of shard 1's 6-item backlog (3 items, steals = 1)
            // and keeps filling; shard 1 then fills from its
            // remaining 3 and finds nothing worth splitting (every
            // other backlog is 0 or 1 item), so the count must end
            // at exactly 1 — and stealing must neither duplicate nor
            // drop a sample even though the victim's own top_up runs
            // in the same pass, after the split.
            let s = sim("stealexact");
            let samples = corpus(&s, 12);
            s.drop_caches();
            let mut ds = sharded_reader(samples, Arc::clone(&s), 2, 8);
            let mut labels = Vec::new();
            while let Some(item) = ds.next() {
                labels.push(item.unwrap().sample.label);
            }
            assert_eq!(
                ds.steal_count(),
                1,
                "steal accounting drifted from the deterministic layout"
            );
            assert_eq!(labels.len(), 12, "stolen items dropped or doubled");
            labels.sort_unstable();
            assert_eq!(labels, (0..12).collect::<Vec<u32>>());
        }

        #[test]
        fn hierarchy_routed_reader_yields_all_samples_with_tier_hits() {
            use crate::storage::{
                policy, HierarchySpec, StorageHierarchy, TierSpec,
            };
            let dir = std::env::temp_dir().join(format!(
                "dlio-shardedreader-hier-{}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let mk = |name: &str| DeviceModel {
                name: name.into(),
                read_bw: 1e9,
                write_bw: 1e9,
                read_lat: 0.0,
                write_lat: 0.0,
                channels: 8,
                elevator: vec![(1, 1.0)],
                time_scale: 1000.0,
                lat_tables: None,
            };
            let s = Arc::new(
                StorageSim::cold(dir, vec![mk("fast"), mk("slow")]).unwrap(),
            );
            let samples: Vec<Sample> = (0..20)
                .map(|i| {
                    let p = SimPath::new("slow", format!("c/f{i}.bin"));
                    s.write(&p, &vec![i as u8; 256]).unwrap();
                    Sample { path: p, label: i as u32 }
                })
                .collect();
            s.drop_caches();
            let hier = Arc::new(
                StorageHierarchy::new(
                    Arc::clone(&s),
                    HierarchySpec::new(
                        "h",
                        vec![
                            TierSpec::device("fast", 0),
                            TierSpec::device("slow", 0),
                        ],
                    ),
                    Box::new(policy::Noop),
                )
                .unwrap(),
            );
            let ds = super::super::sharded_reader_hier(
                samples,
                Arc::clone(&hier),
                2,
                3,
            );
            let out = crate::pipeline::collect(ds).unwrap();
            assert_eq!(out.len(), 20);
            for ls in &out {
                assert_eq!(ls.bytes, vec![ls.sample.label as u8; 256]);
            }
            // Every read was served by the slow tier (auto-registered
            // residency), none by the empty fast tier.
            let stats = hier.stats();
            assert_eq!(stats[0].hits, 0);
            assert_eq!(stats[1].hits, 20);
            assert_eq!(hier.total_reads(), 20);
        }

        #[test]
        fn missing_file_is_element_error_not_fatal() {
            let s = sim("missing");
            let mut samples = corpus(&s, 6);
            samples.insert(
                3,
                Sample { path: SimPath::new("ssd", "nope.bin"), label: 99 },
            );
            s.drop_caches();
            let ds = sharded_reader(samples, Arc::clone(&s), 2, 2)
                .ignore_errors();
            let counter = ds.dropped_counter();
            let out = crate::pipeline::collect(ds).unwrap();
            assert_eq!(out.len(), 6);
            assert_eq!(
                counter.load(std::sync::atomic::Ordering::Relaxed),
                1
            );
            let mut labels: Vec<u32> =
                out.iter().map(|ls| ls.sample.label).collect();
            labels.sort_unstable();
            assert_eq!(labels, vec![0, 1, 2, 3, 4, 5]);
        }
    }
}
