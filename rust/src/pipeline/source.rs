//! Source datasets (`tf.data.Dataset.from_tensor_slices`).

use anyhow::Result;

use super::dataset::Dataset;
use crate::data::manifest::{Manifest, Sample};

/// A dataset yielding the elements of a vector in order.
pub struct VecSource<T> {
    items: std::vec::IntoIter<T>,
}

/// `from_tensor_slices` over any vector.
pub fn from_vec<T: Send + 'static>(items: Vec<T>) -> VecSource<T> {
    VecSource { items: items.into_iter() }
}

/// The paper's source dataset: the (file path, label) list (Fig. 2).
pub fn from_manifest(m: &Manifest) -> VecSource<Sample> {
    from_vec(m.samples.clone())
}

impl<T: Send + 'static> Dataset for VecSource<T> {
    type Item = T;

    fn next(&mut self) -> Option<Result<T>> {
        self.items.next().map(Ok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::dataset::collect;
    use crate::storage::SimPath;

    #[test]
    fn yields_in_order() {
        let d = from_vec(vec!["a", "b", "c"]);
        assert_eq!(collect(d).unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn empty_source() {
        let d = from_vec(Vec::<u8>::new());
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn manifest_source_preserves_pairs() {
        let m = Manifest {
            samples: vec![
                Sample { path: SimPath::new("d", "0"), label: 5 },
                Sample { path: SimPath::new("d", "1"), label: 6 },
            ],
            num_classes: 10,
            src_size: 32,
        };
        let items = collect(from_manifest(&m)).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].label, 6);
    }
}
