//! The `Dataset` abstraction — a faithful rust port of the tf.data
//! surface the paper characterizes (§II-A, Fig. 2).
//!
//! A dataset is a pull-based iterator of `Result` elements.  Errors
//! flow through the pipeline as values (so `ignore_errors` can drop
//! them, §III-A) and `None` marks exhaustion.  Combinators mirror the
//! tf.data operators used in the paper:
//!
//! ```text
//! from_tensor_slices -> shuffle -> map(num_parallel_calls)
//!     -> ignore_errors -> batch -> prefetch -> iterator
//! ```

use anyhow::Result;

use crate::util::Rng;

/// A pull-based stream of elements.
pub trait Dataset: Send {
    type Item: Send + 'static;

    /// Next element: `None` = exhausted, `Some(Err)` = element-level
    /// failure (recoverable via [`ignore_errors`]).
    fn next(&mut self) -> Option<Result<Self::Item>>;
}

/// Boxed dataset alias used across the coordinator.
pub type BoxedDataset<T> = Box<dyn Dataset<Item = T>>;

impl<T: Send + 'static> Dataset for BoxedDataset<T> {
    type Item = T;

    fn next(&mut self) -> Option<Result<T>> {
        (**self).next()
    }
}

/// Combinator constructors, tf.data style.
pub trait DatasetExt: Dataset + Sized + 'static {
    /// `tf.data.Dataset.shuffle(buffer_size)`.
    fn shuffle(self, buffer_size: usize, rng: Rng)
        -> super::shuffle::Shuffle<Self>
    {
        super::shuffle::Shuffle::new(self, buffer_size, rng)
    }

    /// `tf.data.Dataset.map(f, num_parallel_calls)` — deterministic
    /// (ordered) parallel map, as tf.data defaults to.
    fn parallel_map<U, F>(self, threads: usize, f: F)
        -> super::map::ParallelMap<U>
    where
        U: Send + 'static,
        F: Fn(Self::Item) -> Result<U> + Send + Sync + 'static,
    {
        super::map::ParallelMap::new(self, threads, f)
    }

    /// [`parallel_map`](Self::parallel_map) with a readahead window:
    /// up to `threads + readahead` elements in flight or buffered
    /// ahead of the consumer (readahead 0 = plain `parallel_map`).
    fn parallel_map_ahead<U, F>(
        self,
        threads: usize,
        readahead: usize,
        f: F,
    ) -> super::map::ParallelMap<U>
    where
        U: Send + 'static,
        F: Fn(Self::Item) -> Result<U> + Send + Sync + 'static,
    {
        super::map::ParallelMap::with_window(
            self,
            threads,
            threads.max(1) + readahead,
            f,
        )
    }

    /// `tf.contrib.data.ignore_errors()`.
    fn ignore_errors(self) -> super::ignore_errors::IgnoreErrors<Self> {
        super::ignore_errors::IgnoreErrors::new(self)
    }

    /// `tf.data.Dataset.batch(batch_size)`.
    fn batch(self, batch_size: usize, drop_remainder: bool)
        -> super::batch::BatchDataset<Self>
    {
        super::batch::BatchDataset::new(self, batch_size, drop_remainder)
    }

    /// `tf.data.Dataset.prefetch(n)` — background-thread prefetcher.
    fn prefetch(self, buffer_size: usize)
        -> super::prefetch::Prefetch<Self::Item>
    {
        super::prefetch::Prefetch::new(self, buffer_size)
    }

    /// `Dataset.take(n)`.
    fn take(self, n: usize) -> Take<Self> {
        Take { inner: self, left: n }
    }

    /// Box the dataset for dynamic composition.
    fn boxed(self) -> BoxedDataset<Self::Item> {
        Box::new(self)
    }
}

impl<D: Dataset + Sized + 'static> DatasetExt for D {}

/// `Dataset.take(n)` adapter.
pub struct Take<D: Dataset> {
    inner: D,
    left: usize,
}

impl<D: Dataset> Dataset for Take<D> {
    type Item = D::Item;

    fn next(&mut self) -> Option<Result<D::Item>> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        self.inner.next()
    }
}

/// Drain a dataset to a vec of Ok items, propagating the first error.
pub fn collect<D: Dataset>(mut d: D) -> Result<Vec<D::Item>> {
    let mut out = Vec::new();
    while let Some(item) = d.next() {
        out.push(item?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::source::from_vec;
    use super::*;

    #[test]
    fn take_limits_and_stops() {
        let d = from_vec(vec![1, 2, 3, 4, 5]).take(3);
        assert_eq!(collect(d).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn take_zero_is_empty() {
        let d = from_vec(vec![1, 2]).take(0);
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn take_beyond_end_is_harmless() {
        let d = from_vec(vec![1, 2]).take(10);
        assert_eq!(collect(d).unwrap(), vec![1, 2]);
    }

    #[test]
    fn boxed_composes() {
        let d: BoxedDataset<i32> = from_vec(vec![1, 2, 3]).boxed();
        let d = d.take(2);
        assert_eq!(collect(d).unwrap(), vec![1, 2]);
    }
}
