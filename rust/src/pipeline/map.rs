//! `tf.data.Dataset.map(f, num_parallel_calls)` (§II-A.1).
//!
//! The paper: *"Threads will be spawned by the runtime to execute the
//! I/O function and the number of threads used by the map can be
//! specified with num_parallel_calls"*.  This is the knob every
//! thread-scaling experiment (Figs. 4-6) sweeps.
//!
//! Semantics reproduced from TensorFlow's deterministic
//! `ParallelMapDataset`:
//!
//! * `num_parallel_calls` worker threads pull upstream elements under
//!   a shared lock (upstream pulls are serialized; the *map function*
//!   runs in parallel — exactly TF's contract).
//! * Results are delivered **in input order** via a reorder buffer.
//! * At most `window` elements are in flight or buffered (default:
//!   `num_parallel_calls`), which provides the backpressure that keeps
//!   memory bounded.  A larger window — `parallel_map_ahead`'s
//!   readahead — lets workers run ahead of a bursty consumer without
//!   adding threads, the map-side half of the engine-backed readahead
//!   (`source::read_ahead` keeps the *reads* in flight; the window
//!   keeps their *decoded results* flowing).
//! * Element-level errors (from upstream or from `f`) are delivered in
//!   order as `Err` values, to be dropped by `ignore_errors`.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::dataset::{BoxedDataset, Dataset};

struct MapState<T: Send + 'static, U> {
    upstream: Option<BoxedDataset<T>>,
    /// Next sequence number to hand to a worker.
    issue_seq: u64,
    /// Completed results awaiting in-order delivery.
    results: BTreeMap<u64, Result<U>>,
    in_flight: usize,
    upstream_done: bool,
    shutdown: bool,
}

struct Shared<T: Send + 'static, U> {
    state: Mutex<MapState<T, U>>,
    /// Signals the consumer that a result may be ready.
    ready: Condvar,
    /// Signals workers that window space freed up.
    slot: Condvar,
    capacity: usize,
}

/// Ordered parallel map over a boxed upstream.
pub struct ParallelMap<U: Send + 'static> {
    shared: Arc<dyn ErasedShared<U>>,
    workers: Vec<JoinHandle<()>>,
    next_seq: u64,
}

/// Object-safe view of `Shared<T, U>` for the consumer side (erases T).
trait ErasedShared<U>: Send + Sync {
    fn pop_next(&self, seq: u64) -> Option<Result<U>>;
    fn request_shutdown(&self);
}

impl<T: Send + 'static, U: Send + 'static> ErasedShared<U> for Shared<T, U> {
    fn pop_next(&self, seq: u64) -> Option<Result<U>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.results.remove(&seq) {
                self.slot.notify_all();
                return Some(r);
            }
            let exhausted = st.upstream_done
                && st.in_flight == 0
                && st.results.is_empty();
            if exhausted {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    fn request_shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.slot.notify_all();
        self.ready.notify_all();
    }
}

impl<U: Send + 'static> ParallelMap<U> {
    pub fn new<D, F>(upstream: D, threads: usize, f: F) -> Self
    where
        D: Dataset + 'static,
        F: Fn(D::Item) -> Result<U> + Send + Sync + 'static,
    {
        Self::with_window(upstream, threads, threads, f)
    }

    /// Like [`new`](Self::new) but with an explicit in-flight window
    /// (clamped to at least `threads`): up to `window` elements may be
    /// running or buffered ahead of the consumer.
    pub fn with_window<D, F>(
        upstream: D,
        threads: usize,
        window: usize,
        f: F,
    ) -> Self
    where
        D: Dataset + 'static,
        F: Fn(D::Item) -> Result<U> + Send + Sync + 'static,
    {
        let threads = threads.max(1);
        let window = window.max(threads);
        let shared = Arc::new(Shared::<D::Item, U> {
            state: Mutex::new(MapState {
                upstream: Some(Box::new(upstream) as BoxedDataset<D::Item>),
                issue_seq: 0,
                results: BTreeMap::new(),
                in_flight: 0,
                upstream_done: false,
                shutdown: false,
            }),
            ready: Condvar::new(),
            slot: Condvar::new(),
            capacity: window,
        });
        let f = Arc::new(f);
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                let f = Arc::clone(&f);
                std::thread::Builder::new()
                    .name(format!("dlio-map-{i}"))
                    .spawn(move || worker_loop(sh, f))
                    .expect("spawn map worker")
            })
            .collect();
        ParallelMap { shared, workers, next_seq: 0 }
    }
}

fn worker_loop<T: Send + 'static, U: Send + 'static>(
    sh: Arc<Shared<T, U>>,
    f: Arc<dyn Fn(T) -> Result<U> + Send + Sync>,
) {
    loop {
        // --- acquire an input element + sequence number ---
        let (seq, item) = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.upstream_done {
                    return;
                }
                // Backpressure window: buffered + running < capacity.
                if st.results.len() + st.in_flight < sh.capacity {
                    break;
                }
                st = sh.slot.wait(st).unwrap();
            }
            let upstream = st.upstream.as_mut().expect("upstream present");
            match upstream.next() {
                None => {
                    st.upstream_done = true;
                    st.upstream = None; // drop source promptly
                    sh.ready.notify_all();
                    // Wake siblings blocked on the slot condvar so they
                    // can observe upstream_done and exit.
                    sh.slot.notify_all();
                    return;
                }
                Some(item) => {
                    let seq = st.issue_seq;
                    st.issue_seq += 1;
                    st.in_flight += 1;
                    (seq, item)
                }
            }
        };

        // --- run the map function outside the lock ---
        let out = match item {
            Ok(x) => f(x),
            Err(e) => Err(e), // upstream element error propagates in order
        };

        // --- deliver ---
        let mut st = sh.state.lock().unwrap();
        st.results.insert(seq, out);
        st.in_flight -= 1;
        drop(st);
        sh.ready.notify_all();
    }
}

impl<U: Send + 'static> Dataset for ParallelMap<U> {
    type Item = U;

    fn next(&mut self) -> Option<Result<U>> {
        let r = self.shared.pop_next(self.next_seq);
        if r.is_some() {
            self.next_seq += 1;
        }
        r
    }
}

impl<U: Send + 'static> Drop for ParallelMap<U> {
    fn drop(&mut self) {
        self.shared.request_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::dataset::{collect, DatasetExt};
    use super::super::source::from_vec;
    use anyhow::anyhow;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn preserves_input_order() {
        let d = from_vec((0..200).collect::<Vec<i64>>())
            .parallel_map(8, |x| Ok(x * 2));
        let out = collect(d).unwrap();
        assert_eq!(out, (0..200).map(|x| x * 2).collect::<Vec<i64>>());
    }

    #[test]
    fn order_held_even_with_skewed_latencies() {
        let d = from_vec((0..40).collect::<Vec<u64>>()).parallel_map(4, |x| {
            // Earlier elements are slower: order must still hold.
            std::thread::sleep(Duration::from_millis((40 - x) / 4));
            Ok(x)
        });
        let out = collect(d).unwrap();
        assert_eq!(out, (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn actually_parallel() {
        let t0 = std::time::Instant::now();
        let d = from_vec((0..8).collect::<Vec<i32>>()).parallel_map(8, |x| {
            std::thread::sleep(Duration::from_millis(100));
            Ok(x)
        });
        let out = collect(d).unwrap();
        assert_eq!(out.len(), 8);
        // 8 x 100 ms on 8 threads ≈ 100 ms; serial would be 800 ms.
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn single_thread_equals_serial_map() {
        let d = from_vec(vec![1, 2, 3]).parallel_map(1, |x| Ok(x + 1));
        assert_eq!(collect(d).unwrap(), vec![2, 3, 4]);
    }

    #[test]
    fn f_errors_delivered_in_order() {
        let d = from_vec((0..10).collect::<Vec<i32>>()).parallel_map(4, |x| {
            if x == 5 {
                Err(anyhow!("boom"))
            } else {
                Ok(x)
            }
        });
        let mut got = Vec::new();
        let mut errs = 0;
        let mut d = d;
        while let Some(item) = crate::pipeline::dataset::Dataset::next(&mut d)
        {
            match item {
                Ok(v) => got.push(v),
                Err(_) => errs += 1,
            }
        }
        assert_eq!(errs, 1);
        assert_eq!(got, vec![0, 1, 2, 3, 4, 6, 7, 8, 9]);
    }

    #[test]
    fn bounded_in_flight_backpressure() {
        // Without pulling results, at most `threads` elements may be
        // consumed from upstream (+1 per worker possibly blocked at
        // the window check before pulling).
        let pulled = Arc::new(AtomicUsize::new(0));
        let p = Arc::clone(&pulled);
        let src = from_vec((0..1000).collect::<Vec<i32>>());
        struct Counting<D> {
            inner: D,
            n: Arc<AtomicUsize>,
        }
        impl<D: crate::pipeline::dataset::Dataset> crate::pipeline::dataset::Dataset
            for Counting<D>
        {
            type Item = D::Item;
            fn next(&mut self) -> Option<anyhow::Result<D::Item>> {
                self.n.fetch_add(1, Ordering::SeqCst);
                self.inner.next()
            }
        }
        let d = Counting { inner: src, n: p }.parallel_map(4, Ok);
        std::thread::sleep(Duration::from_millis(100));
        let consumed = pulled.load(Ordering::SeqCst);
        assert!(consumed <= 8, "consumed {consumed} without backpressure");
        drop(d);
    }

    #[test]
    fn drop_mid_stream_joins_cleanly() {
        let mut d = from_vec((0..100).collect::<Vec<i32>>())
            .parallel_map(4, |x| {
                std::thread::sleep(Duration::from_millis(5));
                Ok(x)
            });
        let _ = crate::pipeline::dataset::Dataset::next(&mut d);
        drop(d); // must not hang or panic
    }

    #[test]
    fn empty_upstream_terminates() {
        let d = from_vec(Vec::<i32>::new()).parallel_map(4, Ok);
        assert!(collect(d).unwrap().is_empty());
    }

    #[test]
    fn thread_count_zero_clamped() {
        let d = from_vec(vec![1]).parallel_map(0, Ok);
        assert_eq!(collect(d).unwrap(), vec![1]);
    }

    #[test]
    fn readahead_window_widens_in_flight_bound() {
        // With window 12 over 2 threads, workers may buffer up to 12
        // results ahead of an idle consumer (vs 2 without readahead).
        let pulled = Arc::new(AtomicUsize::new(0));
        struct Counting<D> {
            inner: D,
            n: Arc<AtomicUsize>,
        }
        impl<D: crate::pipeline::dataset::Dataset> crate::pipeline::dataset::Dataset
            for Counting<D>
        {
            type Item = D::Item;
            fn next(&mut self) -> Option<anyhow::Result<D::Item>> {
                self.n.fetch_add(1, Ordering::SeqCst);
                self.inner.next()
            }
        }
        let src = Counting {
            inner: from_vec((0..1000).collect::<Vec<i32>>()),
            n: Arc::clone(&pulled),
        };
        let d = src.parallel_map_ahead(2, 10, Ok);
        std::thread::sleep(Duration::from_millis(150));
        let consumed = pulled.load(Ordering::SeqCst);
        // Ran ahead beyond the thread count, but bounded by the window
        // (+1 per worker possibly blocked at the check).
        assert!(consumed > 4, "no readahead: {consumed}");
        assert!(consumed <= 14, "unbounded readahead: {consumed}");
        let out = collect(d).unwrap();
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn readahead_preserves_order() {
        let d = from_vec((0..100).collect::<Vec<u64>>())
            .parallel_map_ahead(4, 16, |x| Ok(x * 2));
        assert_eq!(
            collect(d).unwrap(),
            (0..100).map(|x| x * 2).collect::<Vec<u64>>()
        );
    }
}
