//! Shared support for the figure/table bench harnesses
//! (`rust/benches/*.rs`, run via `cargo bench`).
//!
//! Each bench regenerates one table or figure of the paper: it prints
//! the same rows/series the paper reports, next to the paper's
//! reference numbers where the text states them.  Absolute numbers
//! differ (simulated testbed, accelerated clock); the *shapes* — who
//! wins, by what factor, where curves flatten — are the reproduction
//! target (see EXPERIMENTS.md).

use std::sync::Arc;

use anyhow::Result;

use crate::config::{default_time_scale, Testbed};
use crate::coordinator::fixtures::make_sim;
use crate::runtime::Runtime;
use crate::storage::{IoObserver, StorageSim};

/// Standard bench environment: paper testbed at the default (or
/// `$DLIO_TIME_SCALE`) acceleration, per-bench workdir, artifacts open.
pub struct BenchEnv {
    pub testbed: Testbed,
    pub sim: Arc<StorageSim>,
    pub rt: Runtime,
}

/// Create the bench environment (optionally traced).
pub fn env(bench: &str, observer: Option<Arc<dyn IoObserver>>)
    -> Result<BenchEnv>
{
    env_with_scale(bench, default_time_scale(), observer)
}

/// Like [`env`] but with a bench-specific default time scale
/// (`$DLIO_TIME_SCALE` still takes precedence).  The thread-scaling
/// figures run the devices *slower* than the default so that device
/// service time dominates single-core map-function compute, matching
/// the paper's I/O:CPU balance per worker (EXPERIMENTS.md).
pub fn env_with_scale(
    bench: &str,
    scale_default: f64,
    observer: Option<Arc<dyn IoObserver>>,
) -> Result<BenchEnv> {
    let scale = std::env::var("DLIO_TIME_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(scale_default);
    let mut testbed = Testbed::paper(scale);
    testbed.workdir =
        format!("{}/bench-{bench}", crate::config::default_workdir());
    let sim = make_sim(&testbed, observer)?;
    let rt = Runtime::open_default()?;
    Ok(BenchEnv { testbed, sim, rt })
}

/// The time scale actually in force for a bench created with
/// [`env_with_scale`].
pub fn effective_scale(scale_default: f64) -> f64 {
    std::env::var("DLIO_TIME_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(scale_default)
}

/// Bench sizing knob: 0 = smoke (CI-fast), 1 = default, 2 = full paper
/// geometry.  Set `DLIO_BENCH_LEVEL`.
pub fn level() -> u32 {
    std::env::var("DLIO_BENCH_LEVEL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Scale a (smoke, default, full) triple by the bench level.
pub fn pick<T: Copy>(smoke: T, default: T, full: T) -> T {
    match level() {
        0 => smoke,
        1 => default,
        _ => full,
    }
}

/// Print the bench banner with the reproduction context.
pub fn banner(id: &str, what: &str, paper_ref: &str) {
    println!("\n=== {id}: {what} ===");
    println!("paper reference: {paper_ref}");
    println!(
        "testbed: simulated Blackdog+Tegner devices at {}x time scale \
         (ratios are scale-invariant)",
        default_time_scale()
    );
}
