//! [`TraceRecorder`]: bounded-memory JSONL capture of the engine's
//! request-level event stream, plus the in-memory [`MemorySink`] the
//! replayer measures itself with.
//!
//! The recorder implements `EngineObserver`: attach it with
//! `engine.set_observer(recorder.observer())` and every request
//! completion is stamped with a sequence number and handed to a
//! background writer thread through a bounded queue.  A full queue
//! briefly blocks the completing thread (backpressure) instead of
//! buffering without bound, so recording memory is O([`QUEUE_CAP`])
//! regardless of trace length.  `finish()` drains the queue, flushes
//! the file, and reports how many events were written.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::compute::StepRecord;
use crate::storage::{EngineEvent, EngineObserver};

use super::event::{TraceEvent, TraceManifest};

/// Events buffered between the engine and the writer thread.  At ~150
/// bytes per event this bounds recording memory near 1 MB.
pub const QUEUE_CAP: usize = 8192;

struct SinkState {
    queue: VecDeque<TraceEvent>,
    /// Sequence stamp for the next event (assigned under this lock so
    /// file order always equals seq order).
    next_seq: u64,
    closed: bool,
}

struct Sink {
    state: Mutex<SinkState>,
    /// Completing threads wait here when the queue is full.
    space: Condvar,
    /// The writer thread waits here for events.
    filled: Condvar,
}

impl EngineObserver for Sink {
    fn record(&self, e: EngineEvent) {
        let mut st = self.state.lock().unwrap();
        while st.queue.len() >= QUEUE_CAP && !st.closed {
            st = self.space.wait(st).unwrap();
        }
        if st.closed {
            // finish() already ran (observer left attached): drop.
            return;
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.queue.push_back(TraceEvent::from_engine(seq, &e));
        drop(st);
        self.filled.notify_one();
    }
}

/// Records the engine's event stream to a JSONL trace file (header
/// manifest first, then one event per line).
pub struct TraceRecorder {
    sink: Arc<Sink>,
    writer: Option<JoinHandle<Result<u64>>>,
    path: PathBuf,
}

impl TraceRecorder {
    /// Create the trace file, write its header, and start the
    /// background writer.
    pub fn create(
        path: impl Into<PathBuf>,
        manifest: &TraceManifest,
    ) -> Result<TraceRecorder> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("mkdir {}", parent.display()))?;
            }
        }
        let mut file = BufWriter::new(
            File::create(&path)
                .with_context(|| format!("create {}", path.display()))?,
        );
        file.write_all(manifest.to_jsonl().as_bytes())?;
        file.write_all(b"\n")?;
        let sink = Arc::new(Sink {
            state: Mutex::new(SinkState {
                queue: VecDeque::new(),
                next_seq: 0,
                closed: false,
            }),
            space: Condvar::new(),
            filled: Condvar::new(),
        });
        let writer = {
            let sink = Arc::clone(&sink);
            std::thread::Builder::new()
                .name("dlio-trace-writer".into())
                .spawn(move || writer_loop(sink, file))
                .expect("spawn trace writer")
        };
        Ok(TraceRecorder { sink, writer: Some(writer), path })
    }

    /// The observer half to attach via `IoEngine::set_observer`.
    pub fn observer(&self) -> Arc<dyn EngineObserver> {
        Arc::clone(&self.sink) as Arc<dyn EngineObserver>
    }

    /// Trace file path.
    pub fn path(&self) -> &PathBuf {
        &self.path
    }

    /// Drain the queue, flush, and close; returns events written.
    /// Detach the observer (`IoEngine::clear_observer`) before calling
    /// — post-finish events are silently dropped.
    pub fn finish(mut self) -> Result<u64> {
        self.finish_inner()
    }

    fn finish_inner(&mut self) -> Result<u64> {
        let Some(handle) = self.writer.take() else {
            return Ok(0);
        };
        {
            let mut st = self.sink.state.lock().unwrap();
            st.closed = true;
        }
        self.sink.filled.notify_all();
        self.sink.space.notify_all();
        handle
            .join()
            .map_err(|_| anyhow!("trace writer thread panicked"))?
    }
}

impl Drop for TraceRecorder {
    fn drop(&mut self) {
        // Best-effort flush when the caller forgot finish() (an
        // error-path `?`): the trace stays readable.
        let _ = self.finish_inner();
    }
}

fn writer_loop(sink: Arc<Sink>, file: BufWriter<File>) -> Result<u64> {
    let result = write_events(&sink, file);
    if result.is_err() {
        // Poison the sink: with the writer gone, a full queue would
        // block engine completion threads in record() forever.  Mark
        // closed (record() then drops events), discard the backlog,
        // and wake every blocked producer; finish() surfaces the
        // error.
        let mut st = sink.state.lock().unwrap();
        st.closed = true;
        st.queue.clear();
        drop(st);
        sink.space.notify_all();
    }
    result
}

fn write_events(sink: &Arc<Sink>, mut file: BufWriter<File>) -> Result<u64> {
    let mut written = 0u64;
    loop {
        let batch: Vec<TraceEvent> = {
            let mut st = sink.state.lock().unwrap();
            loop {
                if !st.queue.is_empty() {
                    break st.queue.drain(..).collect();
                }
                if st.closed {
                    file.flush().context("flushing trace file")?;
                    return Ok(written);
                }
                st = sink.filled.wait(st).unwrap();
            }
        };
        // Queue space freed: unblock any completing thread first, then
        // do the (slow) serialization outside the lock.
        sink.space.notify_all();
        for ev in &batch {
            file.write_all(ev.to_jsonl().as_bytes())
                .context("writing trace event")?;
            file.write_all(b"\n")?;
            written += 1;
        }
    }
}

/// Append step-level records ([`StepRecord`] lines, schema v4) to a
/// finished trace file.  Request events stream through the recorder's
/// writer thread as they complete; step records are known only when
/// the training loop ends, so drivers call this after
/// [`TraceRecorder::finish`].  Returns the number of lines appended.
pub fn append_steps(
    path: impl Into<PathBuf>,
    steps: &[StepRecord],
) -> Result<u64> {
    let path = path.into();
    let mut file = BufWriter::new(
        std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .with_context(|| format!("append to {}", path.display()))?,
    );
    for s in steps {
        file.write_all(s.to_jsonl().as_bytes())
            .context("writing step record")?;
        file.write_all(b"\n")?;
    }
    file.flush().context("flushing step records")?;
    Ok(steps.len() as u64)
}

/// In-memory event sink: collects the stream instead of writing it.
/// The replayer attaches one to measure its own run with exactly the
/// machinery that produced the recording (symmetric diffs); tests use
/// it to assert on event streams.
pub struct MemorySink {
    events: Mutex<Vec<TraceEvent>>,
}

impl MemorySink {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<MemorySink> {
        Arc::new(MemorySink { events: Mutex::new(Vec::new()) })
    }

    /// Snapshot of everything recorded so far, in seq order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }
}

impl EngineObserver for MemorySink {
    fn record(&self, e: EngineEvent) {
        let mut evs = self.events.lock().unwrap();
        let seq = evs.len() as u64;
        evs.push(TraceEvent::from_engine(seq, &e));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{EngineOp, IoClass};
    use crate::util::json::Json;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dlio-trace-rec-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn engine_event(i: u64) -> EngineEvent {
        EngineEvent {
            device: "d".into(),
            class: IoClass::Ingest,
            op: EngineOp::ProbeRead,
            origin: "test",
            tier: None,
            tenant: crate::storage::TenantId::default(),
            bytes: 1000 + i,
            ok: true,
            submit_secs: i as f64 * 0.001,
            queue_secs: 0.0005,
            service_secs: 0.0005,
        }
    }

    fn manifest() -> TraceManifest {
        TraceManifest {
            version: super::super::event::TRACE_VERSION,
            workload: "unit".into(),
            qos_mode: "static".into(),
            qos: None,
            time_scale: 1.0,
            devices: vec![crate::storage::profiles::blackdog_ssd(1.0)],
        }
    }

    #[test]
    fn records_header_then_events_in_seq_order() {
        let path = scratch("order").join("t.jsonl");
        let rec = TraceRecorder::create(&path, &manifest()).unwrap();
        let obs = rec.observer();
        for i in 0..100 {
            obs.record(engine_event(i));
        }
        assert_eq!(rec.finish().unwrap(), 100);
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let head = TraceManifest::from_json(
            &Json::parse(lines.next().unwrap()).unwrap(),
        )
        .unwrap();
        assert_eq!(head.workload, "unit");
        let events: Vec<TraceEvent> = lines
            .map(|l| TraceEvent::from_json(&Json::parse(l).unwrap()).unwrap())
            .collect();
        assert_eq!(events.len(), 100);
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.seq, i as u64, "file order must equal seq order");
            assert_eq!(e.bytes, 1000 + i as u64);
        }
    }

    #[test]
    fn bounded_queue_backpressures_instead_of_growing() {
        // Feed far more events than QUEUE_CAP from many threads; the
        // writer drains them all (backpressure, not drops).
        let path = scratch("pressure").join("t.jsonl");
        let rec = TraceRecorder::create(&path, &manifest()).unwrap();
        let total = QUEUE_CAP * 2 + 123;
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let obs = rec.observer();
                let n = total / 4 + usize::from(t < total % 4);
                std::thread::spawn(move || {
                    for i in 0..n {
                        obs.record(engine_event(i as u64));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(rec.finish().unwrap(), total as u64);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), total + 1); // + header
    }

    #[test]
    fn drop_without_finish_still_flushes() {
        let path = scratch("dropflush").join("t.jsonl");
        {
            let rec = TraceRecorder::create(&path, &manifest()).unwrap();
            rec.observer().record(engine_event(0));
            // dropped without finish()
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn append_steps_extends_a_finished_trace() {
        let path = scratch("steps").join("t.jsonl");
        let rec = TraceRecorder::create(&path, &manifest()).unwrap();
        rec.observer().record(engine_event(0));
        assert_eq!(rec.finish().unwrap(), 1);
        let steps = vec![
            StepRecord {
                step: 0,
                start_secs: 0.0,
                input_wait_secs: 0.01,
                compute_secs: 0.1,
                ckpt_stall_secs: 0.0,
                images: 16,
            },
            StepRecord {
                step: 1,
                start_secs: 0.11,
                input_wait_secs: 0.0,
                compute_secs: 0.1,
                ckpt_stall_secs: 0.02,
                images: 16,
            },
        ];
        assert_eq!(append_steps(&path, &steps).unwrap(), 2);
        let trace = super::super::replay::Trace::load(&path).unwrap();
        assert_eq!(trace.events.len(), 1);
        assert_eq!(trace.steps.len(), 2);
        assert_eq!(trace.steps[1].images, 16);
        assert_eq!(trace.steps[1].ckpt_stall_secs, 0.02);
    }

    #[test]
    fn memory_sink_collects_in_seq_order() {
        let sink = MemorySink::new();
        for i in 0..10 {
            EngineObserver::record(&*sink, engine_event(i));
        }
        let evs = sink.events();
        assert_eq!(evs.len(), 10);
        assert!(evs.iter().enumerate().all(|(i, e)| e.seq == i as u64));
    }
}
