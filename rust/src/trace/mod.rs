//! Request-level trace capture, replay, and analysis (DESIGN.md §11),
//! plus the legacy dstat-style interval tracer (§IV-B, Figs. 8 & 10).
//!
//! The paper characterizes TensorFlow I/O with system-level tracing
//! (dstat's per-second byte bins); tf-Darshan (PAPERS.md) shows the
//! payoff of *per-request* instrumentation.  This module provides
//! both layers:
//!
//! * [`TraceRecorder`] — hooks the `IoEngine`'s request-level event
//!   stream ([`storage::EngineObserver`]) and writes a versioned JSONL
//!   trace (header [`TraceManifest`], one [`TraceEvent`] per request)
//!   with bounded memory via a background writer thread.
//! * [`replay`] — re-issues a recorded stream through a fresh engine
//!   against any storage profile / QoS config, open-loop (recorded
//!   inter-arrival gaps, `--speed`-scaled) or closed-loop
//!   (dependency-preserving, as fast as possible), and diffs the runs
//!   ([`ReplayReport`]).
//! * [`analyze`] — per-class aggregates, busy/overlap fractions, and
//!   interval timelines over event streams.  The legacy [`Dstat`] row
//!   shape is derivable from events ([`analyze::dstat_rows`]), making
//!   the interval tracer a *view* over the event stream; [`Dstat`]
//!   itself remains as the lightweight device-level observer for runs
//!   that don't need request granularity.
//!
//! [`storage::EngineObserver`]: crate::storage::EngineObserver
//! [`replay`]: replay::replay

pub mod analyze;
pub mod compact;
pub mod dstat;
pub mod event;
pub mod recorder;
pub mod replay;

pub use compact::{compact, write_trace, CompactReport};
pub use dstat::{Dstat, TraceRow};
pub use event::{TraceEvent, TraceManifest, TRACE_VERSION};
pub use recorder::{append_steps, MemorySink, TraceRecorder};
pub use replay::{
    replay, report, sweep, sweep_to_csv, sweep_to_json, ReplayConfig,
    ReplayMode, ReplayOutcome, ReplayReport, Trace,
};
