//! dstat-style I/O activity tracing (§IV-B, Figs. 8 & 10).
//!
//! The paper samples disk activity once per second with *dstat* and
//! plots MB read/written per interval.  [`Dstat`] implements the
//! [`IoObserver`] hook of the device simulator: every byte grant is
//! binned into a fixed-width interval per (device, direction), and the
//! series can be rendered as the paper's CSV.

pub mod dstat;

pub use dstat::{Dstat, TraceRow};
