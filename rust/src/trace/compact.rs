//! Trace compaction for multi-epoch recordings (`dlio trace-compact`).
//!
//! A multi-epoch training run records the same request pattern once
//! per epoch: N identical runs of (device, class, op, bytes) in
//! submit order, differing only in timing jitter.  Replaying all N
//! epochs buys nothing over replaying one — the pattern, not the
//! repetition, carries the workload.  `compact` detects the largest
//! epoch count `k` such that the event stream splits into `k`
//! signature-identical runs, keeps the first run (its recorded
//! timings), and stamps the manifest with the compaction factor.
//!
//! The equivalence check is structural, not statistical: compaction
//! succeeds only if every epoch's *exact* (device, class, op, bytes,
//! ok, origin, tier) sequence matches, so by construction
//! `events_in == k * events_out` and `bytes_in == k * bytes_out` —
//! both reported (and re-asserted) in [`CompactReport`].

use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::event::TraceEvent;
use super::replay::Trace;

/// What a compaction did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompactReport {
    /// Epochs detected (1 = no repetition found; output == input).
    pub epochs: usize,
    pub events_in: usize,
    pub events_out: usize,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// The per-event identity compaction compares (timing excluded).
fn signature(e: &TraceEvent) -> (&str, &str, &str, u64, bool, &str, i64) {
    (
        e.device.as_str(),
        e.class.name(),
        e.op.name(),
        e.bytes,
        e.ok,
        e.origin.as_str(),
        e.tier.map_or(-1, |t| t as i64),
    )
}

fn chunks_match(events: &[TraceEvent], k: usize) -> bool {
    let n = events.len();
    if k < 2 || n == 0 || n % k != 0 {
        return false;
    }
    let len = n / k;
    let first = &events[..len];
    (1..k).all(|c| {
        let chunk = &events[c * len..(c + 1) * len];
        chunk
            .iter()
            .zip(first)
            .all(|(a, b)| signature(a) == signature(b))
    })
}

/// Compact `trace` (events must be in submit order, as `Trace::load`
/// returns them).  `epochs`: `Some(k)` validates and uses exactly
/// `k`; `None` auto-detects the largest matching `k` (1 when the
/// stream doesn't repeat — the trace passes through unchanged).
pub fn compact(
    trace: &Trace,
    epochs: Option<usize>,
) -> Result<(Trace, CompactReport)> {
    let n = trace.events.len();
    let k = match epochs {
        Some(k) => {
            if k == 0 {
                bail!("--epochs must be positive");
            }
            if k > 1 {
                if n % k != 0 {
                    bail!(
                        "{n} events do not split into {k} equal epochs"
                    );
                }
                if !chunks_match(&trace.events, k) {
                    bail!(
                        "the {k} epochs are not request-identical \
                         (compaction would drop information)"
                    );
                }
            }
            k
        }
        None => {
            // Largest k whose chunks all match: more epochs folded =
            // smaller representative trace.  A candidate epoch must
            // contain at least two distinct signatures — a uniform
            // stream (every request identical) matches EVERY divisor
            // and has no epoch structure, so auto-folding it would
            // silently collapse the offered load to a near-empty
            // trace.  Explicit `--epochs` can still force it.
            let mut best = 1;
            for k in (2..=n).rev() {
                if n % k == 0 && chunks_match(&trace.events, k) {
                    let first = &trace.events[..n / k];
                    let s0 = signature(&first[0]);
                    if first.iter().any(|e| signature(e) != s0) {
                        best = k;
                        break;
                    }
                }
            }
            best
        }
    };
    let bytes_in: u64 = trace.events.iter().map(|e| e.bytes).sum();
    let kept = if k > 1 { n / k } else { n };
    let mut events: Vec<TraceEvent> = trace.events[..kept].to_vec();
    for (i, e) in events.iter_mut().enumerate() {
        e.seq = i as u64;
    }
    let bytes_out: u64 = events.iter().map(|e| e.bytes).sum();
    // The structural guarantee, re-asserted.
    if bytes_in != bytes_out * k as u64 || n != kept * k {
        return Err(anyhow!(
            "compaction equivalence check failed: {n} events / {bytes_in} \
             bytes != {k} x ({kept} events / {bytes_out} bytes)"
        ));
    }
    let mut manifest = trace.manifest.clone();
    if k > 1 {
        manifest.workload =
            format!("{} [compacted {k}x]", manifest.workload);
    }
    Ok((
        // Step records describe the training loop, not the request
        // stream being folded — they pass through unchanged.
        Trace { manifest, events, steps: trace.steps.clone() },
        CompactReport {
            epochs: k,
            events_in: n,
            events_out: kept,
            bytes_in,
            bytes_out,
        },
    ))
}

/// Write a trace as JSONL (header + one event per line) — the same
/// format `TraceRecorder` produces, without the live-capture
/// machinery.
pub fn write_trace(path: &Path, trace: &Trace) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
    }
    let mut file = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?,
    );
    file.write_all(trace.manifest.to_jsonl().as_bytes())?;
    file.write_all(b"\n")?;
    for e in &trace.events {
        file.write_all(e.to_jsonl().as_bytes())?;
        file.write_all(b"\n")?;
    }
    for s in &trace.steps {
        file.write_all(s.to_jsonl().as_bytes())?;
        file.write_all(b"\n")?;
    }
    file.flush().context("flushing compacted trace")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{EngineOp, IoClass};
    use crate::trace::event::{TraceManifest, TRACE_VERSION};

    fn ev(seq: u64, op: EngineOp, bytes: u64, t: f64) -> TraceEvent {
        TraceEvent {
            seq,
            device: "d".into(),
            class: IoClass::Ingest,
            op,
            origin: "test".into(),
            tier: None,
            tenant: String::new(),
            bytes,
            ok: true,
            submit_secs: t,
            queue_secs: 0.001,
            service_secs: 0.002,
        }
    }

    fn trace_of(events: Vec<TraceEvent>) -> Trace {
        Trace {
            manifest: TraceManifest {
                version: TRACE_VERSION,
                workload: "unit".into(),
                qos_mode: "static".into(),
                qos: None,
                time_scale: 1.0,
                devices: vec![crate::storage::profiles::blackdog_ssd(1.0)],
            },
            events,
            steps: Vec::new(),
        }
    }

    /// One epoch: read 100, read 200, write 5000 — with per-epoch
    /// timing jitter so only the signature is stable.
    fn epochs(k: usize) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for e in 0..k {
            let base = e as f64 * 1.0 + e as f64 * 0.013; // jitter
            out.push(ev(out.len() as u64, EngineOp::Read, 100, base));
            out.push(ev(out.len() as u64, EngineOp::Read, 200, base + 0.1));
            out.push(ev(
                out.len() as u64,
                EngineOp::ProbeWrite,
                5000,
                base + 0.2,
            ));
        }
        out
    }

    #[test]
    fn detects_and_folds_repeated_epochs() {
        let t = trace_of(epochs(3));
        let (c, rep) = compact(&t, None).unwrap();
        assert_eq!(rep.epochs, 3);
        assert_eq!(rep.events_in, 9);
        assert_eq!(rep.events_out, 3);
        assert_eq!(rep.bytes_in, 3 * 5300);
        assert_eq!(rep.bytes_out, 5300);
        assert_eq!(c.events.len(), 3);
        // Representative epoch keeps the FIRST epoch's timings and
        // re-seqs from 0.
        assert_eq!(c.events[0].submit_secs, 0.0);
        for (i, e) in c.events.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
        }
        assert!(c.manifest.workload.contains("compacted 3x"));
    }

    #[test]
    fn non_repeating_stream_passes_through() {
        let mut evs = epochs(1);
        evs.push(ev(3, EngineOp::Read, 999, 0.9)); // breaks any split
        let t = trace_of(evs);
        let (c, rep) = compact(&t, None).unwrap();
        assert_eq!(rep.epochs, 1);
        assert_eq!(rep.events_in, rep.events_out);
        assert_eq!(c.events.len(), 4);
        assert_eq!(c.manifest.workload, "unit");
    }

    #[test]
    fn uniform_stream_is_not_auto_folded() {
        // Every event identical: all divisors "match", but there is
        // no epoch structure — auto-detection must refuse (folding
        // would collapse the offered load), while an explicit
        // --epochs still forces it.
        let uni = |n: usize| -> Vec<TraceEvent> {
            (0..n)
                .map(|i| {
                    ev(i as u64, EngineOp::ProbeRead, 1000, i as f64 * 0.1)
                })
                .collect()
        };
        let (c, rep) = compact(&trace_of(uni(12)), None).unwrap();
        assert_eq!(rep.epochs, 1, "uniform stream auto-folded");
        assert_eq!(c.events.len(), 12);
        let (c, rep) = compact(&trace_of(uni(12)), Some(4)).unwrap();
        assert_eq!(rep.epochs, 4);
        assert_eq!(c.events.len(), 3);
    }

    #[test]
    fn explicit_epochs_validate_or_fail() {
        let t = trace_of(epochs(4));
        let (_, rep) = compact(&t, Some(2)).unwrap();
        assert_eq!(rep.epochs, 2, "explicit k wins over auto-detect");
        assert!(compact(&t, Some(5)).is_err(), "12 events !% 5");
        assert!(compact(&t, Some(0)).is_err());
        // Mismatched chunks with a plausible divisor: rejected.
        let mut evs = epochs(2);
        evs[3] = ev(3, EngineOp::Read, 12345, 1.0); // corrupt epoch 2
        assert!(compact(&trace_of(evs), Some(2)).is_err());
    }

    #[test]
    fn compacted_trace_roundtrips_through_disk_and_replays() {
        let dir = std::env::temp_dir().join(format!(
            "dlio-trace-compact-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let t = trace_of(epochs(3));
        let (c, rep) = compact(&t, None).unwrap();
        let path = dir.join("compact.jsonl");
        write_trace(&path, &c).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.events.len(), rep.events_out);
        assert!(back.manifest.workload.contains("compacted"));
        // And it replays like any other trace.
        let outcome = crate::trace::replay::replay(
            &back,
            &crate::trace::ReplayConfig::default(),
        )
        .unwrap();
        assert_eq!(outcome.errors, 0);
        assert_eq!(outcome.replayed.len(), rep.events_out);
    }
}
