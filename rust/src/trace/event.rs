//! Versioned request-level trace schema (DESIGN.md §11).
//!
//! A trace file is JSONL: line 1 is the [`TraceManifest`] header
//! (what was recorded, against which device models and QoS config, at
//! what time scale), every following line one [`TraceEvent`] — a
//! completed engine request with its submit/queue/service timing.
//! Field names are short (`t`/`q`/`s`) because a trace holds one line
//! per request; classes and ops are written as *names*, not indices,
//! so a reader from a different build stays compatible.

use anyhow::{anyhow, bail, Result};

use crate::storage::{
    AdaptiveQos, DeviceModel, EngineEvent, EngineOp, IoClass,
    LatencyTables, QosConfig, RateCap, RetryPolicy, TenantQos,
};
use crate::util::json::{obj, to_string, Json};

/// Current trace schema version.  Readers refuse files written by a
/// *newer* schema; older versions are accepted as long as the fields
/// parse.
///
/// v2: events may carry a `tier` field — the storage-hierarchy tier
/// the request was accounted to ([`crate::storage::with_tier`]).  v1
/// traces (no tier fields) load with `tier: None` and replay
/// unchanged.
///
/// v3: events may carry a `tenant` field — the tenant the request was
/// tagged with ([`crate::storage::with_tenant`]) — and the manifest's
/// `qos` block may carry a `tenants` table ([`TenantQos`]).  v1/v2
/// traces (no tenant fields) load with an empty tenant and replay
/// unchanged; replay re-tags probes from the recorded field.
///
/// v4: a trace may carry step-level records — lines tagged
/// `"rec":"step"` ([`crate::compute::StepRecord`]) holding each
/// training step's input-wait / compute / checkpoint-stall split,
/// appended after the request events.  v1–v3 traces (no step lines)
/// load with empty `steps` and replay unchanged; replay ignores step
/// lines (they describe the consumer, not the offered I/O load).
pub const TRACE_VERSION: u32 = 4;

/// One recorded engine request.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Recording order (ties on `submit_secs` replay in seq order).
    pub seq: u64,
    pub device: String,
    pub class: IoClass,
    pub op: EngineOp,
    /// Submitter tag (`storage::with_origin`); empty when untagged.
    pub origin: String,
    /// Storage-hierarchy tier the request was accounted to
    /// (`storage::with_tier`); `None` for untiered requests and for
    /// every event of a v1 trace.
    pub tier: Option<u32>,
    /// Tenant the request was tagged with (`storage::with_tenant`);
    /// empty for untagged requests and for every event of a v1/v2
    /// trace.
    pub tenant: String,
    /// Bytes moved.  On failure: a unit request's intended size (so a
    /// replay offers the same load); 0 for failed streams (see
    /// `EngineEvent::bytes`).
    pub bytes: u64,
    pub ok: bool,
    /// Submit time, wall seconds on the recording engine's clock.
    pub submit_secs: f64,
    /// Submit → service start, wall seconds.
    pub queue_secs: f64,
    /// Service start → completion, wall seconds.
    pub service_secs: f64,
}

impl TraceEvent {
    /// Stamp an engine event with its recording sequence number.
    pub fn from_engine(seq: u64, e: &EngineEvent) -> TraceEvent {
        TraceEvent {
            seq,
            device: e.device.clone(),
            class: e.class,
            op: e.op,
            origin: e.origin.to_string(),
            tier: e.tier,
            tenant: e.tenant.as_str().to_string(),
            bytes: e.bytes,
            ok: e.ok,
            submit_secs: e.submit_secs,
            queue_secs: e.queue_secs,
            service_secs: e.service_secs,
        }
    }

    /// Completion time on the recording clock, wall seconds.
    pub fn complete_secs(&self) -> f64 {
        self.submit_secs + self.queue_secs + self.service_secs
    }

    /// Service start (dispatch) time, wall seconds.
    pub fn service_start_secs(&self) -> f64 {
        self.submit_secs + self.queue_secs
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seq", Json::Num(self.seq as f64)),
            ("dev", Json::Str(self.device.clone())),
            ("class", Json::Str(self.class.name().to_string())),
            ("op", Json::Str(self.op.name().to_string())),
            ("origin", Json::Str(self.origin.clone())),
            ("bytes", Json::Num(self.bytes as f64)),
            ("ok", Json::Bool(self.ok)),
            ("t", Json::Num(self.submit_secs)),
            ("q", Json::Num(self.queue_secs)),
            ("s", Json::Num(self.service_secs)),
        ];
        // Untiered events omit the field entirely — a v2 trace with no
        // hierarchy traffic is byte-identical to its v1 form except
        // for the header version.
        if let Some(tier) = self.tier {
            fields.push(("tier", Json::Num(tier as f64)));
        }
        // Likewise for tenants: a v3 trace with only untagged traffic
        // is byte-identical to its v2 form except for the header
        // version.
        if !self.tenant.is_empty() {
            fields.push(("tenant", Json::Str(self.tenant.clone())));
        }
        obj(fields)
    }

    /// One JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        to_string(&self.to_json())
    }

    pub fn from_json(v: &Json) -> Result<TraceEvent> {
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace event missing {key:?}"))
        };
        let st = |key: &str| -> Result<&str> {
            v.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("trace event missing {key:?}"))
        };
        let class_name = st("class")?;
        let op_name = st("op")?;
        Ok(TraceEvent {
            seq: num("seq")? as u64,
            device: st("dev")?.to_string(),
            class: IoClass::parse(class_name)
                .ok_or_else(|| anyhow!("unknown class {class_name:?}"))?,
            op: EngineOp::parse(op_name)
                .ok_or_else(|| anyhow!("unknown op {op_name:?}"))?,
            origin: st("origin").unwrap_or("").to_string(),
            // Optional since v2; absent in v1 traces and for untiered
            // requests.
            tier: v.get("tier").and_then(Json::as_f64).map(|t| t as u32),
            // Optional since v3; absent in v1/v2 traces and for
            // untagged requests.
            tenant: v
                .get("tenant")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            bytes: num("bytes")? as u64,
            ok: matches!(v.get("ok"), Some(Json::Bool(true))),
            submit_secs: num("t")?,
            queue_secs: num("q")?,
            service_secs: num("s")?,
        })
    }
}

/// Trace file header: everything a replayer needs to rebuild the
/// recorded storage setup (or knowingly substitute a different one).
#[derive(Debug, Clone)]
pub struct TraceManifest {
    pub version: u32,
    /// Free-form label of what was recorded (workload + CLI
    /// invocation), for humans reading the diff table.
    pub workload: String,
    /// Scheduler mode label at record time (`QosConfig::mode_name`),
    /// for humans; the machine-readable config is `qos`.
    pub qos_mode: String,
    /// Full scheduler config in force at record time — weights, rate
    /// caps, preemption, adaptive targets — so a default replay
    /// rebuilds the recorded scheduler, not just its mode name.
    /// `None` for traces from recorders that didn't capture it (the
    /// replayer then falls back to the mode label).
    pub qos: Option<QosConfig>,
    /// Simulation speed-up the recorded devices ran at (uniform across
    /// the paper testbeds; informational for replay comparisons).
    pub time_scale: f64,
    /// Full models of every device the engine scheduled, so a default
    /// replay runs against exactly the recorded storage.
    pub devices: Vec<DeviceModel>,
}

fn lat_points_to_json(points: &[(u64, f64)]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|&(b, l)| Json::Arr(vec![Json::Num(b as f64), Json::Num(l)]))
            .collect(),
    )
}

fn device_to_json(m: &DeviceModel) -> Json {
    let mut fields = vec![
        ("name", Json::Str(m.name.clone())),
        ("read_bw", Json::Num(m.read_bw)),
        ("write_bw", Json::Num(m.write_bw)),
        ("read_lat", Json::Num(m.read_lat)),
        ("write_lat", Json::Num(m.write_lat)),
        ("channels", Json::Num(m.channels as f64)),
        (
            "elevator",
            Json::Arr(
                m.elevator
                    .iter()
                    .map(|&(k, g)| {
                        Json::Arr(vec![Json::Num(k as f64), Json::Num(g)])
                    })
                    .collect(),
            ),
        ),
        ("time_scale", Json::Num(m.time_scale)),
    ];
    // Per-block-size latency tables are optional: table-less models
    // serialize exactly as before, so v2-v4 traces stay byte-stable.
    if let Some(t) = &m.lat_tables {
        fields.push(("lat_read", lat_points_to_json(&t.read)));
        fields.push(("lat_write", lat_points_to_json(&t.write)));
    }
    obj(fields)
}

fn lat_points_from_json(v: &Json, key: &str) -> Result<Vec<(u64, f64)>> {
    let mut points = Vec::new();
    let Some(arr) = v.get(key).and_then(Json::as_arr) else {
        return Ok(points);
    };
    for pt in arr {
        let pair = pt
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow!("{key} point must be [bytes, secs]"))?;
        let b = pair[0]
            .as_f64()
            .ok_or_else(|| anyhow!("bad {key} block size"))?;
        let l = pair[1]
            .as_f64()
            .ok_or_else(|| anyhow!("bad {key} latency"))?;
        points.push((b as u64, l));
    }
    Ok(points)
}

fn device_from_json(v: &Json) -> Result<DeviceModel> {
    let num = |key: &str| -> Result<f64> {
        v.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("trace device missing {key:?}"))
    };
    let mut elevator = Vec::new();
    for pt in v
        .get("elevator")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("trace device missing elevator"))?
    {
        let pair = pt
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow!("elevator point must be [depth, gain]"))?;
        let k = pair[0]
            .as_f64()
            .ok_or_else(|| anyhow!("bad elevator depth"))?;
        let g = pair[1]
            .as_f64()
            .ok_or_else(|| anyhow!("bad elevator gain"))?;
        elevator.push((k as u32, g));
    }
    Ok(DeviceModel {
        name: v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace device missing name"))?
            .to_string(),
        read_bw: num("read_bw")?,
        write_bw: num("write_bw")?,
        read_lat: num("read_lat")?,
        write_lat: num("write_lat")?,
        channels: num("channels")? as usize,
        elevator,
        time_scale: num("time_scale")?,
        lat_tables: {
            let read = lat_points_from_json(v, "lat_read")?;
            let write = lat_points_from_json(v, "lat_write")?;
            if read.is_empty() && write.is_empty() {
                None // pre-table trace (v2-v4): single-point model
            } else {
                Some(LatencyTables { read, write })
            }
        },
    })
}

fn qos_to_json(q: &QosConfig) -> Json {
    let caps = Json::Arr(
        q.rate_caps
            .iter()
            .map(|c| match c {
                None => Json::Null,
                Some(cap) => obj(vec![
                    ("bytes_per_sec", Json::Num(cap.bytes_per_sec)),
                    ("burst_bytes", Json::Num(cap.burst_bytes as f64)),
                ]),
            })
            .collect(),
    );
    let adaptive = match &q.adaptive {
        None => Json::Null,
        Some(a) => obj(vec![
            ("target_ingest_p99", Json::Num(a.target_ingest_p99)),
            (
                "per_device",
                Json::Arr(
                    a.per_device
                        .iter()
                        .map(|(d, t)| {
                            Json::Arr(vec![
                                Json::Str(d.clone()),
                                Json::Num(*t),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("max_weight", Json::Num(a.max_weight as f64)),
            ("increase", Json::Num(a.increase as f64)),
            ("decay", Json::Num(a.decay)),
            ("tick", Json::Num(a.tick)),
        ]),
    };
    let tenants = match &q.tenants {
        None => Json::Null,
        Some(t) => obj(vec![
            (
                "shares",
                Json::Arr(
                    t.shares
                        .iter()
                        .map(|(name, s)| {
                            Json::Arr(vec![
                                Json::Str(name.clone()),
                                Json::Num(*s as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("default_share", Json::Num(t.default_share as f64)),
            (
                "rate_caps",
                Json::Arr(
                    t.rate_caps
                        .iter()
                        .map(|(name, cap)| {
                            obj(vec![
                                ("tenant", Json::Str(name.clone())),
                                (
                                    "bytes_per_sec",
                                    Json::Num(cap.bytes_per_sec),
                                ),
                                (
                                    "burst_bytes",
                                    Json::Num(cap.burst_bytes as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "adaptive_targets",
                Json::Arr(
                    t.adaptive_targets
                        .iter()
                        .map(|(name, x)| {
                            Json::Arr(vec![
                                Json::Str(name.clone()),
                                Json::Num(*x),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    };
    let retry = obj(vec![
        (
            "budget",
            Json::Arr(
                q.retry
                    .budget
                    .iter()
                    .map(|&b| Json::Num(b as f64))
                    .collect(),
            ),
        ),
        ("backoff", Json::Num(q.retry.backoff)),
    ]);
    obj(vec![
        ("fifo", Json::Bool(q.fifo)),
        (
            "weights",
            Json::Arr(
                q.weights.iter().map(|&w| Json::Num(w as f64)).collect(),
            ),
        ),
        ("preempt_chunks", Json::Num(q.preempt_chunks as f64)),
        ("max_yield_wait", Json::Num(q.max_yield_wait)),
        ("rate_caps", caps),
        ("adaptive", adaptive),
        ("tenants", tenants),
        ("retry", retry),
    ])
}

fn qos_from_json(v: &Json) -> Result<QosConfig> {
    let num = |key: &str| -> Result<f64> {
        v.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("trace qos missing {key:?}"))
    };
    let weights_arr = v
        .get("weights")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("trace qos missing weights"))?;
    if weights_arr.len() != IoClass::COUNT {
        bail!("trace qos has {} weights, expected {}",
              weights_arr.len(), IoClass::COUNT);
    }
    let mut weights = [0u32; IoClass::COUNT];
    for (i, w) in weights_arr.iter().enumerate() {
        weights[i] = w
            .as_f64()
            .ok_or_else(|| anyhow!("bad qos weight"))? as u32;
    }
    let caps_arr = v
        .get("rate_caps")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("trace qos missing rate_caps"))?;
    if caps_arr.len() != IoClass::COUNT {
        bail!("trace qos has {} rate caps, expected {}",
              caps_arr.len(), IoClass::COUNT);
    }
    let mut rate_caps: [Option<RateCap>; IoClass::COUNT] =
        [None; IoClass::COUNT];
    for (i, c) in caps_arr.iter().enumerate() {
        if matches!(c, Json::Null) {
            continue;
        }
        rate_caps[i] = Some(RateCap {
            bytes_per_sec: c
                .get("bytes_per_sec")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("rate cap missing bytes_per_sec"))?,
            burst_bytes: c
                .get("burst_bytes")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("rate cap missing burst_bytes"))?
                as u64,
        });
    }
    let adaptive = match v.get("adaptive") {
        None | Some(Json::Null) => None,
        Some(a) => {
            let anum = |key: &str| -> Result<f64> {
                a.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("trace adaptive missing {key:?}"))
            };
            let mut per_device = Vec::new();
            for pd in a
                .get("per_device")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
            {
                let pair = pd
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| {
                        anyhow!("per_device entry must be [name, target]")
                    })?;
                per_device.push((
                    pair[0]
                        .as_str()
                        .ok_or_else(|| anyhow!("bad per_device name"))?
                        .to_string(),
                    pair[1]
                        .as_f64()
                        .ok_or_else(|| anyhow!("bad per_device target"))?,
                ));
            }
            Some(AdaptiveQos {
                target_ingest_p99: anum("target_ingest_p99")?,
                per_device,
                max_weight: anum("max_weight")? as u32,
                increase: anum("increase")? as u32,
                decay: anum("decay")?,
                tick: anum("tick")?,
            })
        }
    };
    // Optional since v3: v1/v2 manifests have no tenants block.
    let tenants = match v.get("tenants") {
        None | Some(Json::Null) => None,
        Some(t) => {
            let mut shares = Vec::new();
            for s in t.get("shares").and_then(Json::as_arr).unwrap_or(&[]) {
                let pair = s
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| {
                        anyhow!("tenant share must be [name, share]")
                    })?;
                shares.push((
                    pair[0]
                        .as_str()
                        .ok_or_else(|| anyhow!("bad tenant share name"))?
                        .to_string(),
                    pair[1]
                        .as_f64()
                        .ok_or_else(|| anyhow!("bad tenant share"))?
                        as u32,
                ));
            }
            let mut rate_caps = Vec::new();
            for c in
                t.get("rate_caps").and_then(Json::as_arr).unwrap_or(&[])
            {
                rate_caps.push((
                    c.get("tenant")
                        .and_then(Json::as_str)
                        .ok_or_else(|| {
                            anyhow!("tenant rate cap missing tenant")
                        })?
                        .to_string(),
                    RateCap {
                        bytes_per_sec: c
                            .get("bytes_per_sec")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| {
                                anyhow!(
                                    "tenant rate cap missing bytes_per_sec"
                                )
                            })?,
                        burst_bytes: c
                            .get("burst_bytes")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| {
                                anyhow!(
                                    "tenant rate cap missing burst_bytes"
                                )
                            })?
                            as u64,
                    },
                ));
            }
            let mut adaptive_targets = Vec::new();
            for a in t
                .get("adaptive_targets")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
            {
                let pair = a
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| {
                        anyhow!("tenant target must be [name, target]")
                    })?;
                adaptive_targets.push((
                    pair[0]
                        .as_str()
                        .ok_or_else(|| anyhow!("bad tenant target name"))?
                        .to_string(),
                    pair[1]
                        .as_f64()
                        .ok_or_else(|| anyhow!("bad tenant target"))?,
                ));
            }
            Some(TenantQos {
                shares,
                default_share: t
                    .get("default_share")
                    .and_then(Json::as_f64)
                    .unwrap_or(1.0) as u32,
                rate_caps,
                adaptive_targets,
            })
        }
    };
    // Optional since the fault seam: older manifests predate retry
    // budgets and load with the default policy.
    let retry = match v.get("retry") {
        None | Some(Json::Null) => RetryPolicy::default(),
        Some(r) => {
            let budget_arr = r
                .get("budget")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("trace qos retry missing budget"))?;
            if budget_arr.len() != IoClass::COUNT {
                bail!("trace qos retry has {} budgets, expected {}",
                      budget_arr.len(), IoClass::COUNT);
            }
            let mut budget = [0u32; IoClass::COUNT];
            for (i, b) in budget_arr.iter().enumerate() {
                budget[i] = b
                    .as_f64()
                    .ok_or_else(|| anyhow!("bad qos retry budget"))?
                    as u32;
            }
            RetryPolicy {
                budget,
                backoff: r
                    .get("backoff")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| {
                        anyhow!("trace qos retry missing backoff")
                    })?,
            }
        }
    };
    Ok(QosConfig {
        fifo: matches!(v.get("fifo"), Some(Json::Bool(true))),
        weights,
        preempt_chunks: num("preempt_chunks")? as usize,
        max_yield_wait: num("max_yield_wait")?,
        rate_caps,
        adaptive,
        tenants,
        retry,
    })
}

impl TraceManifest {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("dlio_trace", Json::Num(self.version as f64)),
            ("workload", Json::Str(self.workload.clone())),
            ("qos_mode", Json::Str(self.qos_mode.clone())),
            ("time_scale", Json::Num(self.time_scale)),
            (
                "devices",
                Json::Arr(self.devices.iter().map(device_to_json).collect()),
            ),
        ];
        if let Some(q) = &self.qos {
            fields.push(("qos", qos_to_json(q)));
        }
        obj(fields)
    }

    /// One JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        to_string(&self.to_json())
    }

    pub fn from_json(v: &Json) -> Result<TraceManifest> {
        let version = v
            .get("dlio_trace")
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                anyhow!("not a dlio trace (header missing \"dlio_trace\")")
            })? as u32;
        if version > TRACE_VERSION {
            bail!(
                "trace schema v{version} is newer than this build's \
                 v{TRACE_VERSION}"
            );
        }
        let mut devices = Vec::new();
        for d in v
            .get("devices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace header missing devices"))?
        {
            devices.push(device_from_json(d)?);
        }
        let qos = match v.get("qos") {
            None | Some(Json::Null) => None,
            Some(q) => Some(qos_from_json(q)?),
        };
        Ok(TraceManifest {
            version,
            workload: v
                .get("workload")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            qos_mode: v
                .get("qos_mode")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            qos,
            time_scale: v
                .get("time_scale")
                .and_then(Json::as_f64)
                .unwrap_or(1.0),
            devices,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event() -> TraceEvent {
        TraceEvent {
            seq: 42,
            device: "ssd".into(),
            class: IoClass::Checkpoint,
            op: EngineOp::StreamWrite,
            origin: "saver".into(),
            tier: None,
            tenant: String::new(),
            bytes: 123_456,
            ok: true,
            submit_secs: 1.5,
            queue_secs: 0.25,
            service_secs: 0.125,
        }
    }

    #[test]
    fn event_roundtrips_through_jsonl() {
        let e = event();
        let back =
            TraceEvent::from_json(&Json::parse(&e.to_jsonl()).unwrap())
                .unwrap();
        assert_eq!(back, e);
        assert_eq!(back.complete_secs(), 1.875);
        assert_eq!(back.service_start_secs(), 1.75);
    }

    #[test]
    fn tiered_event_roundtrips_and_untiered_omits_the_field() {
        let mut e = event();
        e.tier = Some(1);
        let line = e.to_jsonl();
        assert!(line.contains("\"tier\""));
        let back = TraceEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, e);
        // Untiered: no "tier" key at all (v1-shaped event body).
        let e = event();
        assert!(!e.to_jsonl().contains("\"tier\""));
    }

    #[test]
    fn v1_event_without_tier_loads_as_none() {
        // A line as a v1 recorder wrote it: no tier or tenant field
        // anywhere.
        let line = "{\"seq\": 3, \"dev\": \"hdd\", \"class\": \"ingest\", \
                    \"op\": \"read\", \"origin\": \"\", \"bytes\": 512, \
                    \"ok\": true, \"t\": 0.5, \"q\": 0.1, \"s\": 0.01}";
        let e = TraceEvent::from_json(&Json::parse(line).unwrap()).unwrap();
        assert_eq!(e.tier, None);
        assert_eq!(e.tenant, "");
        assert_eq!(e.bytes, 512);
    }

    #[test]
    fn tenant_event_roundtrips_and_untagged_omits_the_field() {
        let mut e = event();
        e.tenant = "job-a".into();
        let line = e.to_jsonl();
        assert!(line.contains("\"tenant\""));
        let back =
            TraceEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, e);
        // Untagged: no "tenant" key at all (v2-shaped event body).
        let e = event();
        assert!(!e.to_jsonl().contains("\"tenant\""));
    }

    #[test]
    fn v2_event_without_tenant_loads_as_empty() {
        // A line as a v2 recorder wrote it: tier present, no tenant.
        let line = "{\"seq\": 9, \"dev\": \"ssd\", \"class\": \"drain\", \
                    \"op\": \"copy_read\", \"origin\": \"bb-drain\", \
                    \"bytes\": 4096, \"ok\": true, \"t\": 1.0, \
                    \"q\": 0.2, \"s\": 0.05, \"tier\": 1}";
        let e = TraceEvent::from_json(&Json::parse(line).unwrap()).unwrap();
        assert_eq!(e.tier, Some(1));
        assert_eq!(e.tenant, "");
    }

    #[test]
    fn failed_event_roundtrips() {
        let mut e = event();
        e.ok = false;
        e.bytes = 0;
        let back =
            TraceEvent::from_json(&Json::parse(&e.to_jsonl()).unwrap())
                .unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn event_rejects_unknown_class_and_missing_fields() {
        let mut v = event().to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("class".into(), Json::Str("warp".into()));
        }
        assert!(TraceEvent::from_json(&v).is_err());
        assert!(TraceEvent::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn manifest_roundtrips_with_device_models_and_qos() {
        // A fully-tuned scheduler: caps + adaptive per-device targets
        // + preemption must all survive the round trip, or a default
        // replay cannot rebuild the recorded setup.
        let mut qos = QosConfig::adaptive(0.004)
            .with_rate_cap(IoClass::Checkpoint, 20e6, 1 << 20)
            .with_rate_cap(IoClass::Drain, 10e6, 1 << 19);
        qos.preempt_chunks = 7;
        if let Some(a) = &mut qos.adaptive {
            a.per_device.push(("hdd".into(), 0.012));
        }
        qos.retry =
            RetryPolicy { budget: [3, 1, 0, 5], backoff: 0.0125 };
        let m = TraceManifest {
            version: TRACE_VERSION,
            workload: "microbench files=32".into(),
            qos_mode: qos.mode_name().into(),
            qos: Some(qos.clone()),
            time_scale: 8.0,
            devices: vec![crate::storage::profiles::blackdog_hdd(8.0)],
        };
        let back =
            TraceManifest::from_json(&Json::parse(&m.to_jsonl()).unwrap())
                .unwrap();
        assert_eq!(back.version, TRACE_VERSION);
        assert_eq!(back.qos_mode, "adaptive");
        assert_eq!(back.devices.len(), 1);
        let d = &back.devices[0];
        let orig = &m.devices[0];
        assert_eq!(d.name, orig.name);
        assert_eq!(d.read_bw, orig.read_bw);
        assert_eq!(d.elevator, orig.elevator);
        assert_eq!(d.channels, orig.channels);
        let q = back.qos.expect("qos survives the round trip");
        assert_eq!(q.fifo, qos.fifo);
        assert_eq!(q.weights, qos.weights);
        assert_eq!(q.preempt_chunks, 7);
        assert_eq!(q.max_yield_wait, qos.max_yield_wait);
        assert_eq!(q.rate_caps, qos.rate_caps);
        assert_eq!(q.adaptive, qos.adaptive);
        assert!(q.tenants.is_none(), "tenant-blind config stays blind");
        assert_eq!(q.retry, qos.retry);
    }

    #[test]
    fn manifest_roundtrips_latency_tables_and_defaults_to_none() {
        // A calibrated device's per-block-size tables must survive the
        // round trip; a table-less device must come back as `None`
        // (the v2-v4 single-point form), not as empty tables.
        let mut dev = crate::storage::profiles::blackdog_ssd(1.0);
        dev.lat_tables = Some(LatencyTables {
            read: vec![(4 << 10, 0.0001), (4 << 20, 0.0016)],
            write: vec![(4 << 10, 0.0002)],
        });
        let m = TraceManifest {
            version: TRACE_VERSION,
            workload: "calibrated".into(),
            qos_mode: "fifo".into(),
            qos: None,
            time_scale: 1.0,
            devices: vec![dev.clone(), crate::storage::profiles::blackdog_hdd(1.0)],
        };
        let back =
            TraceManifest::from_json(&Json::parse(&m.to_jsonl()).unwrap())
                .unwrap();
        assert_eq!(back.devices[0].lat_tables, dev.lat_tables);
        assert_eq!(back.devices[1].lat_tables, None);
    }

    #[test]
    fn manifest_without_retry_block_defaults_the_policy() {
        // Pre-fault-seam manifests carry no "retry" key: they must
        // load with the default bounded policy, not an error.
        let qos = QosConfig::default();
        let m = TraceManifest {
            version: TRACE_VERSION,
            workload: "legacy".into(),
            qos_mode: qos.mode_name().into(),
            qos: Some(qos),
            time_scale: 1.0,
            devices: vec![crate::storage::profiles::blackdog_ssd(1.0)],
        };
        let mut v = Json::parse(&m.to_jsonl()).unwrap();
        if let Json::Obj(fields) = &mut v {
            if let Some(Json::Obj(qf)) = fields.get_mut("qos") {
                qf.remove("retry");
            }
        }
        let back = TraceManifest::from_json(&v).unwrap();
        assert_eq!(
            back.qos.expect("qos survives").retry,
            RetryPolicy::default()
        );
    }

    #[test]
    fn manifest_roundtrips_tenant_qos() {
        let qos = QosConfig::default().with_tenants(
            TenantQos::default()
                .with_share("a", 4)
                .with_share("noisy", 1)
                .with_rate_cap("noisy", 15e6, 1 << 18)
                .with_adaptive_target("a", 0.002),
        );
        let m = TraceManifest {
            version: TRACE_VERSION,
            workload: "fleet".into(),
            qos_mode: qos.mode_name().into(),
            qos: Some(qos.clone()),
            time_scale: 1.0,
            devices: vec![crate::storage::profiles::blackdog_ssd(1.0)],
        };
        let back =
            TraceManifest::from_json(&Json::parse(&m.to_jsonl()).unwrap())
                .unwrap();
        let t = back
            .qos
            .expect("qos survives")
            .tenants
            .expect("tenant table survives");
        let orig = qos.tenants.unwrap();
        assert_eq!(t, orig);
    }

    #[test]
    fn manifest_without_qos_loads_as_none() {
        let m = TraceManifest {
            version: TRACE_VERSION,
            workload: "w".into(),
            qos_mode: "fifo".into(),
            qos: None,
            time_scale: 1.0,
            devices: vec![crate::storage::profiles::blackdog_ssd(1.0)],
        };
        let back =
            TraceManifest::from_json(&Json::parse(&m.to_jsonl()).unwrap())
                .unwrap();
        assert!(back.qos.is_none());
        assert_eq!(back.qos_mode, "fifo");
    }

    #[test]
    fn manifest_rejects_newer_schema_and_non_traces() {
        let newer = format!("{{\"dlio_trace\": {}}}", TRACE_VERSION + 1);
        assert!(
            TraceManifest::from_json(&Json::parse(&newer).unwrap()).is_err()
        );
        assert!(
            TraceManifest::from_json(&Json::parse("{\"a\":1}").unwrap())
                .is_err()
        );
    }
}
