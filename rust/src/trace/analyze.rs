//! Analysis over request-level trace event streams (DESIGN.md §11):
//! per-class aggregates, busy/overlap fractions, interval timelines
//! (the Fig. 8/10 view), and the legacy `Dstat` row shape derived
//! from events.

use anyhow::{bail, Result};

use crate::metrics::LatencyHistogram;
use crate::storage::{Dir, IoClass};

use super::dstat::TraceRow;
use super::event::TraceEvent;

/// Per-class aggregate over an event stream — the row shape the
/// record-vs-replay diff table compares.
#[derive(Debug, Clone, Default)]
pub struct ClassAgg {
    pub completed: u64,
    pub errors: u64,
    pub bytes: u64,
    pub mean_queue_secs: f64,
    /// Queue-wait quantiles from the same log2 histogram the engine
    /// stats use (conservative bucket upper bounds).
    pub p50_queue_secs: f64,
    pub p99_queue_secs: f64,
    /// First submit → last completion, wall seconds (0 when empty).
    pub makespan_secs: f64,
    /// Union of the class's service intervals, wall seconds: how long
    /// the class actually held the device(s).
    pub busy_secs: f64,
}

/// Length of the union of (possibly overlapping) intervals.
fn union_secs(iv: Vec<(f64, f64)>) -> f64 {
    merged(iv).iter().map(|(a, b)| b - a).sum()
}

/// Merge to disjoint sorted intervals (for union and intersection
/// sweeps).
fn merged(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.retain(|(a, b)| b > a);
    iv.sort_by(|x, y| x.0.total_cmp(&y.0));
    let mut out: Vec<(f64, f64)> = Vec::new();
    for (a, b) in iv {
        match out.last_mut() {
            Some((_, ce)) if a <= *ce => {
                if b > *ce {
                    *ce = b;
                }
            }
            _ => out.push((a, b)),
        }
    }
    out
}

fn service_intervals(events: &[TraceEvent], class: IoClass) -> Vec<(f64, f64)> {
    events
        .iter()
        .filter(|e| e.class == class)
        .map(|e| (e.service_start_secs(), e.complete_secs()))
        .collect()
}

/// Aggregate an event stream per class (indexed by `IoClass::index`).
pub fn class_aggregates(events: &[TraceEvent]) -> [ClassAgg; IoClass::COUNT] {
    let mut hists: [LatencyHistogram; IoClass::COUNT] =
        std::array::from_fn(|_| LatencyHistogram::new());
    let mut aggs: [ClassAgg; IoClass::COUNT] =
        std::array::from_fn(|_| ClassAgg::default());
    let mut first: [f64; IoClass::COUNT] = [f64::INFINITY; IoClass::COUNT];
    let mut last: [f64; IoClass::COUNT] = [0.0; IoClass::COUNT];
    let mut queue_sum: [f64; IoClass::COUNT] = [0.0; IoClass::COUNT];
    for e in events {
        let c = e.class.index();
        aggs[c].completed += 1;
        if !e.ok {
            aggs[c].errors += 1;
        }
        aggs[c].bytes += e.bytes;
        hists[c].record(e.queue_secs);
        queue_sum[c] += e.queue_secs;
        first[c] = first[c].min(e.submit_secs);
        last[c] = last[c].max(e.complete_secs());
    }
    for (c, agg) in aggs.iter_mut().enumerate() {
        if agg.completed > 0 {
            agg.mean_queue_secs = queue_sum[c] / agg.completed as f64;
            agg.p50_queue_secs = hists[c].quantile(0.50);
            agg.p99_queue_secs = hists[c].p99();
            agg.makespan_secs = (last[c] - first[c]).max(0.0);
        }
        agg.busy_secs = union_secs(service_intervals(
            events,
            IoClass::ALL[c],
        ));
    }
    aggs
}

/// Fraction of the *shorter* class's busy time during which both
/// classes had a request in service — e.g. how much of a checkpoint
/// burst's device time overlapped live ingest (the paper's
/// compute/ingest-overlap question, asked of the I/O classes the
/// trace can see).  0 when either class never ran.
pub fn overlap_fraction(
    events: &[TraceEvent],
    a: IoClass,
    b: IoClass,
) -> f64 {
    let ia = merged(service_intervals(events, a));
    let ib = merged(service_intervals(events, b));
    let la: f64 = ia.iter().map(|(s, e)| e - s).sum();
    let lb: f64 = ib.iter().map(|(s, e)| e - s).sum();
    if la <= 0.0 || lb <= 0.0 {
        return 0.0;
    }
    // Two-pointer sweep over the disjoint sorted interval lists.
    let mut inter = 0.0;
    let (mut i, mut j) = (0, 0);
    while i < ia.len() && j < ib.len() {
        let lo = ia[i].0.max(ib[j].0);
        let hi = ia[i].1.min(ib[j].1);
        if hi > lo {
            inter += hi - lo;
        }
        if ia[i].1 <= ib[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    inter / la.min(lb)
}

/// Fraction of the whole trace's makespan during which `class` had a
/// request in service (1 - this is the slack another activity could
/// hide in).
pub fn busy_fraction(events: &[TraceEvent], class: IoClass) -> f64 {
    let start = events
        .iter()
        .map(|e| e.submit_secs)
        .fold(f64::INFINITY, f64::min);
    let end = events
        .iter()
        .map(|e| e.complete_secs())
        .fold(0.0f64, f64::max);
    if !(end > start) {
        return 0.0;
    }
    union_secs(service_intervals(events, class)) / (end - start)
}

/// The legacy `Dstat` interval view derived from the event stream:
/// per (device, interval) read/write byte bins with zero-filled gaps —
/// the exact row shape `Dstat::rows()` produces, which is what makes
/// the interval tracer a *view* over events rather than a separate
/// instrument.  Event bytes are binned at completion time (the
/// recorder sees whole requests, not per-chunk grants), so at
/// sub-request interval widths the two tracers can place a request's
/// bytes in adjacent bins; per-device totals always agree.
pub fn dstat_rows(
    events: &[TraceEvent],
    interval_secs: f64,
) -> Result<Vec<TraceRow>> {
    if !(interval_secs > 0.0) || !interval_secs.is_finite() {
        bail!("interval must be a positive number of seconds");
    }
    let mut bins: std::collections::HashMap<(String, u64), (u64, u64)> =
        std::collections::HashMap::new();
    for e in events {
        let iv = (e.complete_secs() / interval_secs) as u64;
        let slot = bins.entry((e.device.clone(), iv)).or_insert((0, 0));
        match e.op.dir() {
            Dir::Read => slot.0 += e.bytes,
            Dir::Write => slot.1 += e.bytes,
        }
    }
    // One renderer for both tracers (`dstat::render_rows`): the parity
    // guarantee is structural, not two copies kept in sync by a test.
    Ok(super::dstat::render_rows(&bins))
}

/// One interval of one (device, class) lane — the Fig. 8/10 per-class
/// timeline the paper hand-plotted from dstat, now first-class.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineRow {
    pub interval: u64,
    pub device: String,
    pub class: IoClass,
    pub ops: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
}

/// Per-class interval timeline (sorted by device, class, interval;
/// only active lanes are emitted, but intervals within a lane are
/// zero-filled so plots show idle gaps).
pub fn timeline(
    events: &[TraceEvent],
    interval_secs: f64,
) -> Result<Vec<TimelineRow>> {
    if !(interval_secs > 0.0) || !interval_secs.is_finite() {
        bail!("interval must be a positive number of seconds");
    }
    type Key = (String, usize);
    let mut bins: std::collections::BTreeMap<Key, Vec<(u64, u64, u64)>> =
        std::collections::BTreeMap::new();
    let max_iv = events
        .iter()
        .map(|e| (e.complete_secs() / interval_secs) as u64)
        .max()
        .unwrap_or(0);
    for e in events {
        let iv = (e.complete_secs() / interval_secs) as usize;
        let lane = bins
            .entry((e.device.clone(), e.class.index()))
            .or_insert_with(|| vec![(0, 0, 0); max_iv as usize + 1]);
        let slot = &mut lane[iv];
        slot.0 += 1;
        match e.op.dir() {
            Dir::Read => slot.1 += e.bytes,
            Dir::Write => slot.2 += e.bytes,
        }
    }
    let mut out = Vec::new();
    for ((device, class_idx), lane) in bins {
        for (iv, (ops, r, w)) in lane.into_iter().enumerate() {
            out.push(TimelineRow {
                interval: iv as u64,
                device: device.clone(),
                class: IoClass::ALL[class_idx],
                ops,
                read_bytes: r,
                write_bytes: w,
            });
        }
    }
    Ok(out)
}

/// Render a timeline as CSV: `sec,device,class,ops,read_mb,write_mb`.
pub fn timeline_csv(events: &[TraceEvent], interval_secs: f64) -> Result<String> {
    let mut s = String::from("sec,device,class,ops,read_mb,write_mb\n");
    for row in timeline(events, interval_secs)? {
        s.push_str(&format!(
            "{:.3},{},{},{},{:.3},{:.3}\n",
            row.interval as f64 * interval_secs,
            row.device,
            row.class,
            row.ops,
            row.read_bytes as f64 / 1e6,
            row.write_bytes as f64 / 1e6,
        ));
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::EngineOp;

    fn ev(
        device: &str,
        class: IoClass,
        op: EngineOp,
        bytes: u64,
        submit: f64,
        queue: f64,
        service: f64,
    ) -> TraceEvent {
        TraceEvent {
            seq: 0,
            device: device.into(),
            class,
            op,
            origin: String::new(),
            tier: None,
            tenant: String::new(),
            bytes,
            ok: true,
            submit_secs: submit,
            queue_secs: queue,
            service_secs: service,
        }
    }

    #[test]
    fn aggregates_split_by_class() {
        let events = vec![
            ev("d", IoClass::Ingest, EngineOp::Read, 100, 0.0, 0.010, 0.005),
            ev("d", IoClass::Ingest, EngineOp::Read, 200, 0.01, 0.010, 0.005),
            ev("d", IoClass::Checkpoint, EngineOp::Write, 5000, 0.0, 0.100,
               0.050),
        ];
        let aggs = class_aggregates(&events);
        let ing = &aggs[IoClass::Ingest.index()];
        assert_eq!(ing.completed, 2);
        assert_eq!(ing.bytes, 300);
        assert!((ing.mean_queue_secs - 0.010).abs() < 1e-9);
        // makespan: first submit 0.0 -> last complete 0.025
        assert!((ing.makespan_secs - 0.025).abs() < 1e-9);
        let ck = &aggs[IoClass::Checkpoint.index()];
        assert_eq!(ck.completed, 1);
        assert_eq!(ck.bytes, 5000);
        // Conservative log2 bucket upper bound: >= the true wait,
        // < 2x above it.
        assert!(ck.p99_queue_secs >= 0.100 && ck.p99_queue_secs < 0.2);
        assert_eq!(aggs[IoClass::Drain.index()].completed, 0);
    }

    #[test]
    fn busy_union_merges_overlapping_service() {
        // Two overlapping ingest services [0.1,0.3] and [0.2,0.4]:
        // busy = 0.3, not 0.4.
        let events = vec![
            ev("d", IoClass::Ingest, EngineOp::ProbeRead, 1, 0.0, 0.1, 0.2),
            ev("d", IoClass::Ingest, EngineOp::ProbeRead, 1, 0.0, 0.2, 0.2),
        ];
        let aggs = class_aggregates(&events);
        assert!((aggs[IoClass::Ingest.index()].busy_secs - 0.3).abs() < 1e-9);
        // Trace spans 0.0 -> 0.4; busy fraction = 0.3/0.4.
        assert!((busy_fraction(&events, IoClass::Ingest) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn overlap_fraction_measures_co_service() {
        // Ingest in service [0.0, 0.4]; checkpoint [0.3, 0.5]: overlap
        // 0.1 over the shorter class's 0.2 busy = 0.5.
        let events = vec![
            ev("d", IoClass::Ingest, EngineOp::ProbeRead, 1, 0.0, 0.0, 0.4),
            ev("d", IoClass::Checkpoint, EngineOp::ProbeWrite, 1, 0.3, 0.0,
               0.2),
        ];
        let f = overlap_fraction(&events, IoClass::Ingest, IoClass::Checkpoint);
        assert!((f - 0.5).abs() < 1e-9, "overlap {f}");
        // Symmetric, and zero against an idle class.
        let g = overlap_fraction(&events, IoClass::Checkpoint, IoClass::Ingest);
        assert!((g - f).abs() < 1e-9);
        assert_eq!(overlap_fraction(&events, IoClass::Ingest, IoClass::Drain),
                   0.0);
    }

    #[test]
    fn dstat_rows_bin_by_device_and_direction() {
        let events = vec![
            ev("hdd", IoClass::Ingest, EngineOp::Read, 100, 0.0, 0.0, 0.01),
            ev("hdd", IoClass::Ingest, EngineOp::Read, 50, 0.02, 0.0, 0.01),
            ev("hdd", IoClass::Checkpoint, EngineOp::Write, 7, 0.0, 0.0, 0.01),
            ev("ssd", IoClass::Ingest, EngineOp::ProbeRead, 1, 0.0, 0.0, 0.01),
        ];
        let rows = dstat_rows(&events, 10.0).unwrap();
        assert_eq!(rows.len(), 2); // one wide interval, two devices
        assert_eq!(rows[0].device, "hdd");
        assert_eq!(rows[0].read_bytes, 150);
        assert_eq!(rows[0].write_bytes, 7);
        assert_eq!(rows[1].device, "ssd");
        assert_eq!(rows[1].read_bytes, 1);
        assert!(dstat_rows(&events, 0.0).is_err());
        assert!(dstat_rows(&events, f64::NAN).is_err());
    }

    #[test]
    fn dstat_view_over_events_matches_legacy_tracer() {
        // Satellite parity proof: run mixed traffic through a sim with
        // BOTH tracers attached — the legacy device-level Dstat and
        // the request-level event stream — and derive Dstat's rows
        // from the events.  With an interval wider than the run, the
        // two binning clocks (per-chunk grants vs whole-request
        // completions) collapse into the same bins, so the derived
        // rows must equal the legacy tracer's exactly.
        use crate::storage::{DeviceModel, EngineObserver, SimPath, StorageSim};
        use crate::trace::{Dstat, MemorySink};
        use std::sync::Arc;

        let dir = std::env::temp_dir().join(format!(
            "dlio-trace-parity-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let model = |name: &str| DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
            lat_tables: None,
        };
        let dstat = Arc::new(Dstat::new(1e6)); // one wide bin
        let sim = StorageSim::new(
            dir,
            vec![model("fast"), model("slow")],
            0, // cold cache: every read is device-charged on both sides
            Arc::clone(&dstat) as Arc<dyn crate::storage::IoObserver>,
        )
        .unwrap();
        let sink = MemorySink::new();
        sim.engine()
            .set_observer(Arc::clone(&sink) as Arc<dyn EngineObserver>);

        // Mixed traffic: writes, cold reads, probes, cross-device copy.
        let a = SimPath::new("fast", "a.bin");
        let b = SimPath::new("slow", "a.bin");
        sim.write(&a, &vec![1u8; 50_000]).unwrap();
        assert_eq!(sim.read(&a).unwrap().len(), 50_000);
        sim.probe_read("slow", 12_345).unwrap();
        sim.probe_write("fast", 6_789).unwrap();
        sim.copy(&a, &b).unwrap();

        let rows_legacy = dstat.rows();
        let rows_events = dstat_rows(&sink.events(), 1e6).unwrap();
        assert_eq!(
            rows_events, rows_legacy,
            "event-derived interval view diverged from the legacy tracer"
        );
        // And the totals surface agrees per device/direction.
        assert_eq!(dstat.totals("fast"), (100_000, 56_789));
        assert_eq!(dstat.totals("slow"), (12_345, 50_000));
    }

    #[test]
    fn timeline_zero_fills_idle_intervals_per_lane() {
        let events = vec![
            ev("d", IoClass::Ingest, EngineOp::Read, 10, 0.0, 0.0, 0.01),
            ev("d", IoClass::Ingest, EngineOp::Read, 20, 0.25, 0.0, 0.01),
        ];
        let rows = timeline(&events, 0.1).unwrap();
        // Intervals 0..=2 for the single (d, ingest) lane.
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].ops, 1);
        assert_eq!(rows[1].ops, 0, "idle interval not zero-filled");
        assert_eq!(rows[2].read_bytes, 20);
        let csv = timeline_csv(&events, 0.1).unwrap();
        assert!(csv.starts_with("sec,device,class,ops,read_mb,write_mb\n"));
        assert_eq!(csv.lines().count(), 4);
    }
}
