//! [`TraceReplayer`]-side of the trace subsystem: load a recorded
//! request stream and re-issue it through a fresh [`IoEngine`] —
//! against the recorded storage, or any other profile / QoS config
//! (DESIGN.md §11).
//!
//! Every recorded request replays as a pacing-only probe of the same
//! byte count, device, class, and direction: the storage model defines
//! the service-time envelope, so no backing corpus is needed to re-run
//! a workload.  Two modes:
//!
//! * **Open-loop** — honor the recorded inter-submit gaps, divided by
//!   `speed`: the workload as an arrival process.  Queue waits then
//!   show how a different device/QoS absorbs the *same offered load*.
//! * **Closed-loop** (default) — as fast as possible while preserving
//!   the recorded dependency structure: request *r* is submitted only
//!   once every request that had **completed before r was submitted**
//!   at record time has completed in the replay.  This reproduces the
//!   recorded concurrency profile (in-flight windows, per-class
//!   submission order, stream-chunk dependencies collapse to their
//!   completion order) without reproducing think time — which is what
//!   makes record-on-fast / replay-on-slow meaningful, and what lets a
//!   same-profile replay reproduce the recorded queue waits.
//!
//! The replay measures itself with a [`MemorySink`] — the same event
//! stream machinery that produced the recording — so the
//! [`ReplayReport`] diff compares like with like.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::Table;
use crate::storage::engine::DEFAULT_CHUNK;
use crate::storage::{
    profiles, Clock, ClockSpec, Device, Dir, FaultPlan, IoClass, IoEngine,
    IoRequest, IoTicket, NullObserver, QosConfig,
};
use crate::util::json::{obj, Json};

use super::analyze::{self, ClassAgg};
use super::event::{TraceEvent, TraceManifest};
use super::recorder::MemorySink;
use crate::compute::StepRecord;

/// A loaded trace: header + events in submit order (+ any step-level
/// records, schema v4).
pub struct Trace {
    pub manifest: TraceManifest,
    pub events: Vec<TraceEvent>,
    /// Step records (`"rec":"step"` lines); empty for v1–v3 traces
    /// and for recordings without a training loop.  Replay ignores
    /// them — they describe the consumer, not the offered I/O load.
    pub steps: Vec<StepRecord>,
}

impl Trace {
    /// Parse a JSONL trace file (header line + one event per line).
    /// Streams line by line — a trace holds one line per request, so
    /// only the parsed events (never the whole file text) are held in
    /// memory.
    pub fn load(path: &Path) -> Result<Trace> {
        use std::io::BufRead as _;
        let file = std::fs::File::open(path)
            .with_context(|| format!("read trace {}", path.display()))?;
        let mut manifest: Option<TraceManifest> = None;
        let mut events = Vec::new();
        let mut steps = Vec::new();
        for (i, line) in std::io::BufReader::new(file).lines().enumerate() {
            let line = line
                .with_context(|| format!("read trace {}", path.display()))?;
            let lineno = i + 1; // file line numbers, blanks included
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let v = Json::parse(trimmed)
                .map_err(|e| anyhow!("trace line {lineno}: {e}"))?;
            match &manifest {
                None => manifest = Some(TraceManifest::from_json(&v)?),
                Some(_) if StepRecord::is_step_line(&v) => steps.push(
                    StepRecord::from_json(&v)
                        .with_context(|| format!("trace line {lineno}"))?,
                ),
                Some(_) => events.push(
                    TraceEvent::from_json(&v)
                        .with_context(|| format!("trace line {lineno}"))?,
                ),
            }
        }
        let manifest =
            manifest.ok_or_else(|| anyhow!("empty trace file"))?;
        // Replay order = recorded submit order (seq breaks ties, so
        // per-class ordering is exactly as recorded).
        events.sort_by(|a, b| {
            a.submit_secs
                .total_cmp(&b.submit_secs)
                .then(a.seq.cmp(&b.seq))
        });
        steps.sort_by_key(|s| s.step);
        Ok(Trace { manifest, events, steps })
    }

    /// Per-class aggregates of the *recorded* run.
    pub fn recorded_aggregates(&self) -> [ClassAgg; IoClass::COUNT] {
        analyze::class_aggregates(&self.events)
    }
}

/// How the recorded stream is re-offered to the engine.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplayMode {
    /// Dependency-preserving, as fast as possible (see module docs).
    Closed,
    /// Recorded inter-submit gaps divided by `speed`.
    Open { speed: f64 },
}

impl ReplayMode {
    pub fn name(&self) -> &'static str {
        match self {
            ReplayMode::Closed => "closed",
            ReplayMode::Open { .. } => "open",
        }
    }
}

/// What to replay against.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub mode: ReplayMode,
    /// Scheduler for the replay engine (independent of what was
    /// recorded — the A/B knob).
    pub qos: QosConfig,
    /// Substitute every traced device's model with this paper profile
    /// (`hdd|ssd|optane|lustre`), keeping the traced device *names*
    /// so events still route.  `None` replays against the recorded
    /// models.
    pub profile: Option<String>,
    /// Override the devices' simulation speed-up (default: recorded).
    pub time_scale: Option<f64>,
    /// Time source for the replay engine.  `Virtual` runs the whole
    /// replay in discrete-event time (same modelled durations, no
    /// sleeping) — the default for `--sweep` matrices.
    pub clock: ClockSpec,
    /// Fault plan spec (`kind[:device[:start[:duration]]]`, see
    /// [`FaultPlan::parse`]) armed on the replay devices before the
    /// first submission — replay the same recorded stream with and
    /// without an injected fault to measure degraded-mode behavior.
    pub inject: Option<String>,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            mode: ReplayMode::Closed,
            qos: QosConfig::default(),
            profile: None,
            time_scale: None,
            clock: ClockSpec::Wall,
            inject: None,
        }
    }
}

/// What a replay run produced.
pub struct ReplayOutcome {
    /// Clock seconds (wall or virtual, per [`ReplayConfig::clock`])
    /// from first submission to last completion.
    pub wall_secs: f64,
    /// The replay's own event stream (same schema as the recording).
    pub replayed: Vec<TraceEvent>,
    /// Requests whose replay ticket failed (0 in practice: probes
    /// cannot fail on a healthy engine).
    pub errors: u64,
}

/// Heap entry ordering closed-loop dependencies by recorded
/// completion time.
struct PendingDone {
    complete: f64,
    seq: u64,
    ticket: IoTicket,
}

impl PartialEq for PendingDone {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for PendingDone {}

impl PartialOrd for PendingDone {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for PendingDone {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.complete
            .total_cmp(&other.complete)
            .then(self.seq.cmp(&other.seq))
    }
}

fn submit_probe(engine: &IoEngine, ev: &TraceEvent) -> Result<IoTicket> {
    let req = match ev.op.dir() {
        Dir::Read => IoRequest::ProbeRead {
            device: ev.device.clone(),
            bytes: ev.bytes,
        },
        Dir::Write => IoRequest::ProbeWrite {
            device: ev.device.clone(),
            bytes: ev.bytes,
        },
    };
    // Re-tag the recorded tier and tenant so replayed events keep
    // their hierarchy and fleet attribution (per-tier / per-tenant
    // stats rows survive replay, and a tenant-aware replay QoS config
    // schedules the stream under the recorded keys).  v1/v2 events
    // carry no tenant: the empty string is the default tenant, so
    // they replay exactly as before.
    let tenant = crate::storage::TenantId::new(&ev.tenant);
    crate::storage::with_tenant(&tenant, || {
        crate::storage::with_origin("replay", || match ev.tier {
            Some(t) => crate::storage::with_tier(t, || {
                engine.submit_class(req, ev.class)
            }),
            None => engine.submit_class(req, ev.class),
        })
    })
}

/// Build the replay devices per `cfg` (recorded models, or a profile
/// substitution that keeps the traced names).
fn replay_devices(
    manifest: &TraceManifest,
    cfg: &ReplayConfig,
    clock: &Clock,
) -> Result<HashMap<String, Arc<Device>>> {
    if manifest.devices.is_empty() {
        bail!("trace manifest lists no devices");
    }
    let mut devices = HashMap::new();
    for m in &manifest.devices {
        let mut model = match &cfg.profile {
            None => m.clone(),
            Some(p) => {
                let ts = cfg.time_scale.unwrap_or(m.time_scale);
                // A typo'd profile name must say what IS valid, not
                // just fail (the by_name presets are the contract).
                let mut pm = profiles::by_name(p, ts).ok_or_else(|| {
                    anyhow!(
                        "unknown profile {p:?} (valid: {})",
                        profiles::DEVICE_NAMES.join(", ")
                    )
                })?;
                pm.name = m.name.clone();
                pm
            }
        };
        if let Some(ts) = cfg.time_scale {
            if !(ts > 0.0) {
                bail!("time scale must be positive");
            }
            model.time_scale = ts;
        }
        devices.insert(
            model.name.clone(),
            Arc::new(Device::with_clock(
                model,
                Arc::new(NullObserver),
                clock.clone(),
            )),
        );
    }
    if let Some(spec) = &cfg.inject {
        let plan = FaultPlan::parse(spec)?;
        for fs in &plan.devices {
            if fs.device != "*" && !devices.contains_key(&fs.device) {
                let mut names: Vec<&str> =
                    devices.keys().map(String::as_str).collect();
                names.sort_unstable();
                bail!(
                    "fault plan targets unknown device {:?} (valid: {})",
                    fs.device,
                    names.join(", ")
                );
            }
        }
        for (name, dev) in &devices {
            dev.set_health(plan.arm(name, clock).map(Arc::new));
        }
    }
    Ok(devices)
}

/// Re-issue `trace` through a fresh engine per `cfg`.
pub fn replay(trace: &Trace, cfg: &ReplayConfig) -> Result<ReplayOutcome> {
    let clock = cfg.clock.build();
    let devices = replay_devices(&trace.manifest, cfg, &clock)?;
    let engine = IoEngine::with_config(&devices, DEFAULT_CHUNK, cfg.qos.clone());
    let sink = MemorySink::new();
    engine
        .set_observer(Arc::clone(&sink) as Arc<dyn crate::storage::EngineObserver>);
    let mut errors = 0u64;
    // Register the driver: virtual time must not advance while this
    // thread is mid-submission (it advances while we block on tickets
    // or sleep out open-loop gaps).
    let _reg = clock.enter();
    let t0 = clock.now();
    match cfg.mode {
        ReplayMode::Closed => {
            let mut done: BinaryHeap<Reverse<PendingDone>> = BinaryHeap::new();
            for ev in &trace.events {
                // Honor recorded dependencies: everything that had
                // completed before this submission completes first.
                loop {
                    let ready = match done.peek() {
                        Some(Reverse(p)) => p.complete <= ev.submit_secs,
                        None => false,
                    };
                    if !ready {
                        break;
                    }
                    let Reverse(p) = done.pop().expect("peeked entry");
                    if p.ticket.wait().is_err() {
                        errors += 1;
                    }
                }
                let ticket = submit_probe(&engine, ev)?;
                done.push(Reverse(PendingDone {
                    complete: ev.complete_secs(),
                    seq: ev.seq,
                    ticket,
                }));
            }
            while let Some(Reverse(p)) = done.pop() {
                if p.ticket.wait().is_err() {
                    errors += 1;
                }
            }
        }
        ReplayMode::Open { speed } => {
            if !(speed > 0.0) || !speed.is_finite() {
                bail!("replay speed must be positive, got {speed}");
            }
            let base = trace
                .events
                .first()
                .map(|e| e.submit_secs)
                .unwrap_or(0.0);
            let mut tickets = Vec::with_capacity(trace.events.len());
            for ev in &trace.events {
                let target = (ev.submit_secs - base) / speed;
                let elapsed = clock.now() - t0;
                if target > elapsed {
                    clock.sleep_secs((target - elapsed).min(3600.0));
                }
                tickets.push(submit_probe(&engine, ev)?);
            }
            for t in tickets {
                if t.wait().is_err() {
                    errors += 1;
                }
            }
        }
    }
    let wall_secs = clock.now() - t0;
    // Every ticket resolved, and events deliver before tickets do, so
    // the sink is complete.
    engine.clear_observer();
    drop(engine);
    Ok(ReplayOutcome { wall_secs, replayed: sink.events(), errors })
}

/// Record-vs-replay comparison: per-class aggregates side by side,
/// plus the ingest/checkpoint service-overlap fractions.
pub struct ReplayReport {
    pub mode: String,
    pub qos_mode: String,
    /// Profile replayed against (`"recorded"` when not substituted).
    pub profile: String,
    pub wall_secs: f64,
    pub errors: u64,
    pub recorded: [ClassAgg; IoClass::COUNT],
    pub replayed: [ClassAgg; IoClass::COUNT],
    /// Ingest×Checkpoint service-overlap fraction, recorded / replayed
    /// ([`analyze::overlap_fraction`]).
    pub recorded_overlap: f64,
    pub replayed_overlap: f64,
}

/// Build the diff report for a finished replay.
pub fn report(
    trace: &Trace,
    cfg: &ReplayConfig,
    outcome: &ReplayOutcome,
) -> ReplayReport {
    ReplayReport {
        mode: cfg.mode.name().to_string(),
        qos_mode: cfg.qos.mode_name().to_string(),
        profile: cfg
            .profile
            .clone()
            .unwrap_or_else(|| "recorded".to_string()),
        wall_secs: outcome.wall_secs,
        errors: outcome.errors,
        recorded: trace.recorded_aggregates(),
        replayed: analyze::class_aggregates(&outcome.replayed),
        recorded_overlap: analyze::overlap_fraction(
            &trace.events,
            IoClass::Ingest,
            IoClass::Checkpoint,
        ),
        replayed_overlap: analyze::overlap_fraction(
            &outcome.replayed,
            IoClass::Ingest,
            IoClass::Checkpoint,
        ),
    }
}

impl ReplayReport {
    /// Classes with activity on either side, in priority order.
    fn active_classes(&self) -> Vec<IoClass> {
        IoClass::ALL
            .into_iter()
            .filter(|c| {
                self.recorded[c.index()].completed > 0
                    || self.replayed[c.index()].completed > 0
            })
            .collect()
    }

    /// Human diff table: one row per active class, recorded → replayed.
    pub fn to_table(&self) -> String {
        let mut t = Table::new(&[
            "class",
            "reqs rec->rep",
            "MB rec->rep",
            "p50 queue ms",
            "p99 queue ms",
            "makespan s",
        ]);
        for c in self.active_classes() {
            let (r, p) = (&self.recorded[c.index()], &self.replayed[c.index()]);
            t.row(&[
                c.name().to_string(),
                format!("{} -> {}", r.completed, p.completed),
                format!(
                    "{:.2} -> {:.2}",
                    r.bytes as f64 / 1e6,
                    p.bytes as f64 / 1e6
                ),
                format!(
                    "{:.3} -> {:.3}",
                    r.p50_queue_secs * 1e3,
                    p.p50_queue_secs * 1e3
                ),
                format!(
                    "{:.3} -> {:.3}",
                    r.p99_queue_secs * 1e3,
                    p.p99_queue_secs * 1e3
                ),
                format!("{:.3} -> {:.3}", r.makespan_secs, p.makespan_secs),
            ]);
        }
        let mut out = format!(
            "# replay mode={} qos={} profile={} wall={:.3}s errors={}\n",
            self.mode, self.qos_mode, self.profile, self.wall_secs,
            self.errors,
        );
        out.push_str(&t.render());
        out.push_str(&format!(
            "ingest/checkpoint service overlap: recorded {:.1}% -> \
             replayed {:.1}%\n",
            self.recorded_overlap * 100.0,
            self.replayed_overlap * 100.0,
        ));
        out
    }

    fn agg_json(a: &ClassAgg) -> Json {
        obj(vec![
            ("completed", Json::Num(a.completed as f64)),
            ("errors", Json::Num(a.errors as f64)),
            ("bytes", Json::Num(a.bytes as f64)),
            ("mean_queue_ms", Json::Num(a.mean_queue_secs * 1e3)),
            ("p50_queue_ms", Json::Num(a.p50_queue_secs * 1e3)),
            ("p99_queue_ms", Json::Num(a.p99_queue_secs * 1e3)),
            ("makespan_secs", Json::Num(a.makespan_secs)),
            ("busy_secs", Json::Num(a.busy_secs)),
        ])
    }

    /// Machine-readable diff (all four classes, stable schema).
    pub fn to_json(&self) -> Json {
        let classes = IoClass::ALL
            .into_iter()
            .map(|c| {
                (
                    c.name().to_string(),
                    obj(vec![
                        ("recorded", Self::agg_json(&self.recorded[c.index()])),
                        ("replayed", Self::agg_json(&self.replayed[c.index()])),
                    ]),
                )
            })
            .collect();
        obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("qos", Json::Str(self.qos_mode.clone())),
            ("profile", Json::Str(self.profile.clone())),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("errors", Json::Num(self.errors as f64)),
            (
                "overlap",
                obj(vec![
                    ("recorded", Json::Num(self.recorded_overlap)),
                    ("replayed", Json::Num(self.replayed_overlap)),
                ]),
            ),
            ("classes", Json::Obj(classes)),
        ])
    }

    /// CSV diff: one row per active class.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "class,rec_reqs,rep_reqs,rec_mb,rep_mb,rec_p50_queue_ms,\
             rep_p50_queue_ms,rec_p99_queue_ms,rep_p99_queue_ms,\
             rec_makespan_s,rep_makespan_s\n",
        );
        for c in self.active_classes() {
            let (r, p) = (&self.recorded[c.index()], &self.replayed[c.index()]);
            out.push_str(&format!(
                "{},{},{},{:.3},{:.3},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4}\n",
                c.name(),
                r.completed,
                p.completed,
                r.bytes as f64 / 1e6,
                p.bytes as f64 / 1e6,
                r.p50_queue_secs * 1e3,
                p.p50_queue_secs * 1e3,
                r.p99_queue_secs * 1e3,
                p.p99_queue_secs * 1e3,
                r.makespan_secs,
                p.makespan_secs,
            ));
        }
        out
    }
}

/// Replay-driven what-if sweep: run ONE recorded trace across a QoS
/// scheduler-mode matrix (the `qos-sweep` mode axis) and return one
/// diff report per cell — `dlio trace-replay --sweep fifo,static,...`.
/// Every cell replays the same request stream under `base` (mode,
/// profile, time scale), varying only the scheduler.
pub fn sweep(
    trace: &Trace,
    base: &ReplayConfig,
    modes: &[String],
    adaptive_target: f64,
) -> Result<Vec<ReplayReport>> {
    if modes.is_empty() {
        bail!("--sweep needs at least one scheduler mode");
    }
    // Validate the whole matrix before replaying the first cell.
    let mut cfgs = Vec::with_capacity(modes.len());
    for mode in modes {
        let mut cfg = base.clone();
        cfg.qos = QosConfig::parse_mode(mode, adaptive_target)?;
        cfgs.push(cfg);
    }
    let mut out = Vec::with_capacity(cfgs.len());
    for cfg in &cfgs {
        let outcome = replay(trace, cfg)?;
        out.push(report(trace, cfg, &outcome));
    }
    Ok(out)
}

/// One CSV row per sweep cell (header + flattened ingest/checkpoint
/// diff columns — the row shape mirrors `qos-sweep`).
pub fn sweep_to_csv(reports: &[ReplayReport]) -> String {
    let mut out = String::from(
        "qos,profile,mode,wall_secs,errors,\
         ingest_rec_p99_ms,ingest_rep_p99_ms,ingest_mb,\
         ckpt_rec_p99_ms,ckpt_rep_p99_ms,ckpt_mb\n",
    );
    for r in reports {
        let ing_r = &r.recorded[IoClass::Ingest.index()];
        let ing_p = &r.replayed[IoClass::Ingest.index()];
        let ck_r = &r.recorded[IoClass::Checkpoint.index()];
        let ck_p = &r.replayed[IoClass::Checkpoint.index()];
        out.push_str(&format!(
            "{},{},{},{:.4},{},{:.4},{:.4},{:.2},{:.4},{:.4},{:.2}\n",
            r.qos_mode,
            r.profile,
            r.mode,
            r.wall_secs,
            r.errors,
            ing_r.p99_queue_secs * 1e3,
            ing_p.p99_queue_secs * 1e3,
            ing_p.bytes as f64 / 1e6,
            ck_r.p99_queue_secs * 1e3,
            ck_p.p99_queue_secs * 1e3,
            ck_p.bytes as f64 / 1e6,
        ));
    }
    out
}

/// JSON array of the sweep's full diff reports (one per cell).
pub fn sweep_to_json(reports: &[ReplayReport]) -> Json {
    Json::Arr(reports.iter().map(|r| r.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::manifest::Sample;
    use crate::pipeline::{sharded_reader, Dataset};
    use crate::storage::{DeviceModel, SimPath, StorageSim};
    use crate::trace::TraceRecorder;
    use crate::util::Rng;
    use std::path::PathBuf;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dlio-trace-replay-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// Deterministic-wait device: one channel, latency-dominated, so
    /// queue waits are multiples of the 2.5 ms op latency — solidly
    /// inside one log2 histogram bucket on any plausible host.
    fn lat_device(name: &str) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 200e6,
            write_bw: 200e6,
            read_lat: 2.5e-3,
            write_lat: 2.5e-3,
            channels: 1,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
            lat_tables: None,
        }
    }

    /// Record a fixed-seed sharded-reader microbench (+ one checkpoint
    /// burst) and return the loaded trace.
    fn record_microbench(tag: &str) -> Trace {
        let dir = scratch(tag);
        let sim = Arc::new(
            StorageSim::cold(dir.join("sim"), vec![lat_device("d")]).unwrap(),
        );
        let mut samples: Vec<Sample> = (0..24)
            .map(|i| {
                let p = SimPath::new("d", format!("corpus/f{i}.bin"));
                sim.write(&p, &vec![(i % 251) as u8; 32 * 1024]).unwrap();
                Sample { path: p, label: i as u32 }
            })
            .collect();
        // Fixed-seed shuffle: the microbench protocol, deterministic.
        let mut rng = Rng::new(7);
        for i in (1..samples.len()).rev() {
            let j = rng.index(i + 1);
            samples.swap(i, j);
        }
        sim.drop_caches();
        sim.engine().reset_stats();
        let trace_path = dir.join("t.jsonl");
        let rec = TraceRecorder::create(
            &trace_path,
            &super::super::event::TraceManifest {
                version: super::super::event::TRACE_VERSION,
                workload: "test-microbench".into(),
                qos_mode: sim.engine().qos().mode_name().into(),
                qos: Some(sim.engine().qos().clone()),
                time_scale: 1.0,
                devices: vec![lat_device("d")],
            },
        )
        .unwrap();
        sim.engine().set_observer(rec.observer());
        let mut ds = sharded_reader(samples, Arc::clone(&sim), 2, 3);
        let mut ckpt = Vec::new();
        let mut n = 0;
        while let Some(item) = ds.next() {
            item.unwrap();
            n += 1;
            if n == 12 {
                // Mid-run checkpoint burst (the §V contention pattern).
                for _ in 0..3 {
                    ckpt.push(
                        sim.engine()
                            .submit(IoRequest::ProbeWrite {
                                device: "d".into(),
                                bytes: 128 * 1024,
                            })
                            .unwrap(),
                    );
                }
            }
        }
        assert_eq!(n, 24);
        for t in ckpt {
            t.wait().unwrap();
        }
        sim.engine().clear_observer();
        rec.finish().unwrap();
        Trace::load(&trace_path).unwrap()
    }

    #[test]
    fn closed_loop_roundtrip_reproduces_bytes_and_tail_waits() {
        // The acceptance criterion: record a fixed-seed microbench,
        // closed-loop replay on the SAME profile -> per-class byte
        // totals match exactly, per-class p99 queue waits within 20%
        // (same log2 bucket: the conservative upper bounds are equal
        // when the waits land in the same bucket).
        let trace = record_microbench("roundtrip");
        let rec_aggs = trace.recorded_aggregates();
        let ing = &rec_aggs[IoClass::Ingest.index()];
        assert_eq!(ing.completed, 24);
        assert_eq!(ing.bytes, 24 * 32 * 1024);
        assert_eq!(rec_aggs[IoClass::Checkpoint.index()].completed, 3);

        let outcome = replay(&trace, &ReplayConfig::default()).unwrap();
        assert_eq!(outcome.errors, 0);
        let rep_aggs = analyze::class_aggregates(&outcome.replayed);
        for c in [IoClass::Ingest, IoClass::Checkpoint] {
            let (r, p) = (&rec_aggs[c.index()], &rep_aggs[c.index()]);
            assert_eq!(r.completed, p.completed, "{c}: request count");
            assert_eq!(r.bytes, p.bytes, "{c}: byte totals must be exact");
        }
        let (rq, pq) = (ing.p99_queue_secs,
                        rep_aggs[IoClass::Ingest.index()].p99_queue_secs);
        assert!(rq > 0.0, "recorded run shows no queueing to reproduce");
        let ratio = pq / rq;
        assert!(
            (0.8..=1.2).contains(&ratio),
            "ingest p99 queue wait drifted: recorded {:.2} ms, \
             replayed {:.2} ms",
            rq * 1e3,
            pq * 1e3
        );
    }

    #[test]
    fn closed_loop_preserves_per_class_submission_order() {
        let trace = record_microbench("order");
        let outcome = replay(&trace, &ReplayConfig::default()).unwrap();
        // Replayed per-class submit order equals recorded per-class
        // order (bytes identify requests: every corpus file is the
        // same size, but the checkpoint probes differ from reads, so
        // compare the class sequence).
        let rec_classes: Vec<IoClass> =
            trace.events.iter().map(|e| e.class).collect();
        let mut rep = outcome.replayed.clone();
        rep.sort_by(|a, b| {
            a.submit_secs.total_cmp(&b.submit_secs).then(a.seq.cmp(&b.seq))
        });
        let rep_classes: Vec<IoClass> = rep.iter().map(|e| e.class).collect();
        assert_eq!(rec_classes, rep_classes);
    }

    #[test]
    fn open_loop_honors_recorded_gaps_scaled_by_speed() {
        // Synthetic trace: two probes 200 ms apart.  At speed 2 the
        // replay must take ~100 ms; at speed 20, ~10 ms.
        let manifest = TraceManifest {
            version: super::super::event::TRACE_VERSION,
            workload: "gap".into(),
            qos_mode: "static".into(),
            qos: None,
            time_scale: 1000.0,
            devices: vec![DeviceModel {
                name: "d".into(),
                read_bw: 1e9,
                write_bw: 1e9,
                read_lat: 0.0,
                write_lat: 0.0,
                channels: 4,
                elevator: vec![(1, 1.0)],
                time_scale: 1000.0,
                lat_tables: None,
            }],
        };
        let mk = |seq: u64, t: f64| TraceEvent {
            seq,
            device: "d".into(),
            class: IoClass::Ingest,
            op: crate::storage::EngineOp::ProbeRead,
            origin: String::new(),
            tier: None,
            tenant: String::new(),
            bytes: 1024,
            ok: true,
            submit_secs: t,
            queue_secs: 0.0001,
            service_secs: 0.0001,
        };
        let trace = Trace {
            manifest,
            events: vec![mk(0, 0.0), mk(1, 0.2)],
            steps: Vec::new(),
        };
        let run = |speed: f64| {
            let cfg = ReplayConfig {
                mode: ReplayMode::Open { speed },
                ..ReplayConfig::default()
            };
            replay(&trace, &cfg).unwrap().wall_secs
        };
        let slow = run(2.0);
        let fast = run(20.0);
        assert!(slow >= 0.095, "gap not honored: {slow}s");
        assert!(fast < slow, "speed-up did not shrink the schedule");
        // Closed-loop ignores the gap entirely (no dependency links
        // the two probes).
        let closed = replay(&trace, &ReplayConfig::default()).unwrap();
        assert!(closed.wall_secs < 0.05, "closed loop slept the gap");
        assert!(replay(
            &trace,
            &ReplayConfig {
                mode: ReplayMode::Open { speed: 0.0 },
                ..ReplayConfig::default()
            }
        )
        .is_err());
    }

    #[test]
    fn profile_substitution_keeps_traced_names_and_slows_replay() {
        let trace = record_microbench("profile");
        // Replay against the (much slower per-op) paper HDD at high
        // acceleration: events still route (device name "d" is kept),
        // and the report carries the substituted profile label.
        let cfg = ReplayConfig {
            profile: Some("hdd".into()),
            time_scale: Some(200.0),
            ..ReplayConfig::default()
        };
        let outcome = replay(&trace, &cfg).unwrap();
        assert_eq!(outcome.errors, 0);
        let rep = report(&trace, &cfg, &outcome);
        assert_eq!(rep.profile, "hdd");
        let aggs = analyze::class_aggregates(&outcome.replayed);
        assert_eq!(
            aggs[IoClass::Ingest.index()].bytes,
            24 * 32 * 1024,
            "byte totals survive profile substitution"
        );
        // Regression: the unknown-profile error must list the valid
        // preset names, not just fail bare.
        let err = replay(
            &trace,
            &ReplayConfig {
                profile: Some("floppy".into()),
                ..ReplayConfig::default()
            },
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("hdd") && err.contains("lustre"),
            "unknown-profile error does not list presets: {err}"
        );
    }

    #[test]
    fn report_renders_table_json_and_csv() {
        let trace = record_microbench("report");
        let cfg = ReplayConfig::default();
        let outcome = replay(&trace, &cfg).unwrap();
        let rep = report(&trace, &cfg, &outcome);
        let table = rep.to_table();
        assert!(table.contains("ingest"));
        assert!(table.contains("checkpoint"));
        assert!(table.contains("service overlap"));
        // JSON round-trips through the in-repo parser with the schema
        // CI asserts on.
        let v = Json::parse(&crate::util::json::to_string(&rep.to_json()))
            .unwrap();
        assert_eq!(v.get("errors").and_then(Json::as_f64), Some(0.0));
        let ing = v
            .get("classes")
            .and_then(|c| c.get("ingest"))
            .expect("ingest class in report");
        let rec_bytes = ing
            .get("recorded")
            .and_then(|r| r.get("bytes"))
            .and_then(Json::as_f64)
            .unwrap();
        let rep_bytes = ing
            .get("replayed")
            .and_then(|r| r.get("bytes"))
            .and_then(Json::as_f64)
            .unwrap();
        assert_eq!(rec_bytes, rep_bytes);
        // CSV: header + one row per active class, constant arity.
        let csv = rep.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines.len() >= 3);
        let ncols = lines[0].split(',').count();
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged csv: {l}");
        }
    }

    #[test]
    fn sweep_runs_one_cell_per_mode_with_exact_bytes() {
        // Satellite: one recorded trace across the qos-sweep scheduler
        // matrix — every cell replays the same stream, byte-exact.
        let trace = record_microbench("sweep");
        let rec = trace.recorded_aggregates();
        let modes: Vec<String> =
            vec!["fifo".into(), "static".into(), "adaptive".into()];
        let reports =
            sweep(&trace, &ReplayConfig::default(), &modes, 0.005).unwrap();
        assert_eq!(reports.len(), 3);
        for (r, mode) in reports.iter().zip(&modes) {
            assert_eq!(&r.qos_mode, mode);
            assert_eq!(r.errors, 0);
            for c in [IoClass::Ingest, IoClass::Checkpoint] {
                assert_eq!(
                    r.replayed[c.index()].bytes,
                    rec[c.index()].bytes,
                    "{mode}/{c}: sweep cell diverged from the recording"
                );
            }
        }
        // One CSV row per cell, constant arity.
        let csv = sweep_to_csv(&reports);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4, "header + one row per cell");
        let ncols = lines[0].split(',').count();
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged csv: {l}");
        }
        // JSON parses back as an array of cells.
        let v = Json::parse(&crate::util::json::to_string(&sweep_to_json(
            &reports,
        )))
        .unwrap();
        match v {
            Json::Arr(cells) => assert_eq!(cells.len(), 3),
            other => panic!("expected array, got {other:?}"),
        }
        // An unknown mode fails the whole sweep before any cell runs.
        assert!(sweep(
            &trace,
            &ReplayConfig::default(),
            &["banana".into()],
            0.005
        )
        .is_err());
    }

    #[test]
    fn virtual_replay_is_byte_exact_and_deterministic() {
        // The sweep default: closed-loop replay on a virtual clock.
        // Byte totals match the recording exactly, and two runs of the
        // same stream land on the same discrete-event makespan — time
        // is computed, not measured, so nothing on the host can move
        // it.
        let trace = record_microbench("virt");
        let cfg = ReplayConfig {
            clock: ClockSpec::Virtual,
            ..ReplayConfig::default()
        };
        let a = replay(&trace, &cfg).unwrap();
        let b = replay(&trace, &cfg).unwrap();
        assert_eq!(a.errors, 0);
        let rec = trace.recorded_aggregates();
        let rep = analyze::class_aggregates(&a.replayed);
        for c in [IoClass::Ingest, IoClass::Checkpoint] {
            assert_eq!(
                rep[c.index()].bytes,
                rec[c.index()].bytes,
                "{c}: virtual replay diverged from the recording"
            );
        }
        assert!(a.wall_secs > 0.0, "virtual makespan must be modelled");
        assert!(
            (a.wall_secs - b.wall_secs).abs() < 1e-9,
            "virtual replays not deterministic: {} vs {}",
            a.wall_secs,
            b.wall_secs
        );
    }

    #[test]
    fn v2_trace_without_tenants_loads_and_replays_unchanged() {
        // Back-compat: a pre-tenant (v2-shaped) trace — no "tenant"
        // key on any line — loads, replays, and every replayed event
        // lands on the default tenant.  Untagged events serialize
        // without the key, so the file written here is byte-shaped
        // like a genuine v2 recording.
        let dir = scratch("v2compat");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = TraceManifest {
            version: 2,
            workload: "legacy".into(),
            qos_mode: "static".into(),
            qos: None,
            time_scale: 1000.0,
            devices: vec![lat_device("d")],
        };
        let mk = |seq: u64, t: f64| TraceEvent {
            seq,
            device: "d".into(),
            class: IoClass::Ingest,
            op: crate::storage::EngineOp::ProbeRead,
            origin: String::new(),
            tier: None,
            tenant: String::new(),
            bytes: 4096,
            ok: true,
            submit_secs: t,
            queue_secs: 0.001,
            service_secs: 0.001,
        };
        let mut text = manifest.to_jsonl();
        text.push('\n');
        for i in 0..4 {
            let line = mk(i, i as f64 * 0.01).to_jsonl();
            assert!(
                !line.contains("tenant"),
                "untagged event must serialize v2-shaped: {line}"
            );
            text.push_str(&line);
            text.push('\n');
        }
        let path = dir.join("legacy.jsonl");
        std::fs::write(&path, text).unwrap();
        let trace = Trace::load(&path).unwrap();
        assert_eq!(trace.events.len(), 4);
        assert!(trace.events.iter().all(|e| e.tenant.is_empty()));
        let cfg = ReplayConfig {
            clock: ClockSpec::Virtual,
            ..ReplayConfig::default()
        };
        let outcome = replay(&trace, &cfg).unwrap();
        assert_eq!(outcome.errors, 0);
        assert_eq!(outcome.replayed.len(), 4);
        assert!(
            outcome.replayed.iter().all(|e| e.tenant.is_empty()),
            "v2 events must replay on the default tenant"
        );
    }

    #[test]
    fn v1_through_v3_traces_load_under_v4_with_empty_steps() {
        // Schema v4 added trailing per-step record lines; every older
        // on-disk shape must keep loading (with `steps` empty), and a
        // v4 file's step lines must ride along without disturbing the
        // request-event replay.
        let dir = scratch("vercompat");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |seq: u64, t: f64| TraceEvent {
            seq,
            device: "d".into(),
            class: IoClass::Ingest,
            op: crate::storage::EngineOp::ProbeRead,
            origin: String::new(),
            tier: None,
            tenant: String::new(),
            bytes: 4096,
            ok: true,
            submit_secs: t,
            queue_secs: 0.001,
            service_secs: 0.001,
        };
        let write_trace = |version, steps: &[StepRecord]| -> PathBuf {
            let manifest = TraceManifest {
                version,
                workload: format!("legacy-v{version}"),
                qos_mode: "static".into(),
                qos: None,
                time_scale: 1000.0,
                devices: vec![lat_device("d")],
            };
            let mut text = manifest.to_jsonl();
            text.push('\n');
            for i in 0..3 {
                text.push_str(&mk(i, i as f64 * 0.01).to_jsonl());
                text.push('\n');
            }
            for s in steps {
                text.push_str(&s.to_jsonl());
                text.push('\n');
            }
            let path = dir.join(format!("legacy-v{version}.jsonl"));
            std::fs::write(&path, text).unwrap();
            path
        };
        for version in 1..=3 {
            let trace = Trace::load(&write_trace(version, &[])).unwrap();
            assert_eq!(trace.manifest.version, version);
            assert_eq!(trace.events.len(), 3, "v{version} lost events");
            assert!(
                trace.steps.is_empty(),
                "v{version} trace must load with no step records"
            );
        }
        // Current-version file with step lines appended after the
        // events (the append_steps layout).
        let steps = [
            StepRecord {
                step: 0,
                start_secs: 0.0,
                input_wait_secs: 0.002,
                compute_secs: 0.004,
                ckpt_stall_secs: 0.0,
                images: 8,
            },
            StepRecord {
                step: 1,
                start_secs: 0.006,
                input_wait_secs: 0.001,
                compute_secs: 0.004,
                ckpt_stall_secs: 0.003,
                images: 8,
            },
        ];
        let trace = Trace::load(&write_trace(
            super::super::event::TRACE_VERSION,
            &steps,
        ))
        .unwrap();
        assert_eq!(trace.events.len(), 3);
        assert_eq!(trace.steps, steps.to_vec());
        let cfg = ReplayConfig {
            clock: ClockSpec::Virtual,
            ..ReplayConfig::default()
        };
        let outcome = replay(&trace, &cfg).unwrap();
        assert_eq!(outcome.errors, 0);
        assert_eq!(
            outcome.replayed.len(),
            3,
            "step lines must not become replayed requests"
        );
    }

    #[test]
    fn replay_re_tags_recorded_tenants() {
        // v3: replayed probes carry the recorded tenant, so per-tenant
        // stats rows and tenant-aware replay QoS see the same keys the
        // recording did.
        let manifest = TraceManifest {
            version: super::super::event::TRACE_VERSION,
            workload: "fleet".into(),
            qos_mode: "static".into(),
            qos: None,
            time_scale: 1000.0,
            devices: vec![lat_device("d")],
        };
        let mk = |seq: u64, t: f64, tenant: &str| TraceEvent {
            seq,
            device: "d".into(),
            class: IoClass::Ingest,
            op: crate::storage::EngineOp::ProbeRead,
            origin: String::new(),
            tier: None,
            tenant: tenant.to_string(),
            bytes: 4096,
            ok: true,
            submit_secs: t,
            queue_secs: 0.001,
            service_secs: 0.001,
        };
        let trace = Trace {
            manifest,
            events: vec![
                mk(0, 0.00, "alpha"),
                mk(1, 0.01, "beta"),
                mk(2, 0.02, "alpha"),
                mk(3, 0.03, ""),
            ],
            steps: Vec::new(),
        };
        let cfg = ReplayConfig {
            clock: ClockSpec::Virtual,
            ..ReplayConfig::default()
        };
        let outcome = replay(&trace, &cfg).unwrap();
        assert_eq!(outcome.errors, 0);
        let mut rep: Vec<String> = outcome
            .replayed
            .iter()
            .map(|e| e.tenant.clone())
            .collect();
        rep.sort();
        assert_eq!(rep, vec!["", "alpha", "alpha", "beta"]);
    }

    /// Synthetic four-probe trace on a single latency device — the
    /// smallest stream that exercises closed-loop dependencies, used
    /// by the fault-injection tests below.
    fn tiny_trace(workload: &str) -> Trace {
        let manifest = TraceManifest {
            version: super::super::event::TRACE_VERSION,
            workload: workload.into(),
            qos_mode: "static".into(),
            qos: None,
            time_scale: 1.0,
            devices: vec![lat_device("d")],
        };
        let mk = |seq: u64, t: f64| TraceEvent {
            seq,
            device: "d".into(),
            class: IoClass::Ingest,
            op: crate::storage::EngineOp::ProbeRead,
            origin: String::new(),
            tier: None,
            tenant: String::new(),
            bytes: 4096,
            ok: true,
            submit_secs: t,
            queue_secs: 0.001,
            service_secs: 0.001,
        };
        Trace {
            manifest,
            events: (0..4).map(|i| mk(i, i as f64 * 0.01)).collect(),
            steps: Vec::new(),
        }
    }

    #[test]
    fn inject_error_lists_valid_fault_kinds_and_devices() {
        // Satellite: a typo'd --inject plan must say what IS valid —
        // every fault kind, in the same style as the clock / profile /
        // share-scheme errors.
        let trace = tiny_trace("badinject");
        let err = replay(
            &trace,
            &ReplayConfig {
                inject: Some("quantum".into()),
                ..ReplayConfig::default()
            },
        )
        .unwrap_err()
        .to_string();
        for kind in crate::storage::FAULT_KINDS {
            assert!(
                err.contains(kind),
                "inject error does not list {kind:?}: {err}"
            );
        }
        // A plan naming a device the trace never recorded lists the
        // traced device names instead of failing bare.
        let err = replay(
            &trace,
            &ReplayConfig {
                inject: Some("offline:nvme9".into()),
                ..ReplayConfig::default()
            },
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("nvme9") && err.contains("(valid: d)"),
            "unknown-device inject error unhelpful: {err}"
        );
    }

    #[test]
    fn injected_fault_replay_degrades_deterministically() {
        // The §14 determinism gate at unit scale: the same recorded
        // stream under the same injected fault on a virtual clock
        // lands on a bit-identical makespan, and the fault actually
        // bites (slow stretches the schedule, offline fails probes).
        let trace = tiny_trace("inject");
        let base = ReplayConfig {
            clock: ClockSpec::Virtual,
            ..ReplayConfig::default()
        };
        let healthy = replay(&trace, &base).unwrap();
        assert_eq!(healthy.errors, 0);

        let slow = ReplayConfig {
            inject: Some("slow:d".into()),
            ..base.clone()
        };
        let a = replay(&trace, &slow).unwrap();
        let b = replay(&trace, &slow).unwrap();
        assert_eq!(a.errors, 0, "a slow device still serves");
        assert!(
            a.wall_secs > healthy.wall_secs * 2.0,
            "slow fault did not stretch the replay: healthy {} vs {}",
            healthy.wall_secs,
            a.wall_secs
        );
        assert!(
            (a.wall_secs - b.wall_secs).abs() < 1e-9,
            "injected replays not deterministic: {} vs {}",
            a.wall_secs,
            b.wall_secs
        );

        // An offline device fails every probe even after the default
        // retry budget — the failures surface in `errors`, never as a
        // panic or a hang.
        let off = replay(
            &trace,
            &ReplayConfig {
                inject: Some("offline:d".into()),
                ..base.clone()
            },
        )
        .unwrap();
        assert_eq!(off.errors, trace.events.len() as u64);
    }

    #[test]
    fn load_rejects_garbage_and_empty_files() {
        let dir = scratch("badload");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("empty.jsonl");
        std::fs::write(&p, "").unwrap();
        assert!(Trace::load(&p).is_err());
        let p = dir.join("notjson.jsonl");
        std::fs::write(&p, "hello\n").unwrap();
        assert!(Trace::load(&p).is_err());
        let p = dir.join("nottrace.jsonl");
        std::fs::write(&p, "{\"x\": 1}\n").unwrap();
        assert!(Trace::load(&p).is_err());
    }
}
