//! The tracer proper.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::storage::{Dir, IoObserver};

/// One interval of one device's activity.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    pub device: String,
    /// Interval index (0 = first interval after tracer start).
    pub interval: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
}

struct State {
    /// (device, interval) -> (read, write)
    bins: HashMap<(String, u64), (u64, u64)>,
}

/// Render `(device, interval) -> (read, write)` bins as rows sorted
/// by (device, interval) with zero-filled gaps — shared by the legacy
/// tracer and the event-stream view (`analyze::dstat_rows`), which is
/// what keeps their output shapes in lockstep.
pub(crate) fn render_rows(
    bins: &HashMap<(String, u64), (u64, u64)>,
) -> Vec<TraceRow> {
    let devices: Vec<String> = bins
        .keys()
        .map(|(d, _)| d.clone())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    let max_iv = bins.keys().map(|(_, i)| *i).max().unwrap_or(0);
    let mut out = Vec::new();
    for d in devices {
        for iv in 0..=max_iv {
            let (r, w) =
                bins.get(&(d.clone(), iv)).copied().unwrap_or((0, 0));
            out.push(TraceRow {
                device: d.clone(),
                interval: iv,
                read_bytes: r,
                write_bytes: w,
            });
        }
    }
    out
}

/// Interval-binned byte counter, dstat-equivalent.
pub struct Dstat {
    start: Instant,
    /// Interval width in seconds (dstat default: 1.0).
    interval: f64,
    state: Mutex<State>,
}

impl Dstat {
    /// Fallible constructor: a non-positive or non-finite interval is
    /// a configuration error the CLI reports instead of panicking
    /// (regression: `dlio trace --interval-secs 0` used to trip the
    /// assert below).
    pub fn try_new(interval_secs: f64) -> anyhow::Result<Self> {
        if !(interval_secs > 0.0) || !interval_secs.is_finite() {
            anyhow::bail!(
                "interval must be a positive number of seconds, \
                 got {interval_secs}"
            );
        }
        Ok(Dstat {
            start: Instant::now(),
            interval: interval_secs,
            state: Mutex::new(State { bins: HashMap::new() }),
        })
    }

    pub fn new(interval_secs: f64) -> Self {
        Self::try_new(interval_secs).expect("positive finite interval")
    }

    /// dstat's default once-per-second sampling.
    pub fn per_second() -> Self {
        Self::new(1.0)
    }

    pub fn interval_secs(&self) -> f64 {
        self.interval
    }

    /// Elapsed intervals since tracer start.
    pub fn now_interval(&self) -> u64 {
        (self.start.elapsed().as_secs_f64() / self.interval) as u64
    }

    /// Drain the trace as rows sorted by (device, interval), including
    /// zero rows for gaps so plots show idle periods.
    pub fn rows(&self) -> Vec<TraceRow> {
        render_rows(&self.state.lock().unwrap().bins)
    }

    /// Render as dstat-style CSV: `sec,device,read_mb,write_mb`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("sec,device,read_mb,write_mb\n");
        for row in self.rows() {
            s.push_str(&format!(
                "{:.1},{},{:.3},{:.3}\n",
                row.interval as f64 * self.interval,
                row.device,
                row.read_bytes as f64 / 1e6,
                row.write_bytes as f64 / 1e6,
            ));
        }
        s
    }

    /// Total (read, write) bytes seen for a device.
    pub fn totals(&self, device: &str) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        st.bins
            .iter()
            .filter(|((d, _), _)| d == device)
            .fold((0, 0), |(ar, aw), (_, (r, w))| (ar + r, aw + w))
    }
}

impl IoObserver for Dstat {
    fn record(&self, device: &str, dir: Dir, bytes: u64) {
        let iv = self.now_interval();
        let mut st = self.state.lock().unwrap();
        let e = st.bins.entry((device.to_string(), iv)).or_insert((0, 0));
        match dir {
            Dir::Read => e.0 += bytes,
            Dir::Write => e.1 += bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_by_device_and_direction() {
        let d = Dstat::new(10.0); // wide interval: everything in bin 0
        d.record("hdd", Dir::Read, 100);
        d.record("hdd", Dir::Read, 50);
        d.record("hdd", Dir::Write, 7);
        d.record("ssd", Dir::Read, 1);
        assert_eq!(d.totals("hdd"), (150, 7));
        assert_eq!(d.totals("ssd"), (1, 0));
        let rows = d.rows();
        assert_eq!(rows.len(), 2); // one interval, two devices
    }

    #[test]
    fn intervals_split_over_time() {
        let d = Dstat::new(0.05);
        d.record("x", Dir::Read, 10);
        std::thread::sleep(std::time::Duration::from_millis(120));
        d.record("x", Dir::Read, 20);
        let rows = d.rows();
        let active: Vec<_> =
            rows.iter().filter(|r| r.read_bytes > 0).collect();
        assert_eq!(active.len(), 2);
        assert!(active[1].interval >= active[0].interval + 2);
        // Gap rows present (idle intervals rendered as zero).
        assert!(rows.iter().any(|r| r.read_bytes == 0));
    }

    #[test]
    fn csv_shape() {
        let d = Dstat::new(1.0);
        d.record("hdd", Dir::Write, 2_000_000);
        let csv = d.to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "sec,device,read_mb,write_mb");
        assert_eq!(lines.next().unwrap(), "0.0,hdd,0.000,2.000");
    }

    #[test]
    fn empty_tracer_renders_header_only() {
        let d = Dstat::per_second();
        assert_eq!(d.to_csv(), "sec,device,read_mb,write_mb\n");
        assert_eq!(d.rows().len(), 0);
    }

    #[test]
    fn non_positive_intervals_error_instead_of_panicking() {
        // Regression: Dstat::new asserted, so `dlio trace
        // --interval-secs 0` panicked instead of reporting a CLI
        // error.
        assert!(Dstat::try_new(0.0).is_err());
        assert!(Dstat::try_new(-1.0).is_err());
        assert!(Dstat::try_new(f64::NAN).is_err());
        assert!(Dstat::try_new(f64::INFINITY).is_err());
        assert!(Dstat::try_new(0.5).is_ok());
    }
}
