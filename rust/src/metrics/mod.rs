//! Measurement helpers: the paper's median-of-six protocol, wall
//! timers, and aligned table rendering for the figure/table benches.

use std::time::Instant;

/// Median of a slice (sorts in place).  Empty input -> 0.
pub fn median(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    // total_cmp, not partial_cmp().unwrap(): a NaN sample (e.g. a 0/0
    // rate from an empty interval) must not panic the whole report.
    xs.sort_by(|a, b| a.total_cmp(b));
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

/// Relative spread of measurements vs their median — the paper reports
/// "<1% on Blackdog, <4-6% on Tegner" (§IV); used to sanity-check runs.
pub fn rel_spread(xs: &mut [f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let med = median(xs);
    if med == 0.0 {
        return 0.0;
    }
    let max_dev = xs
        .iter()
        .map(|x| (x - med).abs())
        .fold(0.0f64, f64::max);
    max_dev / med
}

/// The paper's measurement protocol: run `reps` times, discard the
/// first (warm-up), return the median of the rest.
pub fn median_of_reps(reps: usize, mut run: impl FnMut(usize) -> f64) -> f64 {
    assert!(reps >= 2, "need at least warm-up + 1 measurement");
    let mut vals = Vec::with_capacity(reps - 1);
    for i in 0..reps {
        let v = run(i);
        if i > 0 {
            vals.push(v);
        }
    }
    median(&mut vals)
}

/// Number of log2 latency buckets: bucket `i` covers
/// `[2^i, 2^(i+1))` microseconds, so 40 buckets span 1 µs .. 2^40 µs
/// (~12.7 days — nothing a simulated request can plausibly exceed).
pub const LAT_BUCKETS: usize = 40;

/// Fixed-size log2 latency histogram: bounded memory regardless of
/// request count, good to a factor-of-two resolution — exactly what
/// per-class queue-latency percentiles (tf-Darshan-style) need.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; LAT_BUCKETS],
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; LAT_BUCKETS], total: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(secs: f64) -> usize {
        let us = (secs * 1e6).max(1.0);
        (us.log2().floor() as usize).min(LAT_BUCKETS - 1)
    }

    /// Record one sample (seconds).
    pub fn record(&mut self, secs: f64) {
        self.counts[Self::bucket(secs)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Quantile estimate in seconds: the *upper bound* of the first
    /// bucket whose cumulative count reaches `q * total` (conservative
    /// — never under-reports a tail latency).  Empty histogram -> 0.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0)
            as u64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return 2f64.powi(i as i32 + 1) * 1e-6;
            }
        }
        2f64.powi(LAT_BUCKETS as i32) * 1e-6
    }

    /// p99 shorthand (the Fig. 4/8 tail-latency headline number).
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Simple wall-clock stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Column-aligned plain-text table (the benches print paper-style rows
/// with this).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}", c, w = widths[i]));
                if i + 1 < ncol {
                    out.push_str("  ");
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even_empty() {
        assert_eq!(median(&mut []), 0.0);
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn median_survives_nan_samples() {
        // Regression: partial_cmp().unwrap() panicked here.  Under
        // total_cmp a NaN sorts after every number, so the median of
        // the remaining finite samples is still returned.
        let m = median(&mut [1.0, f64::NAN, 2.0]);
        assert_eq!(m, 2.0);
    }

    #[test]
    fn median_of_reps_discards_warmup() {
        // Warm-up returns an outlier; median must ignore it.
        let vals = [100.0, 1.0, 2.0, 3.0, 2.0, 1.0];
        let mut i = 0;
        let m = median_of_reps(6, |_| {
            let v = vals[i];
            i += 1;
            v
        });
        assert_eq!(m, 2.0);
    }

    #[test]
    fn rel_spread_small_for_tight_runs() {
        let mut xs = [100.0, 100.5, 99.8, 100.2];
        assert!(rel_spread(&mut xs) < 0.01);
        let mut ys = [100.0, 130.0];
        assert!(rel_spread(&mut ys) > 0.1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Device", "MB/s"]);
        t.row(&["hdd".into(), "163.00".into()]);
        t.row(&["optane".into(), "1603.06".into()]);
        let s = t.render();
        let lines: Vec<_> = s.lines().collect();
        assert!(lines[0].starts_with("Device"));
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines.len(), 4);
        // Columns aligned: "MB/s" column starts at same offset in rows.
        let col = lines[0].find("MB/s").unwrap();
        assert_eq!(&lines[2][col - 2..col], "  ");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn latency_histogram_quantiles() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), 0.0);
        // 99 fast samples (~10 us) + 1 slow (~100 ms): p50 stays in the
        // fast bucket, p99+ reaches the slow one.
        for _ in 0..99 {
            h.record(10e-6);
        }
        h.record(0.1);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        assert!(p50 <= 32e-6, "p50 {p50}");
        // The single 100 ms outlier is the max: quantile(1.0) lands in
        // its bucket (conservative: never below the true sample, at
        // most 2x above).
        let pmax = h.quantile(1.0);
        assert!((0.1..=0.2).contains(&pmax), "pmax {pmax}");
        // Sub-microsecond samples clamp into the first bucket.
        let mut tiny = LatencyHistogram::new();
        tiny.record(0.0);
        assert!(tiny.quantile(1.0) <= 4e-6);
    }

    #[test]
    fn latency_histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1e-3);
        b.record(1e-3);
        b.record(0.5);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!(a.quantile(1.0) >= 0.5);
    }

    #[test]
    fn timer_measures() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(t.secs() >= 0.02);
    }
}
