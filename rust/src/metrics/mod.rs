//! Measurement helpers: the paper's median-of-six protocol, wall
//! timers, and aligned table rendering for the figure/table benches.

use std::time::Instant;

/// Median of a slice (sorts in place).  Empty input -> 0.
pub fn median(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

/// Relative spread of measurements vs their median — the paper reports
/// "<1% on Blackdog, <4-6% on Tegner" (§IV); used to sanity-check runs.
pub fn rel_spread(xs: &mut [f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let med = median(xs);
    if med == 0.0 {
        return 0.0;
    }
    let max_dev = xs
        .iter()
        .map(|x| (x - med).abs())
        .fold(0.0f64, f64::max);
    max_dev / med
}

/// The paper's measurement protocol: run `reps` times, discard the
/// first (warm-up), return the median of the rest.
pub fn median_of_reps(reps: usize, mut run: impl FnMut(usize) -> f64) -> f64 {
    assert!(reps >= 2, "need at least warm-up + 1 measurement");
    let mut vals = Vec::with_capacity(reps - 1);
    for i in 0..reps {
        let v = run(i);
        if i > 0 {
            vals.push(v);
        }
    }
    median(&mut vals)
}

/// Simple wall-clock stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Column-aligned plain-text table (the benches print paper-style rows
/// with this).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}", c, w = widths[i]));
                if i + 1 < ncol {
                    out.push_str("  ");
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even_empty() {
        assert_eq!(median(&mut []), 0.0);
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn median_of_reps_discards_warmup() {
        // Warm-up returns an outlier; median must ignore it.
        let vals = [100.0, 1.0, 2.0, 3.0, 2.0, 1.0];
        let mut i = 0;
        let m = median_of_reps(6, |_| {
            let v = vals[i];
            i += 1;
            v
        });
        assert_eq!(m, 2.0);
    }

    #[test]
    fn rel_spread_small_for_tight_runs() {
        let mut xs = [100.0, 100.5, 99.8, 100.2];
        assert!(rel_spread(&mut xs) < 0.01);
        let mut ys = [100.0, 130.0];
        assert!(rel_spread(&mut ys) > 0.1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Device", "MB/s"]);
        t.row(&["hdd".into(), "163.00".into()]);
        t.row(&["optane".into(), "1603.06".into()]);
        let s = t.render();
        let lines: Vec<_> = s.lines().collect();
        assert!(lines[0].starts_with("Device"));
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines.len(), 4);
        // Columns aligned: "MB/s" column starts at same offset in rows.
        let col = lines[0].find("MB/s").unwrap();
        assert_eq!(&lines[2][col - 2..col], "  ");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn timer_measures() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(t.secs() >= 0.02);
    }
}
