//! Synthetic corpus generation (DESIGN.md §2 substitution table).
//!
//! Reproduces the two datasets of §IV as SIMG corpora:
//!
//! * `imagenet_subset` — 16,384 files, median 112 KB (the paper's
//!   ImageNet subset for the micro-benchmark), 256x256x3 sources.
//! * `caltech101` — 9,144 files over 102 classes, median ~12 KB
//!   (the mini-app dataset), 96x96x3 sources.
//!
//! File sizes are drawn log-normally around the published median —
//! real-world image-size distributions are approximately log-normal —
//! and written *unpaced* (generation is test fixture setup, not a
//! measured workload).  A configurable fraction of corrupt files
//! exercises `ignore_errors` (§III-A uses it because "data
//! completeness is not guaranteed").

use anyhow::Result;

use super::format::{encode, Image};
use super::manifest::{Manifest, Sample};
use crate::storage::{SimPath, StorageSim};
use crate::util::Rng;

/// Parameters for corpus synthesis.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    /// Corpus name: files land under `<device>://<name>/NNNNN.simg`.
    pub name: String,
    pub num_files: usize,
    pub num_classes: u32,
    /// Source image edge (all files share one geometry bucket).
    pub src_size: u32,
    /// Median file size in bytes (log-normal target).
    pub median_bytes: u64,
    /// Sigma of the underlying normal (0 = all files identical size).
    pub sigma: f64,
    /// Fraction of deliberately corrupt files in [0, 1).
    pub corrupt_frac: f64,
    pub seed: u64,
}

impl CorpusSpec {
    /// §IV-A: ImageNet subset, 16,384 JPEGs, median 112 KB.
    pub fn imagenet_subset(num_files: usize) -> Self {
        CorpusSpec {
            name: "imagenet".into(),
            num_files,
            num_classes: 1000,
            src_size: 256,
            median_bytes: 112 * 1024,
            sigma: 0.35,
            corrupt_frac: 0.0,
            seed: 0xD1,
        }
    }

    /// §IV-A file-size profile with small (96px) pixel payloads: same
    /// on-disk distribution as [`imagenet_subset`] (median 112 KB via
    /// entropy padding) but ~4x cheaper decode+resize.  Used by the
    /// Fig. 4 bench on single-core hosts, where the paper's multi-core
    /// decode parallelism must be emulated by shrinking per-image CPU
    /// cost (EXPERIMENTS.md, Fig. 4 notes).
    pub fn imagenet_subset_96(num_files: usize) -> Self {
        CorpusSpec {
            name: "imagenet96".into(),
            num_files,
            num_classes: 1000,
            src_size: 96,
            median_bytes: 112 * 1024,
            sigma: 0.35,
            corrupt_frac: 0.0,
            seed: 0xD2,
        }
    }

    /// §IV-B: Caltech 101, 9,144 images, 102 classes, median ~12 KB.
    pub fn caltech101(num_files: usize) -> Self {
        CorpusSpec {
            name: "caltech101".into(),
            num_files,
            num_classes: 102,
            src_size: 96,
            median_bytes: 12 * 1024,
            sigma: 0.45,
            corrupt_frac: 0.0,
            seed: 0xCA,
        }
    }
}

/// Synthesize structured pixels for a class: a class-dependent gradient
/// field plus per-image noise.  Structured enough to DEFLATE like a
/// photo (≈2-4x), cheap enough to generate thousands of files.
fn synth_pixels(rng: &mut Rng, size: u32, label: u32) -> Vec<u8> {
    let s = size as usize;
    let mut pixels = vec![0u8; s * s * 3];
    let lf = label as f64;
    let (a, b, c) = (
        (lf * 0.37).sin() * 60.0,
        (lf * 0.61).cos() * 60.0,
        (lf * 0.13).sin() * 40.0,
    );
    let phase = rng.next_f64() * std::f64::consts::TAU;
    let noise_amp = 12.0;
    for y in 0..s {
        for x in 0..s {
            let base = 128.0
                + a * (x as f64 / s as f64 + phase).sin()
                + b * (y as f64 / s as f64 - phase).cos();
            let idx = (y * s + x) * 3;
            for ch in 0..3 {
                let n = (rng.next_f64() - 0.5) * noise_amp;
                let v = base + c * ch as f64 * 0.3 + n;
                pixels[idx + ch] = v.clamp(0.0, 255.0) as u8;
            }
        }
    }
    pixels
}

/// Generate a corpus onto `device`, returning its manifest.  Files are
/// written directly to backing storage (unpaced) — corpus creation is
/// fixture setup, not part of any measured experiment.
pub fn generate(
    sim: &StorageSim,
    device: &str,
    spec: &CorpusSpec,
) -> Result<Manifest> {
    let mut rng = Rng::new(spec.seed);
    let mut samples = Vec::with_capacity(spec.num_files);
    for i in 0..spec.num_files {
        let label = rng.next_below(spec.num_classes as u64) as u32;
        let rel = format!("{}/{:06}.simg", spec.name, i);
        let path = SimPath::new(device, rel);
        let target = if spec.sigma > 0.0 {
            Some(rng.next_lognormal(spec.median_bytes as f64, spec.sigma)
                as usize)
        } else {
            Some(spec.median_bytes as usize)
        };
        let bytes = if rng.next_f64() < spec.corrupt_frac {
            // Corrupt file: random garbage of plausible size.
            let mut junk = vec![0u8; target.unwrap().max(64)];
            rng.fill_bytes(&mut junk);
            junk
        } else {
            let img = Image {
                width: spec.src_size,
                height: spec.src_size,
                channels: 3,
                label,
                pixels: synth_pixels(&mut rng, spec.src_size, label),
            };
            encode(&img, target, rng.next_u64())?
        };
        // Unpaced write straight to backing storage.
        let abs = sim.backing_path(&path);
        if let Some(parent) = abs.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&abs, &bytes)?;
        samples.push(Sample { path, label });
    }
    let manifest = Manifest {
        samples,
        num_classes: spec.num_classes,
        src_size: spec.src_size,
    };
    // Persist the manifest next to the corpus (unpaced, fixture data).
    let mpath = sim.backing_path(&SimPath::new(
        device,
        format!("{}/manifest.txt", spec.name),
    ));
    std::fs::write(mpath, manifest.to_text())?;
    Ok(manifest)
}

/// Load a previously generated manifest from a device (unpaced).
pub fn load_manifest(sim: &StorageSim, device: &str, corpus: &str)
    -> Result<Manifest>
{
    let path = sim.backing_path(&SimPath::new(
        device,
        format!("{corpus}/manifest.txt"),
    ));
    Manifest::from_text(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::format::decode;
    use crate::storage::DeviceModel;

    fn sim(tag: &str) -> StorageSim {
        let dir = std::env::temp_dir()
            .join(format!("dlio-gen-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let m = DeviceModel {
            name: "ssd".into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 8,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
            lat_tables: None,
        };
        StorageSim::cold(dir, vec![m]).unwrap()
    }

    fn small_spec() -> CorpusSpec {
        CorpusSpec {
            name: "tiny".into(),
            num_files: 40,
            num_classes: 10,
            src_size: 32,
            median_bytes: 6 * 1024,
            sigma: 0.3,
            corrupt_frac: 0.0,
            seed: 1,
        }
    }

    #[test]
    fn generates_decodable_corpus_with_manifest() {
        let s = sim("basic");
        let m = generate(&s, "ssd", &small_spec()).unwrap();
        assert_eq!(m.len(), 40);
        // Every file decodes and matches its manifest label.
        for sample in &m.samples {
            let bytes = s.read(&sample.path).unwrap();
            let img = decode(&bytes).unwrap();
            assert_eq!(img.label, sample.label);
            assert_eq!(img.width, 32);
        }
    }

    #[test]
    fn manifest_roundtrips_from_disk() {
        let s = sim("manifest");
        let m = generate(&s, "ssd", &small_spec()).unwrap();
        let back = load_manifest(&s, "ssd", "tiny").unwrap();
        assert_eq!(back.samples, m.samples);
    }

    #[test]
    fn file_sizes_track_median() {
        let s = sim("sizes");
        let mut spec = small_spec();
        spec.num_files = 101;
        spec.median_bytes = 20 * 1024;
        let m = generate(&s, "ssd", &spec).unwrap();
        let mut sizes: Vec<u64> = m
            .samples
            .iter()
            .map(|x| s.file_size(&x.path).unwrap())
            .collect();
        sizes.sort();
        let med = sizes[sizes.len() / 2];
        let ratio = med as f64 / spec.median_bytes as f64;
        assert!((0.8..1.25).contains(&ratio), "median {med}");
    }

    #[test]
    fn deterministic_for_seed() {
        let s1 = sim("det1");
        let s2 = sim("det2");
        let m1 = generate(&s1, "ssd", &small_spec()).unwrap();
        let m2 = generate(&s2, "ssd", &small_spec()).unwrap();
        let labels1: Vec<_> = m1.samples.iter().map(|x| x.label).collect();
        let labels2: Vec<_> = m2.samples.iter().map(|x| x.label).collect();
        assert_eq!(labels1, labels2);
        let b1 = s1.read(&m1.samples[0].path).unwrap();
        let b2 = s2.read(&m2.samples[0].path).unwrap();
        assert_eq!(b1, b2);
    }

    #[test]
    fn corrupt_fraction_produces_undecodable_files() {
        let s = sim("corrupt");
        let mut spec = small_spec();
        spec.corrupt_frac = 0.5;
        spec.num_files = 60;
        let m = generate(&s, "ssd", &spec).unwrap();
        let bad = m
            .samples
            .iter()
            .filter(|x| decode(&s.read(&x.path).unwrap()).is_err())
            .count();
        assert!(bad > 10 && bad < 50, "bad={bad}");
    }

    #[test]
    fn labels_within_class_range() {
        let s = sim("labels");
        let m = generate(&s, "ssd", &small_spec()).unwrap();
        assert!(m.samples.iter().all(|x| x.label < 10));
    }
}
