//! Corpus manifest: the "list of file paths and their labels" that
//! forms the source dataset of the paper's input pipelines (Fig. 2).

use anyhow::{anyhow, Result};

use crate::storage::SimPath;

/// One training sample: file location + class label.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub path: SimPath,
    pub label: u32,
}

/// An ordered list of samples plus corpus geometry metadata.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub samples: Vec<Sample>,
    pub num_classes: u32,
    /// Source image edge length (all files in a corpus share one
    /// geometry bucket; see DESIGN.md §2).
    pub src_size: u32,
}

impl Manifest {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Serialize as text: header line then `path<TAB>label` rows.
    pub fn to_text(&self) -> String {
        let mut s = format!(
            "#dlio-manifest v1 classes={} src={}\n",
            self.num_classes, self.src_size
        );
        for sample in &self.samples {
            s.push_str(&format!("{}\t{}\n", sample.path, sample.label));
        }
        s
    }

    pub fn from_text(text: &str) -> Result<Manifest> {
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow!("empty manifest"))?;
        if !header.starts_with("#dlio-manifest v1") {
            return Err(anyhow!("bad manifest header: {header:?}"));
        }
        let field = |key: &str| -> Result<u32> {
            header
                .split_whitespace()
                .find_map(|tok| tok.strip_prefix(&format!("{key}=")))
                .ok_or_else(|| anyhow!("manifest header missing {key}"))?
                .parse()
                .map_err(|e| anyhow!("bad {key}: {e}"))
        };
        let num_classes = field("classes")?;
        let src_size = field("src")?;
        let mut samples = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let (path, label) = line
                .split_once('\t')
                .ok_or_else(|| anyhow!("line {}: missing tab", i + 2))?;
            samples.push(Sample {
                path: SimPath::parse(path)?,
                label: label.parse()
                    .map_err(|e| anyhow!("line {}: {e}", i + 2))?,
            });
        }
        Ok(Manifest { samples, num_classes, src_size })
    }

    /// Take the first `n` samples (bench-scale subsetting).
    pub fn truncated(&self, n: usize) -> Manifest {
        Manifest {
            samples: self.samples.iter().take(n).cloned().collect(),
            num_classes: self.num_classes,
            src_size: self.src_size,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest {
            samples: vec![
                Sample { path: SimPath::new("ssd", "img/0.simg"), label: 3 },
                Sample { path: SimPath::new("ssd", "img/1.simg"), label: 7 },
            ],
            num_classes: 102,
            src_size: 96,
        }
    }

    #[test]
    fn text_roundtrip() {
        let m = manifest();
        let back = Manifest::from_text(&m.to_text()).unwrap();
        assert_eq!(back.samples, m.samples);
        assert_eq!(back.num_classes, 102);
        assert_eq!(back.src_size, 96);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::from_text("nope\n").is_err());
        assert!(Manifest::from_text("").is_err());
    }

    #[test]
    fn rejects_bad_rows() {
        let text = "#dlio-manifest v1 classes=2 src=96\nno-tab-here\n";
        assert!(Manifest::from_text(text).is_err());
        let text = "#dlio-manifest v1 classes=2 src=96\nssd://x\tnotnum\n";
        assert!(Manifest::from_text(text).is_err());
    }

    #[test]
    fn truncated_takes_prefix() {
        let m = manifest().truncated(1);
        assert_eq!(m.len(), 1);
        assert_eq!(m.samples[0].label, 3);
    }
}
