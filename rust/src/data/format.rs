//! SIMG: the synthetic image container standing in for JPEG/PNG files.
//!
//! The paper's corpora are JPEG (micro-benchmark, ImageNet subset) and
//! PNG/JPEG (mini-app, Caltech 101).  We cannot ship those datasets, so
//! the generator synthesizes files whose *I/O-relevant properties*
//! match (§IV-A/B file-size distributions) and whose *decode cost* is
//! real CPU work (entropy decoding via the `flate2` codec — the
//! offline build vendors a delta+Huffman shim with the same surface —
//! the same family of work as JPEG's Huffman stage):
//!
//! ```text
//! offset  size  field
//! 0       4     magic "SIMG"
//! 4       2     version (=1)
//! 6       2     channels
//! 8       4     width
//! 12      4     height
//! 16      4     label (class id)
//! 20      4     payload length P
//! 24      P     DEFLATE-compressed raw pixels (h*w*c bytes, row-major)
//! 24+P    *     entropy pad (ignored by decode; sizes the file to the
//!               corpus distribution, like JPEG's size-vs-content noise)
//! ```

use anyhow::{anyhow, bail, Result};
use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;
use std::io::{Read, Write};

pub const MAGIC: &[u8; 4] = b"SIMG";
pub const VERSION: u16 = 1;
pub const HEADER_LEN: usize = 24;

/// A decoded image: raw u8 pixels plus geometry and label.
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub width: u32,
    pub height: u32,
    pub channels: u16,
    pub label: u32,
    /// Row-major `[h][w][c]` pixel bytes.
    pub pixels: Vec<u8>,
}

impl Image {
    pub fn pixel_len(&self) -> usize {
        self.width as usize * self.height as usize * self.channels as usize
    }
}

/// Encode an image to SIMG bytes, padding the file to `target_len`
/// when the encoded form is smaller (pad is pseudo-random and thus
/// incompressible, as JPEG entropy bytes are).
pub fn encode(img: &Image, target_len: Option<usize>, pad_seed: u64)
    -> Result<Vec<u8>>
{
    if img.pixels.len() != img.pixel_len() {
        bail!(
            "pixel buffer {} != {}x{}x{}",
            img.pixels.len(), img.height, img.width, img.channels
        );
    }
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(&img.pixels)?;
    let payload = enc.finish()?;

    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&img.channels.to_le_bytes());
    out.extend_from_slice(&img.width.to_le_bytes());
    out.extend_from_slice(&img.height.to_le_bytes());
    out.extend_from_slice(&img.label.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);

    if let Some(t) = target_len {
        if t > out.len() {
            let mut rng = crate::util::Rng::new(pad_seed);
            let mut pad = vec![0u8; t - out.len()];
            rng.fill_bytes(&mut pad);
            out.extend_from_slice(&pad);
        }
    }
    Ok(out)
}

/// Decode SIMG bytes back to an [`Image`] (the mini-app's
/// `tf.image.decode_png` stand-in).
pub fn decode(bytes: &[u8]) -> Result<Image> {
    if bytes.len() < HEADER_LEN {
        bail!("truncated SIMG: {} bytes", bytes.len());
    }
    if &bytes[0..4] != MAGIC {
        bail!("bad magic {:?}", &bytes[0..4]);
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != VERSION {
        bail!("unsupported SIMG version {version}");
    }
    let channels = u16::from_le_bytes([bytes[6], bytes[7]]);
    let width = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let height = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let label = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
    let payload_len =
        u32::from_le_bytes(bytes[20..24].try_into().unwrap()) as usize;
    if bytes.len() < HEADER_LEN + payload_len {
        bail!(
            "truncated payload: have {}, need {}",
            bytes.len() - HEADER_LEN, payload_len
        );
    }
    let n = width as usize * height as usize * channels as usize;
    if n == 0 || n > 512 * 1024 * 1024 {
        bail!("implausible geometry {width}x{height}x{channels}");
    }
    let mut pixels = Vec::with_capacity(n);
    let mut dec =
        DeflateDecoder::new(&bytes[HEADER_LEN..HEADER_LEN + payload_len]);
    dec.read_to_end(&mut pixels)
        .map_err(|e| anyhow!("deflate: {e}"))?;
    if pixels.len() != n {
        bail!("decoded {} pixels, expected {}", pixels.len(), n);
    }
    Ok(Image { width, height, channels, label, pixels })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_image(w: u32, h: u32, label: u32) -> Image {
        let mut pixels = Vec::with_capacity((w * h * 3) as usize);
        for y in 0..h {
            for x in 0..w {
                for c in 0..3u32 {
                    pixels.push(((x + y * 2 + c * 37 + label) % 256) as u8);
                }
            }
        }
        Image { width: w, height: h, channels: 3, label, pixels }
    }

    #[test]
    fn roundtrip() {
        let img = test_image(96, 96, 42);
        let bytes = encode(&img, None, 0).unwrap();
        let back = decode(&bytes).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn roundtrip_with_padding() {
        let img = test_image(32, 32, 1);
        let bytes = encode(&img, Some(50_000), 7).unwrap();
        assert_eq!(bytes.len(), 50_000);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn padding_not_applied_when_smaller_than_encoded() {
        let img = test_image(64, 64, 1);
        let bytes = encode(&img, Some(10), 7).unwrap();
        assert!(bytes.len() > 10);
        decode(&bytes).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let img = test_image(8, 8, 0);
        let mut bytes = encode(&img, None, 0).unwrap();
        bytes[0] = b'X';
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let img = test_image(8, 8, 0);
        let bytes = encode(&img, None, 0).unwrap();
        assert!(decode(&bytes[..HEADER_LEN + 3]).is_err());
        assert!(decode(&bytes[..10]).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let img = test_image(8, 8, 0);
        let mut bytes = encode(&img, None, 0).unwrap();
        bytes[4] = 9;
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn rejects_pixel_geometry_mismatch() {
        let mut img = test_image(8, 8, 0);
        img.pixels.pop();
        assert!(encode(&img, None, 0).is_err());
    }

    #[test]
    fn compressed_smaller_than_raw_for_structured_pixels() {
        let img = test_image(96, 96, 3);
        let bytes = encode(&img, None, 0).unwrap();
        assert!(bytes.len() < img.pixels.len());
    }
}
