//! Synthetic datasets: the SIMG container format, corpus generation
//! matching the paper's ImageNet-subset / Caltech-101 size
//! distributions, and the path+label manifests that seed the input
//! pipeline.

pub mod format;
pub mod generator;
pub mod manifest;

pub use format::{decode, encode, Image};
pub use generator::{generate, load_manifest, CorpusSpec};
pub use manifest::{Manifest, Sample};
