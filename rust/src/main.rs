//! `dlio` — the leader binary: CLI over the experiment coordinators.
//!
//! Subcommands mirror the paper's studies:
//!
//! ```text
//! dlio ior         [--size-mb 512] [--reps 6] [--time-scale 8]
//! dlio gen-corpus  [--corpus imagenet|caltech101] [--files N] [--device D]
//! dlio microbench  [--device D] [--threads N] [--batch 64]
//!                  [--iterations N] [--no-preprocess] [--readahead N]
//!                  [--shards N] [--engine-stats]
//! dlio train       [--device D] [--threads N] [--batch 64] [--prefetch 1]
//!                  [--iterations N] [--profile micro|mini]
//! dlio ckpt-study  [--target none|hdd|ssd|optane|bb:optane:hdd]
//!                  [--interval 5] [--iterations 20]
//! dlio trace       [--device D] [--prefetch 0|1] ... (dstat CSV to stdout)
//! ```
//!
//! Every run needs `make artifacts` first (or `DLIO_ARTIFACTS` pointing
//! at a built artifact dir).  `DLIO_TIME_SCALE` (default 8) uniformly
//! accelerates the simulated devices; ratios are scale-invariant.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use dlio::config::{
    default_time_scale, Args, CheckpointTarget, CkptStudyConfig,
    MicrobenchConfig, MiniAppConfig, Testbed,
};
use dlio::coordinator::{ensure_corpus, make_sim, microbench, miniapp};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;
use dlio::runtime::Runtime;
use dlio::storage::ior;
use dlio::trace::Dstat;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("dlio {cmd}: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "ior" => cmd_ior(args),
        "gen-corpus" => cmd_gen_corpus(args),
        "microbench" => cmd_microbench(args),
        "train" => cmd_train(args),
        "ckpt-study" => cmd_ckpt_study(args),
        "trace" => cmd_trace(args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand {other:?}; see `dlio help`")),
    }
}

const HELP: &str = "\
dlio — Characterizing Deep-Learning I/O Workloads (PDSW-DISCS'18) repro

  dlio ior         Table I   raw device bandwidth (IOR protocol)
  dlio gen-corpus             synthesize an SIMG corpus
  dlio microbench  Figs 4/5  tf.data ingestion bandwidth
  dlio train       Figs 6/7  AlexNet mini-app (prefetch study)
  dlio ckpt-study  Fig 9     checkpoint targets incl. burst buffer
  dlio trace       Figs 8/10 dstat-style I/O trace (CSV on stdout)

Common options: --time-scale F (default $DLIO_TIME_SCALE or 8),
--device hdd|ssd|optane|lustre, --threads N, --batch N.
Engine QoS: --fifo (single-queue baseline), --preempt-chunks N,
--engine-stats (per-device, per-class queue/latency table).
Artifacts: run `make artifacts` first or set DLIO_ARTIFACTS.
";

fn testbed(args: &Args) -> Result<Testbed> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let mut tb = Testbed::paper(ts);
    if let Some(dir) = args.get("workdir") {
        tb.workdir = dir.to_string();
    }
    tb.cache_bytes = args.get_usize("cache-mb", 0)? as u64 * 1_000_000;
    // Engine QoS: `--fifo` restores the single-queue baseline (for
    // A/B-ing the class scheduler), `--preempt-chunks N` tunes how
    // often streams yield to higher classes (0 = never).
    if args.has_flag("fifo") {
        tb.qos = dlio::storage::QosConfig::fifo();
    }
    if let Some(n) = args.get("preempt-chunks") {
        tb.qos.preempt_chunks =
            n.parse().map_err(|e| anyhow!("--preempt-chunks: {e}"))?;
    }
    Ok(tb)
}

/// Per-device, per-class engine stats table — the Fig. 4/8-style
/// queue-depth/latency surface, straight from the engine.
fn print_engine_stats(sim: &dlio::storage::StorageSim) {
    let mut t = Table::new(&[
        "Device", "class", "reqs", "err", "max qdepth",
        "mean queue ms", "p99 queue ms", "mean svc ms",
        "MB read", "MB written",
    ]);
    for s in sim.engine().stats() {
        if s.completed == 0 {
            continue;
        }
        for class in dlio::storage::IoClass::ALL {
            let c = s.class(class);
            if c.submitted == 0 {
                continue;
            }
            t.row(&[
                s.device.clone(),
                class.name().into(),
                c.completed.to_string(),
                c.errors.to_string(),
                c.max_queue_depth.to_string(),
                format!("{:.3}", c.mean_queue_secs() * 1e3),
                format!("{:.3}", c.p99_queue_secs() * 1e3),
                format!("{:.3}", c.mean_service_secs() * 1e3),
                format!("{:.1}", c.bytes_read as f64 / 1e6),
                format!("{:.1}", c.bytes_written as f64 / 1e6),
            ]);
        }
        t.row(&[
            s.device.clone(),
            "total".into(),
            s.completed.to_string(),
            s.errors.to_string(),
            s.max_queue_depth.to_string(),
            format!("{:.3}", s.mean_queue_secs() * 1e3),
            "-".into(),
            format!("{:.3}", s.mean_service_secs() * 1e3),
            format!("{:.1}", s.bytes_read as f64 / 1e6),
            format!("{:.1}", s.bytes_written as f64 / 1e6),
        ]);
    }
    print!("{}", t.render());
}

fn corpus_spec(args: &Args) -> Result<CorpusSpec> {
    let name = args.get_or("corpus", "caltech101");
    let mut spec = match name.as_str() {
        "imagenet" => CorpusSpec::imagenet_subset(
            args.get_usize("files", 2048)?),
        "caltech101" => CorpusSpec::caltech101(
            args.get_usize("files", 2048)?),
        other => return Err(anyhow!("unknown corpus {other:?}")),
    };
    spec.corrupt_frac = args.get_f64("corrupt-frac", 0.0)?;
    Ok(spec)
}

fn cmd_ior(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let cfg = ior::IorConfig {
        file_bytes: args.get_usize("size-mb", 512)? as u64 * 1_000_000,
        reps: args.get_usize("reps", 6)?.max(2),
    };
    println!("# IOR protocol: {} MB x {} reps (median, warm-up dropped)",
             cfg.file_bytes / 1_000_000, cfg.reps);
    println!("# time-scale {}x: reported bandwidths are scaled back to \
              modelled-device terms", tb.devices[0].time_scale);
    let ts = tb.devices[0].time_scale;
    let mut table = Table::new(&["Device", "Max Read MB/s", "Max Write MB/s"]);
    for row in ior::run_all(&sim, &cfg)? {
        table.row(&[
            row.device.clone(),
            format!("{:.2}", row.max_read_mbs / ts),
            format!("{:.2}", row.max_write_mbs / ts),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_gen_corpus(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let device = args.get_or("device", "ssd");
    let spec = corpus_spec(args)?;
    let t = dlio::metrics::Timer::start();
    let m = ensure_corpus(&sim, &device, &spec)?;
    println!(
        "corpus {} on {device}: {} files, {} classes, src {}px ({:.1}s)",
        spec.name, m.len(), m.num_classes, m.src_size, t.secs()
    );
    Ok(())
}

fn cmd_microbench(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let rt = Runtime::open_default()?;
    let device = args.get_or("device", "ssd");
    let mut spec = corpus_spec(args)?;
    if args.get("corpus").is_none() {
        spec = CorpusSpec::imagenet_subset(args.get_usize("files", 2048)?);
    }
    let manifest = ensure_corpus(&sim, &device, &spec)?;
    let cfg = MicrobenchConfig {
        device: device.clone(),
        threads: args.get_usize("threads", 4)?,
        batch: args.get_usize("batch", 64)?,
        iterations: args.get_usize("iterations", 16)?,
        preprocess: !args.has_flag("no-preprocess"),
        out_size: args.get_usize("out-size", 64)?,
        readahead: args.get_usize("readahead", 0)?,
        shards: args.get_usize("shards", 1)?,
    };
    let r = microbench::run(Arc::clone(&sim), &rt, &manifest, &cfg, 7)?;
    // Print the readahead actually in force (--shards alone implies
    // the default per-shard window), so logged configs match the run.
    println!(
        "device={device} threads={} preprocess={} readahead={} shards={} : \
         {:.1} images/s  {:.2} MB/s  ({} images in {:.2}s, {} dropped)",
        cfg.threads, cfg.preprocess, cfg.effective_readahead(), cfg.shards,
        r.images_per_sec(), r.mb_per_sec(), r.images, r.elapsed_secs,
        r.dropped
    );
    if args.has_flag("engine-stats") {
        print_engine_stats(&sim);
    }
    Ok(())
}

fn train_cfg(args: &Args) -> Result<MiniAppConfig> {
    Ok(MiniAppConfig {
        device: args.get_or("device", "ssd"),
        threads: args.get_usize("threads", 4)?,
        batch: args.get_usize("batch", 64)?,
        prefetch: args.get_usize("prefetch", 1)?,
        iterations: args.get_usize("iterations", 20)?,
        profile: args.get_or("profile", "micro"),
        seed: args.get_usize("seed", 42)? as u64,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let rt = Runtime::open_default()?;
    let cfg = train_cfg(args)?;
    let mut spec = corpus_spec(args)?;
    spec.num_files = spec
        .num_files
        .max(cfg.batch * cfg.iterations.min(1024));
    let manifest = ensure_corpus(&sim, &cfg.device, &spec)?;
    let r = miniapp::run(Arc::clone(&sim), &rt, &manifest, &cfg)?;
    println!(
        "device={} threads={} prefetch={} batch={} profile={}",
        cfg.device, cfg.threads, cfg.prefetch, cfg.batch, cfg.profile
    );
    println!(
        "steps={} images={} total={:.2}s ingest-wait={:.2}s \
         compute={:.2}s",
        r.steps, r.images, r.total_secs, r.ingest_wait_secs, r.compute_secs
    );
    if let (Some(first), Some(last)) = (r.losses.first(), r.losses.last()) {
        println!("loss: {first:.4} -> {last:.4}");
    }
    Ok(())
}

fn cmd_ckpt_study(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let rt = Runtime::open_default()?;
    let cfg = CkptStudyConfig {
        mini: train_cfg(args)?,
        target: CheckpointTarget::parse(&args.get_or("target", "hdd"))?,
        interval: args.get_usize("interval", 5)?,
        max_to_keep: args.get_usize("max-to-keep", 5)?,
    };
    let spec = corpus_spec(args)?;
    let manifest = ensure_corpus(&sim, &cfg.mini.device, &spec)?;
    let r = miniapp::run_with_checkpoints(Arc::clone(&sim), &rt,
                                          &manifest, &cfg)?;
    println!(
        "target={} interval={} : total={:.2}s ckpt-total={:.2}s \
         ({} checkpoints, median {:.2}s)",
        cfg.target.label(), cfg.interval, r.total_secs, r.ckpt_secs,
        r.ckpt_durations.len(),
        dlio::metrics::median(&mut r.ckpt_durations.clone()),
    );
    if args.has_flag("engine-stats") {
        // Checkpoint-vs-ingest interference, per class (§V): the
        // table the QoS scheduler's isolation claims are read from.
        print_engine_stats(&sim);
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let tracer = Arc::new(Dstat::new(args.get_f64("interval-secs", 1.0)?));
    let sim = make_sim(&tb, Some(tracer.clone()))?;
    let rt = Runtime::open_default()?;
    let cfg = train_cfg(args)?;
    let spec = corpus_spec(args)?;
    let manifest = ensure_corpus(&sim, &cfg.device, &spec)?;
    let target = CheckpointTarget::parse(&args.get_or("target", "none"))?;
    let study = CkptStudyConfig {
        mini: cfg,
        target,
        interval: args.get_usize("interval", 5)?,
        max_to_keep: 5,
    };
    let r = miniapp::run_with_checkpoints(Arc::clone(&sim), &rt,
                                          &manifest, &study)?;
    eprintln!("# run: {} steps in {:.2}s", r.steps, r.total_secs);
    print!("{}", tracer.to_csv());
    Ok(())
}
