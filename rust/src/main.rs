//! `dlio` — the leader binary: CLI over the experiment coordinators.
//!
//! Subcommands mirror the paper's studies:
//!
//! ```text
//! dlio ior         [--size-mb 512] [--reps 6] [--time-scale 8]
//! dlio gen-corpus  [--corpus imagenet|caltech101] [--files N] [--device D]
//! dlio microbench  [--device D|hier:P] [--policy noop|lru|freq|cost]
//!                  [--threads N] [--batch 64]
//!                  [--iterations N] [--no-preprocess] [--readahead N]
//!                  [--shards N] [--engine-stats]
//! dlio train       [--device D|hier:P] [--threads N] [--batch 64]
//!                  [--prefetch 1] [--iterations N] [--profile micro|mini]
//!                  [--compute xla|model] [--accel cpu|k80|p100|v100]
//!                  [--compute-profile alexnet|resnet50|micro] [--trace-out FILE]
//! dlio ckpt-study  [--target none|hdd|ssd|optane|bb:optane:hdd]
//!                  [--interval 5] [--iterations 20] [--device D|hier:P]
//!                  [--compute xla|model] [--trace-out FILE]
//! dlio overlap-sweep [--smoke] [--targets ssd,hdd,hier:P]
//!                  [--shards 1,4] [--prefetch 0,1,2,4]
//!                  [--format csv|json] [--clock wall|virtual]
//! dlio qos-sweep   [--smoke] [--modes fifo,static,adaptive]
//!                  [--intervals 0,2,8] [--shards 1,2,4] [--format csv|json]
//!                  [--clock wall|virtual]
//! dlio tier-sweep  [--smoke] [--hierarchies blackdog-bb,..]
//!                  [--policies noop,lru,freq,cost]
//!                  [--workloads hot,zipf,uniform,ckpt] [--theta F]
//!                  [--rw-ratio F] [--arrival-us F] [--ws-ratio F]
//!                  [--tier0-cap-kb N] [--format csv|json]
//!                  [--clock wall|virtual]
//! dlio fleet-sweep [--smoke] [--tenants 2,4] [--schemes equal,..]
//!                  [--scenarios uniform,noisy,churn,storm,restart]
//!                  [--format csv|json] [--clock wall|virtual]
//! dlio fault-sweep [--smoke] [--kinds none,slow,..] [--devices hdd,ssd]
//!                  [--workers N] [--reads N] [--format csv|json]
//!                  [--clock wall|virtual]
//! dlio trace       [--device D] [--prefetch 0|1] ... (dstat CSV to stdout)
//! dlio trace-record [microbench|miniapp] [--smoke] [--out FILE]
//! dlio trace-replay <file> [--profile P] [--qos fifo|static|adaptive]
//!                  [--sweep fifo,static,..] [--sweep hier/policy,..]
//!                  [--speed X] [--open-loop]
//!                  [--inject kind[:dev[:start[:dur]]]]
//!                  [--clock wall|virtual] [--json|--csv]
//! dlio trace-compact <file> [--epochs N] [--out FILE]
//! ```
//!
//! Every run needs `make artifacts` first (or `DLIO_ARTIFACTS` pointing
//! at a built artifact dir).  `DLIO_TIME_SCALE` (default 8) uniformly
//! accelerates the simulated devices; ratios are scale-invariant.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use dlio::config::{
    default_time_scale, default_workdir, Args, CheckpointTarget,
    CkptStudyConfig, MicrobenchConfig, MiniAppConfig, Testbed,
};
use dlio::compute::{StepRecord, StepSummary};
use dlio::coordinator::{
    build_hierarchy, build_hierarchy_with_policy, ensure_corpus,
    fault_sweep, fleet_sweep, make_sim,
    microbench, miniapp, overlap_sweep, qos_sweep, sim_train, tier_sweep,
    trace_record, StorageTarget,
};
use dlio::data::CorpusSpec;
use dlio::metrics::Table;
use dlio::runtime::Runtime;
use dlio::storage::ior;
use dlio::storage::{profiles, ClockSpec, IoClass, QosConfig, StorageSim};
use dlio::trace::{
    append_steps, replay, Dstat, ReplayConfig, ReplayMode, Trace,
    TraceManifest, TraceRecorder, TRACE_VERSION,
};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("dlio {cmd}: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "ior" => cmd_ior(args),
        "gen-corpus" => cmd_gen_corpus(args),
        "microbench" => cmd_microbench(args),
        "train" => cmd_train(args),
        "ckpt-study" => cmd_ckpt_study(args),
        "overlap-sweep" => cmd_overlap_sweep(args),
        "qos-sweep" => cmd_qos_sweep(args),
        "tier-sweep" => cmd_tier_sweep(args),
        "fleet-sweep" => cmd_fleet_sweep(args),
        "fault-sweep" => cmd_fault_sweep(args),
        "trace" => cmd_trace(args),
        "trace-record" => cmd_trace_record(args),
        "trace-replay" => cmd_trace_replay(args),
        "trace-compact" => cmd_trace_compact(args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand {other:?}; see `dlio help`")),
    }
}

/// `--clock wall|virtual`, falling back to the command's default.
fn clock_arg(args: &Args, default: ClockSpec) -> Result<ClockSpec> {
    match args.get("clock") {
        None => Ok(default),
        Some(s) => ClockSpec::parse(s),
    }
}

const HELP: &str = "\
dlio — Characterizing Deep-Learning I/O Workloads (PDSW-DISCS'18) repro

  dlio ior         Table I   raw device bandwidth (IOR protocol)
  dlio gen-corpus             synthesize an SIMG corpus
  dlio microbench  Figs 4/5  tf.data ingestion bandwidth
  dlio train       Figs 6/7  AlexNet mini-app (prefetch study)
                             (--compute model swaps the XLA step for
                              the calibrated accelerator model: no
                              artifacts, exact under --clock virtual;
                              [--accel cpu|k80|p100|v100]
                              [--compute-profile alexnet|resnet50|micro])
  dlio ckpt-study  Fig 9     checkpoint targets incl. burst buffer
                             (--device hier:<preset> routes ingest AND
                              Direct saves through the hierarchy;
                              --compute model as for train)
  dlio overlap-sweep         prefetcher-overlap matrix (storage target
                             x reader shards x prefetch depth) on the
                             modelled accelerator: per-cell step time
                             vs the analytic max(compute, input) anchor
                             plus stall/overlap fractions ([--smoke]
                             [--targets ssd,hdd,hier:P] [--shards 1,4]
                             [--prefetch 0,1,2,4] [--format csv|json])
  dlio qos-sweep   Figs 4/8  (mode x ckpt interval x shards) matrix ->
                             per-class queue/latency rows, CSV or JSON
  dlio tier-sweep  Figs 9/10 (hierarchy x policy x workload) matrix ->
                             per-tier hit/migration rows plus the
                             cost-model columns (migration_mb,
                             cost_accuracy, rejected_by_cost), CSV or
                             JSON ([--smoke] [--hierarchies A,B]
                             [--policies noop,lru,freq,cost]
                             [--workloads hot,zipf[:T],uniform,ckpt]
                             [--theta F] [--rw-ratio F] [--arrival-us
                              F] [--ws-ratio F])
  dlio fleet-sweep           N concurrent tenant jobs on one device:
                             (tenants x share scheme x scenario) matrix
                             -> per-tenant rows with Jain fairness over
                             ingest p99 and goodput ([--smoke]
                             [--tenants 2,4] [--schemes equal,weighted,
                              blind] [--scenarios uniform,noisy,churn,
                              storm,restart] [--format csv|json])
  dlio fault-sweep           degraded-mode study: one probe workload
                             per (fault kind x device) cell, reporting
                             errors/retries, time-to-recover and the
                             goodput-retained fraction vs the no-fault
                             baseline ([--smoke] [--kinds none,slow,
                              flaky,read-only,offline] [--devices
                              hdd,ssd] [--format csv|json])
  dlio trace       Figs 8/10 dstat-style I/O trace (CSV on stdout)
  dlio trace-record [microbench|miniapp]  record a request-level JSONL
                             trace ([--smoke] [--out FILE])
  dlio trace-replay <file>   re-run a trace against any profile/QoS
                             ([--profile P] [--qos fifo|static|adaptive]
                              [--sweep M1,M2,..] [--speed X] [--open-loop]
                              [--inject kind[:dev[:start[:dur]]]]
                              [--json|--csv]); --sweep H/P,.. pairs
                             (e.g. blackdog-tiered/cost) instead drive
                             the tier-sweep (hierarchy x policy) matrix
                             from the trace's tier-tagged reads
  dlio trace-compact <file>  fold repeated per-epoch event runs into a
                             representative trace ([--epochs N] [--out F])

Common options: --time-scale F (default $DLIO_TIME_SCALE or 8),
--device hdd|ssd|optane|lustre (microbench/train also accept
hier:<preset> to route through a storage hierarchy), --threads N,
--batch N.
Engine QoS: --fifo (single-queue baseline), --adaptive-qos MS|auto
(AIMD ingest-weight controller targeting MS modelled ms of ingest p99
wait; `auto` = per-profile targets), --ckpt-cap-mbs N / --drain-cap-mbs
N (hard token-bucket caps on the Checkpoint / Drain classes),
--preempt-chunks N, --engine-stats (per-device, per-class table).
Time source: --clock wall|virtual — virtual runs the engine in
discrete-event time (no real sleeps; sweeps finish orders of magnitude
faster with identical byte totals).  Default: virtual for qos-sweep /
tier-sweep / fleet-sweep / fault-sweep / trace-replay --sweep, wall
for plain trace-replay.
Fault injection: --inject kind[:device[:start[:duration]]] arms a
device fault on the replay (kinds: none, slow, flaky, read-only,
offline; window in modelled seconds, default immediate and permanent).
Tracing: --trace-out FILE (train / ckpt-study / both --compute modes)
records a schema-v4 JSONL trace: request-level events plus per-step
phase records (input wait / compute / checkpoint stall).
Artifacts: run `make artifacts` first or set DLIO_ARTIFACTS (not
needed by --compute model or overlap-sweep, which are artifact-free).
";

/// Engine QoS from CLI flags (shared by every subcommand that builds
/// an engine): `--fifo` restores the single-queue baseline (for
/// A/B-ing the class scheduler); `--adaptive-qos MS` turns on the
/// AIMD ingest-weight controller (target = MS modelled ms of ingest
/// p99 queue wait; overrides --fifo), `--adaptive-qos auto` uses the
/// per-profile targets in `storage::profiles`; `--ckpt-cap-mbs N` /
/// `--drain-cap-mbs N` hard-cap the Checkpoint / Drain classes at N
/// modelled MB/s; `--preempt-chunks N` tunes how often streams yield
/// to higher classes (0 = never).
fn qos_from_args(args: &Args) -> Result<QosConfig> {
    let mut qos = QosConfig::default();
    if args.has_flag("fifo") {
        qos = QosConfig::fifo();
    }
    if let Some(ms) = args.get("adaptive-qos") {
        qos = if ms == "auto" {
            profiles::adaptive_auto()
        } else {
            let ms: f64 =
                ms.parse().map_err(|e| anyhow!("--adaptive-qos: {e}"))?;
            if ms <= 0.0 {
                return Err(anyhow!(
                    "--adaptive-qos must be positive (ms) or `auto`"
                ));
            }
            QosConfig::adaptive(ms * 1e-3)
        };
    }
    let cap = |key: &str, class: IoClass, qos: QosConfig| -> Result<QosConfig> {
        match args.get(key) {
            None => Ok(qos),
            Some(mbs) => {
                let mbs: f64 =
                    mbs.parse().map_err(|e| anyhow!("--{key}: {e}"))?;
                if mbs <= 0.0 {
                    return Err(anyhow!("--{key} must be positive"));
                }
                Ok(qos.with_rate_cap(class, mbs * 1e6, 2 << 20))
            }
        }
    };
    qos = cap("ckpt-cap-mbs", IoClass::Checkpoint, qos)?;
    qos = cap("drain-cap-mbs", IoClass::Drain, qos)?;
    if let Some(n) = args.get("preempt-chunks") {
        qos.preempt_chunks =
            n.parse().map_err(|e| anyhow!("--preempt-chunks: {e}"))?;
    }
    Ok(qos)
}

fn testbed(args: &Args) -> Result<Testbed> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let mut tb = Testbed::paper(ts);
    if let Some(dir) = args.get("workdir") {
        tb.workdir = dir.to_string();
    }
    tb.cache_bytes = args.get_usize("cache-mb", 0)? as u64 * 1_000_000;
    tb.qos = qos_from_args(args)?;
    Ok(tb)
}

/// Per-device, per-class engine stats table — the Fig. 4/8-style
/// queue-depth/latency surface, straight from the engine.
fn print_engine_stats(sim: &dlio::storage::StorageSim) {
    let mut t = Table::new(&[
        "Device", "class", "reqs", "err", "retry", "max qdepth",
        "mean queue ms", "p99 queue ms", "mean svc ms",
        "MB read", "MB written",
    ]);
    // One snapshot: stats() clones per-class histograms (and the
    // adaptive trajectory) per device, so don't pay for it twice.
    let stats = sim.engine().stats();
    for s in &stats {
        if s.completed == 0 {
            continue;
        }
        for class in dlio::storage::IoClass::ALL {
            let c = s.class(class);
            if c.submitted == 0 {
                continue;
            }
            t.row(&[
                s.device.clone(),
                class.name().into(),
                c.completed.to_string(),
                c.errors.to_string(),
                c.retries.to_string(),
                c.max_queue_depth.to_string(),
                format!("{:.3}", c.mean_queue_secs() * 1e3),
                format!("{:.3}", c.p99_queue_secs() * 1e3),
                format!("{:.3}", c.mean_service_secs() * 1e3),
                format!("{:.1}", c.bytes_read as f64 / 1e6),
                format!("{:.1}", c.bytes_written as f64 / 1e6),
            ]);
        }
        t.row(&[
            s.device.clone(),
            "total".into(),
            s.completed.to_string(),
            s.errors.to_string(),
            s.retries.to_string(),
            s.max_queue_depth.to_string(),
            format!("{:.3}", s.mean_queue_secs() * 1e3),
            "-".into(),
            format!("{:.3}", s.mean_service_secs() * 1e3),
            format!("{:.1}", s.bytes_read as f64 / 1e6),
            format!("{:.1}", s.bytes_written as f64 / 1e6),
        ]);
        // Hierarchy runs: one row per tier the device served (tagged
        // via storage::with_tier) — the per-tier attribution surface.
        for tr in &s.tiers {
            t.row(&[
                s.device.clone(),
                format!("tier{}", tr.tier),
                tr.completed.to_string(),
                tr.errors.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("{:.1}", tr.bytes_read as f64 / 1e6),
                format!("{:.1}", tr.bytes_written as f64 / 1e6),
            ]);
        }
        // Fleet runs: one row per tenant x class (tagged via
        // storage::with_tenant), with the per-class queue-latency
        // histograms — the isolation attribution surface.  Untagged
        // (default-tenant) traffic stays off this ledger.
        for tn in &s.tenants {
            for class in dlio::storage::IoClass::ALL {
                let c = &tn.classes[class.index()];
                if c.completed == 0 {
                    continue;
                }
                t.row(&[
                    s.device.clone(),
                    format!("{}/{}", tn.tenant, class.name()),
                    c.completed.to_string(),
                    c.errors.to_string(),
                    c.retries.to_string(),
                    "-".into(),
                    format!("{:.3}", c.mean_queue_secs() * 1e3),
                    format!("{:.3}", c.p99_queue_secs() * 1e3),
                    format!("{:.3}", c.mean_service_secs() * 1e3),
                    format!("{:.1}", c.bytes_read as f64 / 1e6),
                    format!("{:.1}", c.bytes_written as f64 / 1e6),
                ]);
            }
        }
    }
    print!("{}", t.render());
    // The AIMD controller's story, when it ran: where the ingest
    // weight ended up and how many times it moved.
    for s in &stats {
        if !s.weight_trajectory.is_empty() {
            println!(
                "{}: adaptive ingest weight {} ({} changes)",
                s.device,
                s.ingest_weight,
                s.weight_trajectory.len()
            );
        }
    }
}

fn corpus_spec(args: &Args) -> Result<CorpusSpec> {
    let name = args.get_or("corpus", "caltech101");
    let mut spec = match name.as_str() {
        "imagenet" => CorpusSpec::imagenet_subset(
            args.get_usize("files", 2048)?),
        "caltech101" => CorpusSpec::caltech101(
            args.get_usize("files", 2048)?),
        other => return Err(anyhow!("unknown corpus {other:?}")),
    };
    spec.corrupt_frac = args.get_f64("corrupt-frac", 0.0)?;
    Ok(spec)
}

fn cmd_ior(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let cfg = ior::IorConfig {
        file_bytes: args.get_usize("size-mb", 512)? as u64 * 1_000_000,
        reps: args.get_usize("reps", 6)?.max(2),
    };
    println!("# IOR protocol: {} MB x {} reps (median, warm-up dropped)",
             cfg.file_bytes / 1_000_000, cfg.reps);
    println!("# time-scale {}x: reported bandwidths are scaled back to \
              modelled-device terms", tb.devices[0].time_scale);
    let ts = tb.devices[0].time_scale;
    let mut table = Table::new(&["Device", "Max Read MB/s", "Max Write MB/s"]);
    for row in ior::run_all(&sim, &cfg)? {
        table.row(&[
            row.device.clone(),
            format!("{:.2}", row.max_read_mbs / ts),
            format!("{:.2}", row.max_write_mbs / ts),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_gen_corpus(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let device = args.get_or("device", "ssd");
    let spec = corpus_spec(args)?;
    let t = dlio::metrics::Timer::start();
    let m = ensure_corpus(&sim, &device, &spec)?;
    println!(
        "corpus {} on {device}: {} files, {} classes, src {}px ({:.1}s)",
        spec.name, m.len(), m.num_classes, m.src_size, t.secs()
    );
    Ok(())
}

fn cmd_microbench(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let rt = Runtime::open_default()?;
    let raw = args.get_or("device", "ssd");
    // `hier:<preset>` routes the run through the storage hierarchy;
    // the corpus is homed on the preset's bottom device tier.
    let (hier, device) = match StorageTarget::parse(&raw) {
        StorageTarget::Flat(d) => (None, d),
        StorageTarget::Hier(preset) => {
            // `--policy cost` (etc.) makes the single-job run exercise
            // promotion/demotion; default stays noop.
            let (h, bottom) = build_hierarchy_with_policy(
                &sim,
                &preset,
                &args.get_or("policy", "noop"),
            )?;
            (Some(h), bottom)
        }
    };
    let mut spec = corpus_spec(args)?;
    if args.get("corpus").is_none() {
        spec = CorpusSpec::imagenet_subset(args.get_usize("files", 2048)?);
    }
    let manifest = ensure_corpus(&sim, &device, &spec)?;
    let cfg = MicrobenchConfig {
        device: device.clone(),
        threads: args.get_usize("threads", 4)?,
        batch: args.get_usize("batch", 64)?,
        iterations: args.get_usize("iterations", 16)?,
        preprocess: !args.has_flag("no-preprocess"),
        out_size: args.get_usize("out-size", 64)?,
        readahead: args.get_usize("readahead", 0)?,
        shards: args.get_usize("shards", 1)?,
    };
    // Hierarchy routing only exists on the engine-backed sharded
    // source, so it forces a readahead of at least 1.
    let readahead = match &hier {
        Some(_) => cfg.effective_readahead().max(1),
        None => cfg.effective_readahead(),
    };
    let r = match &hier {
        Some(h) => microbench::run_hier(
            Arc::clone(h), &rt, &manifest, &cfg, 7,
        )?,
        None => microbench::run(Arc::clone(&sim), &rt, &manifest, &cfg, 7)?,
    };
    // Print the readahead actually in force (--shards alone implies
    // the default per-shard window), so logged configs match the run.
    println!(
        "device={raw} threads={} preprocess={} readahead={} shards={} : \
         {:.1} images/s  {:.2} MB/s  ({} images in {:.2}s, {} dropped)",
        cfg.threads, cfg.preprocess, readahead, cfg.shards,
        r.images_per_sec(), r.mb_per_sec(), r.images, r.elapsed_secs,
        r.dropped
    );
    if args.has_flag("engine-stats") {
        print_engine_stats(&sim);
        if let Some(h) = &hier {
            let d = h.policy_decisions();
            println!(
                "policy={} promotions={} demotions={} \
                 rejected-by-cost={} predicted-migration-secs={:.4}",
                h.policy_name(),
                d.promotions,
                d.demotions,
                d.rejected_by_cost,
                h.predicted_migration_secs(),
            );
        }
    }
    Ok(())
}

fn train_cfg(args: &Args) -> Result<MiniAppConfig> {
    Ok(MiniAppConfig {
        device: args.get_or("device", "ssd"),
        threads: args.get_usize("threads", 4)?,
        batch: args.get_usize("batch", 64)?,
        prefetch: args.get_usize("prefetch", 1)?,
        iterations: args.get_usize("iterations", 20)?,
        profile: args.get_or("profile", "micro"),
        seed: args.get_usize("seed", 42)? as u64,
    })
}

/// `--compute xla|model`: the real PJRT step or the calibrated
/// accelerator model (DESIGN.md §16).  Anything else fails fast.
fn compute_mode(args: &Args) -> Result<&'static str> {
    match args.get_or("compute", "xla").as_str() {
        "xla" => Ok("xla"),
        "model" => Ok("model"),
        other => Err(anyhow!("unknown --compute {other:?} (xla|model)")),
    }
}

/// Shared CLI surface for the modelled (`--compute model`) runs:
/// artifact-free, virtual-clock by default.  `--threads` doubles as
/// the shard count so flat/model invocations stay flag-compatible.
fn sim_train_cfg(args: &Args) -> Result<sim_train::SimTrainConfig> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let workdir = args
        .get("workdir")
        .map(str::to_string)
        .unwrap_or_else(default_workdir);
    let mut cfg = sim_train::SimTrainConfig::standard(workdir, ts);
    cfg.device = args.get_or("device", &cfg.device);
    let threads = args.get_usize("threads", cfg.shards)?;
    cfg.shards = args.get_usize("shards", threads)?;
    cfg.window = args.get_usize("window", cfg.window)?;
    cfg.batch = args.get_usize("batch", cfg.batch)?;
    cfg.steps = args.get_usize("iterations", cfg.steps)?;
    cfg.prefetch = args.get_usize("prefetch", cfg.prefetch)?;
    cfg.file_bytes = args.get_usize("file-kb", cfg.file_bytes / 1024)? * 1024;
    cfg.profile = args.get_or("compute-profile", &cfg.profile);
    cfg.tier = args.get_or("accel", &cfg.tier);
    cfg.clock = clock_arg(args, cfg.clock)?;
    cfg.trace_out = args.get("trace-out").map(PathBuf::from);
    Ok(cfg)
}

/// The `--compute model` result line: the per-step phase breakdown
/// the overlap study reads (mean step vs stall/overlap fractions).
fn print_step_summary(s: &StepSummary) {
    println!(
        "steps={} images={} total={:.3}s mean-step={:.3}ms \
         stall-frac={:.3} overlap-frac={:.3} eff-io={:.3}ms/step \
         {:.1} images/s",
        s.steps,
        s.images,
        s.total_secs,
        s.mean_step_secs * 1e3,
        s.stall_frac,
        s.overlap_frac,
        s.effective_io_secs_per_step * 1e3,
        s.images_per_sec,
    );
}

/// `--trace-out FILE` on the artifact-backed paths: attach the
/// request-level recorder to `sim` (call AFTER corpus generation so
/// fixture writes stay out of the trace).
fn trace_recorder_for(
    args: &Args,
    sim: &Arc<StorageSim>,
    tb: &Testbed,
    workload: String,
) -> Result<Option<TraceRecorder>> {
    let Some(out) = args.get("trace-out") else {
        return Ok(None);
    };
    let manifest = TraceManifest {
        version: TRACE_VERSION,
        workload,
        qos_mode: tb.qos.mode_name().to_string(),
        qos: Some(tb.qos.clone()),
        time_scale: tb.devices[0].time_scale,
        devices: tb.devices.clone(),
    };
    let rec = TraceRecorder::create(Path::new(out), &manifest)?;
    sim.engine().set_observer(rec.observer());
    Ok(Some(rec))
}

/// Detach + flush the recorder and append the run's per-step records
/// (the schema-v4 trace tail).
fn finish_trace(
    sim: &Arc<StorageSim>,
    rec: Option<TraceRecorder>,
    steps: &[StepRecord],
) -> Result<()> {
    let Some(rec) = rec else {
        return Ok(());
    };
    sim.engine().clear_observer();
    let path = rec.path().clone();
    let events = rec.finish()?;
    let n = append_steps(path.clone(), steps)?;
    println!(
        "trace: {} request events + {} step records -> {}",
        events,
        n,
        path.display()
    );
    Ok(())
}

/// `dlio train --compute model`: the mini-app loop with the XLA step
/// replaced by the calibrated accelerator model — artifact-free and,
/// under the (default) virtual clock, exact and bit-deterministic.
fn cmd_train_model(args: &Args) -> Result<()> {
    let cfg = sim_train_cfg(args)?;
    let r = sim_train::run(&cfg)?;
    println!(
        "device={} (data on {}) shards={} window={} prefetch={} batch={} \
         compute-profile={} accel={} modelled-step={:.3}ms",
        cfg.device, r.data_device, cfg.shards, cfg.window, cfg.prefetch,
        cfg.batch, cfg.profile, cfg.tier, r.modelled_step_secs * 1e3,
    );
    print_step_summary(&r.summary);
    if let Some(events) = r.trace_events {
        let out = cfg.trace_out.as_ref().expect("events imply trace_out");
        println!(
            "trace: {} request events + {} step records -> {}",
            events,
            r.records.len(),
            out.display()
        );
    }
    if args.has_flag("engine-stats") {
        print_engine_stats(&r.sim);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    if compute_mode(args)? == "model" {
        return cmd_train_model(args);
    }
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let rt = Runtime::open_default()?;
    let cfg = train_cfg(args)?;
    // `hier:<preset>`: ingest routes through the storage hierarchy,
    // corpus homed on its bottom device tier.
    let (hier, device) = match StorageTarget::parse(&cfg.device) {
        StorageTarget::Flat(d) => (None, d),
        StorageTarget::Hier(preset) => {
            let (h, bottom) = build_hierarchy(&sim, &preset)?;
            (Some(h), bottom)
        }
    };
    let mut spec = corpus_spec(args)?;
    spec.num_files = spec
        .num_files
        .max(cfg.batch * cfg.iterations.min(1024));
    let manifest = ensure_corpus(&sim, &device, &spec)?;
    let rec = trace_recorder_for(
        args,
        &sim,
        &tb,
        format!(
            "train device={} threads={} prefetch={} batch={} profile={}",
            cfg.device, cfg.threads, cfg.prefetch, cfg.batch, cfg.profile
        ),
    )?;
    let r = match hier {
        Some(h) => miniapp::run_hier(h, &rt, &manifest, &cfg)?,
        None => miniapp::run(Arc::clone(&sim), &rt, &manifest, &cfg)?,
    };
    finish_trace(&sim, rec, &r.step_records)?;
    println!(
        "device={} threads={} prefetch={} batch={} profile={}",
        cfg.device, cfg.threads, cfg.prefetch, cfg.batch, cfg.profile
    );
    println!(
        "steps={} images={} total={:.2}s ingest-wait={:.2}s \
         compute={:.2}s",
        r.steps, r.images, r.total_secs, r.ingest_wait_secs, r.compute_secs
    );
    if let (Some(first), Some(last)) = (r.losses.first(), r.losses.last()) {
        println!("loss: {first:.4} -> {last:.4}");
    }
    Ok(())
}

/// `dlio ckpt-study --compute model`: the checkpoint-target study over
/// the modelled accelerator — synthetic state through the real
/// `Saver`/`BurstBuffer` machinery, no artifacts needed.
fn cmd_ckpt_study_model(args: &Args) -> Result<()> {
    let mut cfg = sim_train_cfg(args)?;
    cfg.ckpt = CheckpointTarget::parse(&args.get_or("target", "hdd"))?;
    cfg.ckpt_interval = args.get_usize("interval", 5)?;
    cfg.ckpt_params = args.get_usize("ckpt-params", cfg.ckpt_params)?;
    cfg.max_to_keep = args.get_usize("max-to-keep", cfg.max_to_keep)?;
    let r = sim_train::run(&cfg)?;
    let saves = r
        .records
        .iter()
        .filter(|rec| rec.ckpt_stall_secs > 0.0)
        .count();
    println!(
        "target={} interval={} : total={:.3}s ckpt-stall={:.3}s \
         ({} checkpoints)",
        cfg.ckpt.label(),
        cfg.ckpt_interval,
        r.summary.total_secs,
        r.summary.ckpt_stall_secs,
        saves,
    );
    print_step_summary(&r.summary);
    if args.has_flag("engine-stats") {
        print_engine_stats(&r.sim);
    }
    Ok(())
}

fn cmd_ckpt_study(args: &Args) -> Result<()> {
    if compute_mode(args)? == "model" {
        return cmd_ckpt_study_model(args);
    }
    let tb = testbed(args)?;
    let sim = make_sim(&tb, None)?;
    let rt = Runtime::open_default()?;
    let cfg = CkptStudyConfig {
        mini: train_cfg(args)?,
        target: CheckpointTarget::parse(&args.get_or("target", "hdd"))?,
        interval: args.get_usize("interval", 5)?,
        max_to_keep: args.get_usize("max-to-keep", 5)?,
    };
    // `--device hier:<preset>`: ingest reads AND Direct checkpoint
    // saves route through the hierarchy (PR-7 parity for this study).
    let (hier, device) = match StorageTarget::parse(&cfg.mini.device) {
        StorageTarget::Flat(d) => (None, d),
        StorageTarget::Hier(preset) => {
            let (h, bottom) = build_hierarchy(&sim, &preset)?;
            (Some(h), bottom)
        }
    };
    let spec = corpus_spec(args)?;
    let manifest = ensure_corpus(&sim, &device, &spec)?;
    let rec = trace_recorder_for(
        args,
        &sim,
        &tb,
        format!(
            "ckpt-study device={} target={} interval={}",
            cfg.mini.device,
            cfg.target.label(),
            cfg.interval
        ),
    )?;
    let r = match hier {
        Some(h) => miniapp::run_with_checkpoints_hier(
            Arc::clone(&sim), h, &rt, &manifest, &cfg,
        )?,
        None => miniapp::run_with_checkpoints(
            Arc::clone(&sim), &rt, &manifest, &cfg,
        )?,
    };
    finish_trace(&sim, rec, &r.step_records)?;
    println!(
        "target={} interval={} : total={:.2}s ckpt-total={:.2}s \
         ({} checkpoints, median {:.2}s)",
        cfg.target.label(), cfg.interval, r.total_secs, r.ckpt_secs,
        r.ckpt_durations.len(),
        dlio::metrics::median(&mut r.ckpt_durations.clone()),
    );
    if args.has_flag("engine-stats") {
        // Checkpoint-vs-ingest interference, per class (§V): the
        // table the QoS scheduler's isolation claims are read from.
        print_engine_stats(&sim);
    }
    Ok(())
}

/// `dlio overlap-sweep`: the (storage target × reader shards ×
/// prefetch depth) matrix over the modelled accelerator — one CSV/JSON
/// row per cell with the measured step time next to its analytic
/// anchors (DESIGN.md §16): `max(compute, input)` in the overlap
/// regime, `compute + input` in the synchronous column.
fn cmd_overlap_sweep(args: &Args) -> Result<()> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let workdir = args
        .get("workdir")
        .map(str::to_string)
        .unwrap_or_else(default_workdir);
    let mut cfg = if args.has_flag("smoke") {
        overlap_sweep::OverlapSweepConfig::smoke(workdir, ts)
    } else {
        overlap_sweep::OverlapSweepConfig::standard(workdir, ts)
    };
    if let Some(t) = args.get_list("targets") {
        cfg.targets = t;
    }
    cfg.shards = args.get_usize_list("shards", &cfg.shards)?;
    cfg.prefetch = args.get_usize_list("prefetch", &cfg.prefetch)?;
    cfg.window = args.get_usize("window", cfg.window)?;
    cfg.batch = args.get_usize("batch", cfg.batch)?;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.file_bytes = args.get_usize("file-kb", cfg.file_bytes / 1024)? * 1024;
    cfg.profile = args.get_or("compute-profile", &cfg.profile);
    cfg.tier = args.get_or("accel", &cfg.tier);
    cfg.clock = clock_arg(args, cfg.clock)?;
    // Validate the output format *before* running the matrix.
    let format = args.get_or("format", "csv");
    if format != "csv" && format != "json" {
        return Err(anyhow!("unknown --format {format:?} (csv|json)"));
    }
    let rows = overlap_sweep::run(&cfg)?;
    match format.as_str() {
        "csv" => print!("{}", overlap_sweep::to_csv(&rows)),
        "json" => println!("{}", overlap_sweep::to_json(&rows)),
        _ => unreachable!("validated above"),
    }
    Ok(())
}

/// `dlio qos-sweep`: run the (qos mode × checkpoint interval ×
/// shards) matrix over the microbench-style workload and emit one
/// CSV/JSON row of per-class queue-depth/latency numbers per cell —
/// the Fig. 4/8 curves, machine-readable (replaces the hand-run
/// recipe EXPERIMENTS.md used to carry).
fn cmd_qos_sweep(args: &Args) -> Result<()> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let workdir = args
        .get("workdir")
        .map(str::to_string)
        .unwrap_or_else(default_workdir);
    let mut cfg = if args.has_flag("smoke") {
        qos_sweep::QosSweepConfig::smoke(workdir, ts)
    } else {
        qos_sweep::QosSweepConfig::standard(workdir, ts)
    };
    if let Some(device) = args.get("device") {
        cfg.device = device.to_string();
    }
    if let Some(modes) = args.get_list("modes") {
        cfg.modes = modes;
    }
    cfg.intervals = args.get_usize_list("intervals", &cfg.intervals)?;
    cfg.shards = args.get_usize_list("shards", &cfg.shards)?;
    cfg.files = args.get_usize("files", cfg.files)?;
    cfg.file_bytes = args.get_usize("file-kb", cfg.file_bytes / 1024)? * 1024;
    cfg.batch = args.get_usize("batch", cfg.batch)?;
    cfg.window = args.get_usize("window", cfg.window)?;
    cfg.ckpt_writes = args.get_usize("ckpt-writes", cfg.ckpt_writes)?;
    cfg.ckpt_bytes =
        args.get_usize("ckpt-mb", (cfg.ckpt_bytes / 1_000_000) as usize)?
            as u64
            * 1_000_000;
    cfg.adaptive_target = args.get_f64(
        "adaptive-target-ms",
        cfg.adaptive_target * 1e3,
    )? * 1e-3;
    cfg.clock = clock_arg(args, cfg.clock)?;
    // Validate the output format *before* running the matrix: a typo
    // must fail instantly, not after minutes of sweep cells.
    let format = args.get_or("format", "csv");
    if format != "csv" && format != "json" {
        return Err(anyhow!("unknown --format {format:?} (csv|json)"));
    }
    let cells = qos_sweep::run(&cfg)?;
    match format.as_str() {
        "csv" => print!("{}", qos_sweep::to_csv(&cells)),
        "json" => println!("{}", qos_sweep::to_json(&cells)),
        _ => unreachable!("validated above"),
    }
    Ok(())
}

/// `dlio tier-sweep`: run the (hierarchy preset × placement policy ×
/// workload) matrix and emit one CSV/JSON row of per-tier
/// hit/migration numbers per cell — the storage-hierarchy placement
/// study (DESIGN.md §12), machine-readable.
fn cmd_tier_sweep(args: &Args) -> Result<()> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let workdir = args
        .get("workdir")
        .map(str::to_string)
        .unwrap_or_else(default_workdir);
    let mut cfg = if args.has_flag("smoke") {
        tier_sweep::TierSweepConfig::smoke(workdir, ts)
    } else {
        tier_sweep::TierSweepConfig::standard(workdir, ts)
    };
    if let Some(h) = args.get_list("hierarchies") {
        cfg.hierarchies = h;
    }
    if let Some(p) = args.get_list("policies") {
        cfg.policies = p;
    }
    if let Some(w) = args.get_list("workloads") {
        cfg.workloads = w;
    }
    cfg.files = args.get_usize("files", cfg.files)?;
    cfg.file_bytes = args.get_usize("file-kb", cfg.file_bytes / 1024)? * 1024;
    cfg.reads = args.get_usize("reads", cfg.reads)?;
    cfg.warmup_reads = args.get_usize("warmup-reads", cfg.warmup_reads)?;
    cfg.hot_files = args.get_usize("hot-files", cfg.hot_files)?;
    cfg.hot_frac = args.get_f64("hot-frac", cfg.hot_frac)?;
    if !(0.0..=1.0).contains(&cfg.hot_frac) {
        return Err(anyhow!("--hot-frac must be in [0, 1]"));
    }
    cfg.shards = args.get_usize("shards", cfg.shards)?;
    cfg.window = args.get_usize("window", cfg.window)?;
    cfg.tier0_cap =
        args.get_usize("tier0-cap-kb", (cfg.tier0_cap / 1024) as usize)?
            as u64
            * 1024;
    cfg.theta = args.get_f64("theta", cfg.theta)?;
    if !cfg.theta.is_finite() || cfg.theta < 0.0 {
        return Err(anyhow!("--theta must be a non-negative skew"));
    }
    cfg.rw_ratio = args.get_f64("rw-ratio", cfg.rw_ratio)?;
    if !(0.0..=1.0).contains(&cfg.rw_ratio) {
        return Err(anyhow!("--rw-ratio must be in [0, 1]"));
    }
    cfg.arrival_us = args.get_f64("arrival-us", cfg.arrival_us)?;
    if !cfg.arrival_us.is_finite() || cfg.arrival_us < 0.0 {
        return Err(anyhow!("--arrival-us must be non-negative"));
    }
    cfg.ws_ratio = args.get_f64("ws-ratio", cfg.ws_ratio)?;
    if !cfg.ws_ratio.is_finite() || cfg.ws_ratio < 0.0 {
        return Err(anyhow!("--ws-ratio must be non-negative"));
    }
    cfg.ckpt_saves = args.get_usize("ckpt-saves", cfg.ckpt_saves)?;
    cfg.clock = clock_arg(args, cfg.clock)?;
    // Validate the output format *before* running the matrix.
    let format = args.get_or("format", "csv");
    if format != "csv" && format != "json" {
        return Err(anyhow!("unknown --format {format:?} (csv|json)"));
    }
    let cells = tier_sweep::run(&cfg)?;
    match format.as_str() {
        "csv" => print!("{}", tier_sweep::to_csv(&cells)),
        "json" => println!("{}", tier_sweep::to_json(&cells)),
        _ => unreachable!("validated above"),
    }
    Ok(())
}

/// `dlio fleet-sweep`: N concurrent synthetic tenant jobs sharing one
/// engine under the virtual clock, across the (tenant count × share
/// scheme × scenario) matrix — one CSV/JSON row per tenant per cell,
/// with Jain's fairness index over per-tenant ingest p99 and goodput
/// (DESIGN.md §14).
fn cmd_fleet_sweep(args: &Args) -> Result<()> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let mut cfg = if args.has_flag("smoke") {
        fleet_sweep::FleetSweepConfig::smoke(ts)
    } else {
        fleet_sweep::FleetSweepConfig::standard(ts)
    };
    if let Some(device) = args.get("device") {
        cfg.device = device.to_string();
    }
    if let Some(s) = args.get_list("schemes") {
        cfg.schemes = s;
    }
    if let Some(s) = args.get_list("scenarios") {
        cfg.scenarios = s;
    }
    cfg.tenant_counts =
        args.get_usize_list("tenants", &cfg.tenant_counts)?;
    cfg.reads_per_job = args.get_usize("reads", cfg.reads_per_job)?;
    cfg.read_bytes =
        args.get_usize("read-kb", (cfg.read_bytes / 1024) as usize)? as u64
            * 1024;
    cfg.ckpt_every = args.get_usize("ckpt-every", cfg.ckpt_every)?;
    cfg.ckpt_writes = args.get_usize("ckpt-writes", cfg.ckpt_writes)?;
    cfg.ckpt_bytes =
        args.get_usize("ckpt-kb", (cfg.ckpt_bytes / 1024) as usize)? as u64
            * 1024;
    cfg.noisy_factor =
        args.get_usize("noisy-factor", cfg.noisy_factor)?;
    cfg.clock = clock_arg(args, cfg.clock)?;
    // Validate the output format *before* running the matrix.
    let format = args.get_or("format", "csv");
    if format != "csv" && format != "json" {
        return Err(anyhow!("unknown --format {format:?} (csv|json)"));
    }
    let rows = fleet_sweep::run(&cfg)?;
    match format.as_str() {
        "csv" => print!("{}", fleet_sweep::to_csv(&rows)),
        "json" => println!("{}", fleet_sweep::to_json(&rows)),
        _ => unreachable!("validated above"),
    }
    Ok(())
}

/// `dlio fault-sweep`: one closed-loop probe workload per (fault kind
/// × device profile) cell, with the fault window armed mid-run — one
/// CSV/JSON row per cell reporting errors/retries, time-to-recover
/// and the goodput-retained fraction against the cell's no-fault
/// baseline (DESIGN.md §15).
fn cmd_fault_sweep(args: &Args) -> Result<()> {
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let mut cfg = if args.has_flag("smoke") {
        fault_sweep::FaultSweepConfig::smoke(ts)
    } else {
        fault_sweep::FaultSweepConfig::standard(ts)
    };
    if let Some(d) = args.get_list("devices") {
        cfg.devices = d;
    }
    if let Some(k) = args.get_list("kinds") {
        cfg.kinds = k;
    }
    cfg.workers = args.get_usize("workers", cfg.workers)?;
    cfg.reads_per_worker = args.get_usize("reads", cfg.reads_per_worker)?;
    cfg.read_bytes =
        args.get_usize("read-kb", (cfg.read_bytes / 1024) as usize)? as u64
            * 1024;
    cfg.ckpt_every = args.get_usize("ckpt-every", cfg.ckpt_every)?;
    cfg.ckpt_bytes =
        args.get_usize("ckpt-kb", (cfg.ckpt_bytes / 1024) as usize)? as u64
            * 1024;
    cfg.fault_start_frac =
        args.get_f64("fault-start-frac", cfg.fault_start_frac)?;
    cfg.fault_len_frac = args.get_f64("fault-len-frac", cfg.fault_len_frac)?;
    cfg.clock = clock_arg(args, cfg.clock)?;
    // Validate the output format *before* running the matrix.
    let format = args.get_or("format", "csv");
    if format != "csv" && format != "json" {
        return Err(anyhow!("unknown --format {format:?} (csv|json)"));
    }
    let rows = fault_sweep::run(&cfg)?;
    match format.as_str() {
        "csv" => print!("{}", fault_sweep::to_csv(&rows)),
        "json" => println!("{}", fault_sweep::to_json(&rows)),
        _ => unreachable!("validated above"),
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let tb = testbed(args)?;
    // Validate here instead of letting Dstat::new's assert panic on a
    // non-positive interval (regression: `--interval-secs 0`).
    let tracer = Arc::new(
        Dstat::try_new(args.get_f64("interval-secs", 1.0)?)
            .map_err(|e| anyhow!("--interval-secs: {e}"))?,
    );
    let sim = make_sim(&tb, Some(tracer.clone()))?;
    let rt = Runtime::open_default()?;
    let cfg = train_cfg(args)?;
    let spec = corpus_spec(args)?;
    let manifest = ensure_corpus(&sim, &cfg.device, &spec)?;
    let target = CheckpointTarget::parse(&args.get_or("target", "none"))?;
    let study = CkptStudyConfig {
        mini: cfg,
        target,
        interval: args.get_usize("interval", 5)?,
        max_to_keep: 5,
    };
    let r = miniapp::run_with_checkpoints(Arc::clone(&sim), &rt,
                                          &manifest, &study)?;
    eprintln!("# run: {} steps in {:.2}s", r.steps, r.total_secs);
    print!("{}", tracer.to_csv());
    Ok(())
}

/// `dlio trace-record <microbench|miniapp>`: run the workload with the
/// request-level recorder attached and write a JSONL trace — the
/// reusable-workload half of the trace subsystem (DESIGN.md §11).
fn cmd_trace_record(args: &Args) -> Result<()> {
    let workload = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("microbench");
    let ts = args.get_f64("time-scale", default_time_scale())?;
    if ts <= 0.0 {
        return Err(anyhow!("--time-scale must be positive"));
    }
    let workdir = args
        .get("workdir")
        .map(str::to_string)
        .unwrap_or_else(default_workdir);
    let mut cfg = if args.has_flag("smoke") {
        trace_record::TraceRecordConfig::smoke(workdir.clone(), ts)
    } else {
        trace_record::TraceRecordConfig::standard(workdir.clone(), ts)
    };
    cfg.workload = workload.to_string();
    if let Some(device) = args.get("device") {
        cfg.device = device.to_string();
    }
    if let Some(drain) = args.get("drain-device") {
        cfg.drain_device = drain.to_string();
    }
    cfg.files = args.get_usize("files", cfg.files)?;
    cfg.file_bytes = args.get_usize("file-kb", cfg.file_bytes / 1024)? * 1024;
    cfg.shards = args.get_usize("shards", cfg.shards)?;
    cfg.window = args.get_usize("window", cfg.window)?;
    cfg.batch = args.get_usize("batch", cfg.batch)?;
    cfg.ckpt_interval = args.get_usize("interval", cfg.ckpt_interval)?;
    cfg.ckpt_writes = args.get_usize("ckpt-writes", cfg.ckpt_writes)?;
    cfg.ckpt_bytes =
        args.get_usize("ckpt-mb", (cfg.ckpt_bytes / 1_000_000) as usize)?
            as u64
            * 1_000_000;
    cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            Path::new(&workdir).join(format!("trace-{workload}.jsonl"))
        });
    let qos = qos_from_args(args)?;
    let r = trace_record::run(&cfg, qos, &out)?;
    println!(
        "trace-record {workload}: {} events -> {} ({} images, {} ckpt \
         bursts, {} drains, {:.2}s)",
        r.events,
        r.path.display(),
        r.images,
        r.ckpt_bursts,
        r.drains,
        r.elapsed_secs,
    );
    Ok(())
}

/// `dlio trace-replay <file>`: re-issue a recorded request stream
/// against any storage profile / QoS config and print the
/// record-vs-replay diff (table, `--json`, or `--csv`).
fn cmd_trace_replay(args: &Args) -> Result<()> {
    let file = args.positional.get(1).ok_or_else(|| {
        anyhow!("usage: dlio trace-replay <file> [--profile P] [--qos M] \
                 [--speed X] [--open-loop] [--inject PLAN] [--json|--csv]")
    })?;
    let trace = Trace::load(Path::new(file))?;
    let adaptive_target = args.get_f64("adaptive-target-ms", 5.0)? * 1e-3;
    let qos = match args.get("qos") {
        // Default: the manifest's recorded scheduler — the FULL config
        // (weights, caps, preemption, adaptive targets) when the
        // recorder captured it, so a plain replay rebuilds the
        // recorded setup exactly (like the device models).  Older
        // traces fall back to the mode label, unknown labels to
        // static.
        None => trace.manifest.qos.clone().unwrap_or_else(|| {
            QosConfig::parse_mode(&trace.manifest.qos_mode, adaptive_target)
                .unwrap_or_default()
        }),
        // `auto` keys per-device controller targets by device name;
        // under --profile substitution every traced device runs that
        // profile's model, so the target must follow the profile, not
        // the traced names.
        Some("auto") => match args.get("profile") {
            Some(p) => QosConfig::adaptive(
                profiles::adaptive_ingest_target(p).unwrap_or(5.0e-3),
            ),
            None => profiles::adaptive_auto(),
        },
        Some(mode) => QosConfig::parse_mode(mode, adaptive_target)?,
    };
    // `--speed X` implies open-loop (the recorded arrival schedule,
    // scaled); `--open-loop` alone replays the gaps at 1x.
    let speed = args.get_f64("speed", 1.0)?;
    let mode = if args.has_flag("open-loop") || args.get("speed").is_some() {
        ReplayMode::Open { speed }
    } else {
        ReplayMode::Closed
    };
    let time_scale = match args.get("time-scale") {
        None => None,
        Some(v) => {
            let ts: f64 = v.parse().map_err(|e| anyhow!("--time-scale: {e}"))?;
            if ts <= 0.0 {
                return Err(anyhow!("--time-scale must be positive"));
            }
            Some(ts)
        }
    };
    // Plain replays default to wall time (a live re-run you can watch
    // with `dlio trace`); `--sweep` matrices default to virtual —
    // every cell is pure simulation, so discrete-event time gives the
    // same rows orders of magnitude faster.
    let clock = clock_arg(
        args,
        if args.get_list("sweep").is_some() {
            ClockSpec::Virtual
        } else {
            ClockSpec::Wall
        },
    )?;
    let cfg = ReplayConfig {
        mode,
        qos,
        profile: args.get("profile").map(str::to_string),
        time_scale,
        clock,
        inject: args.get("inject").map(str::to_string),
    };
    // `--sweep m1,m2,..`: replay-driven what-if matrix — ONE recorded
    // trace across the qos-sweep scheduler modes, one diff row per
    // cell (ROADMAP follow-up).  `<hierarchy>/<policy>` tokens switch
    // the matrix axis from schedulers to placement: the recorded
    // (v2+) tier-tagged read stream re-runs through each hierarchy ×
    // policy pair, one tier-sweep row per cell.
    if let Some(modes) = args.get_list("sweep") {
        if modes.iter().any(|m| m.contains('/')) {
            let pairs = modes
                .iter()
                .map(|m| {
                    m.split_once('/')
                        .map(|(h, p)| (h.to_string(), p.to_string()))
                        .ok_or_else(|| {
                            anyhow!(
                                "--sweep mixes scheduler modes and \
                                 hierarchy/policy pairs ({m:?}); use \
                                 one kind of token per invocation"
                            )
                        })
                })
                .collect::<Result<Vec<_>>>()?;
            let ts = time_scale.unwrap_or(trace.manifest.time_scale);
            let workdir = args
                .get("workdir")
                .map(str::to_string)
                .unwrap_or_else(default_workdir);
            let mut tcfg =
                tier_sweep::TierSweepConfig::standard(workdir, ts);
            // Trace cells take their block sizes from the recording;
            // tier-0 capacity stays at the preset unless overridden.
            tcfg.tier0_cap =
                args.get_usize("tier0-cap-kb", 0)? as u64 * 1024;
            tcfg.clock = cfg.clock.clone();
            let cells =
                tier_sweep::run_trace_cells(&trace, &tcfg, &pairs)?;
            if args.has_flag("json") {
                println!("{}", tier_sweep::to_json(&cells));
            } else {
                print!("{}", tier_sweep::to_csv(&cells));
            }
            return Ok(());
        }
        let reports =
            dlio::trace::sweep(&trace, &cfg, &modes, adaptive_target)?;
        if args.has_flag("json") {
            println!(
                "{}",
                dlio::util::json::to_string(&dlio::trace::sweep_to_json(
                    &reports
                ))
            );
        } else {
            // The cell matrix is inherently tabular: CSV either way.
            print!("{}", dlio::trace::sweep_to_csv(&reports));
        }
        return Ok(());
    }
    let outcome = replay(&trace, &cfg)?;
    let report = dlio::trace::report(&trace, &cfg, &outcome);
    if args.has_flag("json") {
        println!("{}", dlio::util::json::to_string(&report.to_json()));
    } else if args.has_flag("csv") {
        print!("{}", report.to_csv());
    } else {
        print!("{}", report.to_table());
    }
    Ok(())
}

/// `dlio trace-compact <file>`: fold repeated per-epoch event runs
/// into a compact representative trace (with an event-count /
/// byte-total equivalence check), for cheap multi-epoch replays.
fn cmd_trace_compact(args: &Args) -> Result<()> {
    let file = args.positional.get(1).ok_or_else(|| {
        anyhow!("usage: dlio trace-compact <file> [--epochs N] [--out FILE]")
    })?;
    let trace = Trace::load(Path::new(file))?;
    let epochs = args
        .get("epochs")
        .map(|v| v.parse::<usize>())
        .transpose()
        .map_err(|e| anyhow!("--epochs: {e}"))?;
    let (compacted, rep) = dlio::trace::compact(&trace, epochs)?;
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{file}.compact")));
    dlio::trace::write_trace(&out, &compacted)?;
    println!(
        "trace-compact: {} epochs folded ({} -> {} events, {:.2} -> {:.2} \
         MB) -> {}",
        rep.epochs,
        rep.events_in,
        rep.events_out,
        rep.bytes_in as f64 / 1e6,
        rep.bytes_out as f64 / 1e6,
        out.display(),
    );
    if rep.epochs == 1 {
        eprintln!(
            "trace-compact: no repeated epoch structure found; output \
             equals input"
        );
    }
    Ok(())
}
