//! Pluggable placement policies for the N-tier
//! [`StorageHierarchy`](super::hierarchy::StorageHierarchy)
//! (DESIGN.md §12).
//!
//! A policy decides *where reads hit* (by proposing promotions after
//! each access), *where writes land* ([`place_write`]), and *what
//! migrates between tiers* — the hierarchy executes the decisions as
//! engine `Drain`-class copies and owns the mechanics (residency,
//! capacity pressure, LRU eviction order).  Modelled on the
//! placement-policy-vivarium split: the stack moves blocks, the policy
//! only ever returns migration messages.
//!
//! Three built-ins, selectable by name ([`by_name`]):
//!
//! * [`Noop`] — data stays where it lands; the baseline every
//!   placement study compares against.
//! * [`Lru`] — classic cache-on-read: every access from a slower tier
//!   promotes the file into the fastest *device* tier (RAM tiers
//!   fill read-through on their own), cold files fall out under the
//!   hierarchy's LRU capacity pressure.
//! * [`Frequency`] — hot-set promotion: a file is promoted only once
//!   it has been read `promote_after` times (with periodic decay), so
//!   one-shot scans cannot flush the hot set — the vivarium
//!   `FrequencyPolicy`, reduced to its threshold form.
//!
//! [`place_write`]: PlacementPolicy::place_write

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::device::{DeviceModel, Dir};

/// What a policy sees of one tier when deciding (a snapshot taken
/// under the hierarchy lock — cheap, there are only a handful of
/// tiers).
#[derive(Debug, Clone)]
pub struct TierView {
    pub name: String,
    /// Memory tier (hits are free; never a durable home).
    pub is_ram: bool,
    /// Byte capacity; 0 = unbounded.
    pub capacity: u64,
    /// Bytes currently resident.
    pub used: u64,
}

/// A policy's migration decision: copy `key` from tier `from` to tier
/// `to` (executed asynchronously as an engine `Drain`-class copy;
/// insertions into RAM tiers are free).  `evict_src` drops the source
/// copy once the destination copy has landed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Migration {
    pub key: String,
    pub from: usize,
    pub to: usize,
    pub evict_src: bool,
}

/// Index of the first (fastest) non-RAM tier — the default write
/// target: writes need a durable home, which a RAM tier can't be.
pub fn first_device_tier(tiers: &[TierView]) -> usize {
    tiers
        .iter()
        .position(|t| !t.is_ram)
        .expect("hierarchy has at least one device tier")
}

/// Per-policy decision counters ([`CostAware`] fills them; the
/// stateless built-ins report zeros).  Surfaced per tier-sweep cell
/// and under `--engine-stats`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PolicyDecisions {
    /// Blocks the policy chose to copy up into the fast tier.
    pub promotions: u64,
    /// Cold residents the policy pushed down to make room.
    pub demotions: u64,
    /// Candidate swaps declined because the modelled migration cost
    /// exceeded the projected gain.
    pub rejected_by_cost: u64,
}

/// Placement decisions over an ordered (fast → slow) tier list.
pub trait PlacementPolicy: Send {
    fn name(&self) -> &'static str;

    /// A read of `key` (`bytes` long) was served by tier `served`;
    /// return any promotions/demotions it should trigger.
    fn on_read(
        &mut self,
        key: &str,
        bytes: u64,
        served: usize,
        tiers: &[TierView],
    ) -> Vec<Migration>;

    /// A write of `key` landed on tier `tier`.
    fn on_write(
        &mut self,
        _key: &str,
        _bytes: u64,
        _tier: usize,
        _tiers: &[TierView],
    ) -> Vec<Migration> {
        Vec::new()
    }

    /// Tier a fresh write lands on (must be a non-RAM tier).
    fn place_write(
        &mut self,
        _key: &str,
        _bytes: u64,
        tiers: &[TierView],
    ) -> usize {
        first_device_tier(tiers)
    }

    /// `key` left `tier` (evicted, demoted, or deleted): drop any
    /// per-key bookkeeping so a re-ingested key starts cold.
    fn on_remove(&mut self, _key: &str, _tier: usize) {}

    /// Hand the policy the per-tier device models (`None` for RAM
    /// tiers), index-aligned with every later `tiers` slice.  The
    /// hierarchy calls this once at construction; cost-blind policies
    /// ignore it.
    fn calibrate(&mut self, _models: &[Option<DeviceModel>]) {}

    /// Decision counters accumulated so far (zeros for cost-blind
    /// policies).
    fn decisions(&self) -> PolicyDecisions {
        PolicyDecisions::default()
    }

    /// Modelled seconds of migration work this policy has committed to
    /// (read-from-source + write-to-dest of every accepted swap) —
    /// compared against the engine's measured `Drain` service time to
    /// score cost-model accuracy.  0 for cost-blind policies.
    fn predicted_migration_secs(&self) -> f64 {
        0.0
    }
}

/// Leave everything where it lands: no promotions, no demotions.
#[derive(Debug, Default)]
pub struct Noop;

impl PlacementPolicy for Noop {
    fn name(&self) -> &'static str {
        "noop"
    }

    fn on_read(
        &mut self,
        _key: &str,
        _bytes: u64,
        _served: usize,
        _tiers: &[TierView],
    ) -> Vec<Migration> {
        Vec::new()
    }
}

/// Cache-on-read: every access served below the fastest *device*
/// tier promotes the file into it (keeping the durable source copy);
/// recency-based eviction is the hierarchy's LRU pressure on that
/// tier's capacity.  RAM tiers above it fill read-through anyway, so
/// promotions target the first device tier — on a RAM-topped
/// hierarchy (`blackdog-tiered`) that is the bounded SSD cache, not
/// the page cache.
#[derive(Debug, Default)]
pub struct Lru;

impl PlacementPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_read(
        &mut self,
        key: &str,
        _bytes: u64,
        served: usize,
        tiers: &[TierView],
    ) -> Vec<Migration> {
        let to = first_device_tier(tiers);
        if served <= to {
            return Vec::new();
        }
        vec![Migration {
            key: key.to_string(),
            from: served,
            to,
            evict_src: false,
        }]
    }
}

/// Hot-set promotion: count reads per key and promote into the
/// fastest device tier (see [`Lru`] on why not a RAM tier) only past
/// `promote_after` accesses, halving every count each `decay_every`
/// reads so yesterday's hot set ages out.  One-shot scans never
/// cross the threshold, so they cannot flush the cache — the
/// property [`Lru`] lacks.
#[derive(Debug)]
pub struct Frequency {
    promote_after: u32,
    /// Reads between decay sweeps; 0 disables decay.
    decay_every: u64,
    counts: HashMap<String, u32>,
    reads: u64,
}

impl Frequency {
    pub fn new(promote_after: u32, decay_every: u64) -> Frequency {
        Frequency {
            promote_after: promote_after.max(1),
            decay_every,
            counts: HashMap::new(),
            reads: 0,
        }
    }

    /// Accesses recorded for `key` so far (tests / introspection).
    pub fn count(&self, key: &str) -> u32 {
        self.counts.get(key).copied().unwrap_or(0)
    }
}

impl Default for Frequency {
    /// Promote on the 3rd access, decay every 1024 reads — hot enough
    /// to catch a training loop's repeated samples, cold enough to
    /// ignore a single epoch-start scan.
    fn default() -> Frequency {
        Frequency::new(3, 1024)
    }
}

impl PlacementPolicy for Frequency {
    fn name(&self) -> &'static str {
        "freq"
    }

    fn on_read(
        &mut self,
        key: &str,
        _bytes: u64,
        served: usize,
        tiers: &[TierView],
    ) -> Vec<Migration> {
        self.reads += 1;
        if self.decay_every > 0 && self.reads % self.decay_every == 0 {
            for c in self.counts.values_mut() {
                *c /= 2;
            }
            self.counts.retain(|_, c| *c > 0);
        }
        let count = {
            let c = self.counts.entry(key.to_string()).or_insert(0);
            *c = c.saturating_add(1);
            *c
        };
        let to = first_device_tier(tiers);
        if served <= to || count < self.promote_after {
            return Vec::new();
        }
        vec![Migration {
            key: key.to_string(),
            from: served,
            to,
            evict_src: false,
        }]
    }

    fn on_remove(&mut self, key: &str, _tier: usize) {
        // Evicted from a tier: reset the count so the key must
        // re-earn promotion (otherwise every post-eviction read
        // immediately re-promotes and the cache thrashes).
        self.counts.remove(key);
    }
}

/// Cost-aware bidirectional placement — the vivarium swap criterion.
///
/// Where [`Frequency`] promotes on a fixed access count, `CostAware`
/// prices each candidate promotion against the calibrated device
/// models ([`PlacementPolicy::calibrate`]):
///
/// * **gain** — the access-frequency estimate (reads observed so far,
///   with the same periodic decay as `Frequency`) times the
///   per-access service-time delta between the serving tier and the
///   fast tier at this block's size (per-block-size latency tables
///   when the model carries them);
/// * **cost** — the modelled migration time: read the block from its
///   current tier plus write it into the fast tier, **plus**, when
///   the fast tier is full, the same for demoting its coldest
///   resident down a tier (bidirectional migration — the `freq`
///   policy can only promote, so under pressure it thrashs on LRU
///   evictions instead of choosing a victim).
///
/// The swap runs only when `gain > cost` *and* the candidate is
/// hotter than the victim it would displace; otherwise the attempt is
/// counted in [`PolicyDecisions::rejected_by_cost`].  Uncalibrated
/// (no models handed over — unit-test or bare construction), the
/// policy degrades to threshold promotion with capacity-aware
/// demotion and never rejects on cost.
#[derive(Debug)]
pub struct CostAware {
    /// Minimum observed reads before a block is priced at all (a
    /// 1-read frequency estimate is noise).
    consider_after: u32,
    /// Reads between decay sweeps; 0 disables decay.
    decay_every: u64,
    /// Per-tier device models, index-aligned with `TierView` slices;
    /// empty until [`PlacementPolicy::calibrate`].
    models: Vec<Option<DeviceModel>>,
    counts: HashMap<String, u32>,
    /// Blocks this policy believes are resident in the fast tier:
    /// key → (bytes, last-touch tick).  Kept in sync by `on_read` /
    /// `on_write` / `on_remove`; the hierarchy stays authoritative
    /// (a stale entry just proposes a migration that planning drops).
    resident: HashMap<String, (u64, u64)>,
    /// Fast-tier index the residency map refers to (set on first
    /// decision; hierarchies never reorder tiers).
    target: Option<usize>,
    tick: u64,
    reads: u64,
    dec: PolicyDecisions,
    predicted_secs: f64,
}

impl CostAware {
    pub fn new(consider_after: u32, decay_every: u64) -> CostAware {
        CostAware {
            consider_after: consider_after.max(1),
            decay_every,
            models: Vec::new(),
            counts: HashMap::new(),
            resident: HashMap::new(),
            target: None,
            tick: 0,
            reads: 0,
            dec: PolicyDecisions::default(),
            predicted_secs: 0.0,
        }
    }

    /// Accesses recorded for `key` so far (tests / introspection).
    pub fn count(&self, key: &str) -> u32 {
        self.counts.get(key).copied().unwrap_or(0)
    }

    /// Modelled single-request service time of `bytes` on tier `t`,
    /// `None` when no model was handed over for it.
    fn svc(&self, t: usize, dir: Dir, bytes: u64) -> Option<f64> {
        self.models
            .get(t)
            .and_then(|m| m.as_ref())
            .map(|m| m.service_time(dir, bytes, 1))
    }

    /// The coldest block the residency map knows in the fast tier.
    fn coldest_resident(&self) -> Option<(&str, u64, u64)> {
        self.resident
            .iter()
            .min_by_key(|(_, &(_, tick))| tick)
            .map(|(k, &(bytes, tick))| (k.as_str(), bytes, tick))
    }

    /// First non-RAM tier strictly below `target` — where demoted
    /// victims go (`served` is the caller's fallback when the view
    /// has no such tier, which cannot happen on a valid hierarchy).
    fn demote_tier(target: usize, served: usize, tiers: &[TierView]) -> usize {
        tiers
            .iter()
            .enumerate()
            .skip(target + 1)
            .find(|(_, t)| !t.is_ram)
            .map(|(i, _)| i)
            .unwrap_or(served)
    }
}

impl Default for CostAware {
    /// Price blocks from their 2nd access on, decay every 1024 reads
    /// (same aging cadence as [`Frequency`]).
    fn default() -> CostAware {
        CostAware::new(2, 1024)
    }
}

impl PlacementPolicy for CostAware {
    fn name(&self) -> &'static str {
        "cost"
    }

    fn calibrate(&mut self, models: &[Option<DeviceModel>]) {
        self.models = models.to_vec();
    }

    fn decisions(&self) -> PolicyDecisions {
        self.dec
    }

    fn predicted_migration_secs(&self) -> f64 {
        self.predicted_secs
    }

    fn on_read(
        &mut self,
        key: &str,
        bytes: u64,
        served: usize,
        tiers: &[TierView],
    ) -> Vec<Migration> {
        self.tick += 1;
        self.reads += 1;
        if self.decay_every > 0 && self.reads % self.decay_every == 0 {
            for c in self.counts.values_mut() {
                *c /= 2;
            }
            self.counts.retain(|_, c| *c > 0);
        }
        let count = {
            let c = self.counts.entry(key.to_string()).or_insert(0);
            *c = c.saturating_add(1);
            *c
        };
        let target = first_device_tier(tiers);
        self.target = Some(target);
        if served <= target {
            if served == target {
                // Fast-tier hit: refresh recency so the victim scan
                // sees true coldness.
                self.resident.insert(key.to_string(), (bytes, self.tick));
            }
            return Vec::new();
        }
        if count < self.consider_after {
            return Vec::new(); // not yet priceable, not a rejection
        }

        // --- price the swap ---
        let view = &tiers[target];
        let needs_room =
            view.capacity > 0 && view.used + bytes > view.capacity;
        let below = Self::demote_tier(target, served, tiers);
        let victim = if needs_room {
            match self.coldest_resident() {
                Some((k, vb, _)) => Some((k.to_string(), vb)),
                // Full but nothing known-resident (e.g. freshly
                // attached over a warm tier): nothing to swap out.
                None => return Vec::new(),
            }
        } else {
            None
        };
        // Candidate must be hotter than the block it displaces.
        if let Some((vk, _)) = &victim {
            if self.count(vk) >= count {
                self.dec.rejected_by_cost += 1;
                return Vec::new();
            }
        }
        let priced = (|| {
            let src_read = self.svc(served, Dir::Read, bytes)?;
            let dst_read = self.svc(target, Dir::Read, bytes)?;
            let dst_write = self.svc(target, Dir::Write, bytes)?;
            let delta = src_read - dst_read;
            let gain = count as f64 * delta;
            let mut cost = src_read + dst_write;
            if let Some((_, vb)) = &victim {
                cost += self.svc(target, Dir::Read, *vb)?
                    + self.svc(below, Dir::Write, *vb)?;
            }
            Some((gain, cost))
        })();
        match priced {
            Some((gain, cost)) if gain <= cost => {
                self.dec.rejected_by_cost += 1;
                return Vec::new();
            }
            Some((_, cost)) => self.predicted_secs += cost,
            // Uncalibrated: threshold promotion, no cost veto.
            None => {}
        }

        // --- commit: demote the victim (if any), promote the key ---
        let mut migs = Vec::new();
        if let Some((vk, _)) = victim {
            self.resident.remove(&vk);
            self.dec.demotions += 1;
            migs.push(Migration {
                key: vk,
                from: target,
                to: below,
                evict_src: true,
            });
        }
        self.dec.promotions += 1;
        self.resident.insert(key.to_string(), (bytes, self.tick));
        migs.push(Migration {
            key: key.to_string(),
            from: served,
            to: target,
            evict_src: false,
        });
        migs
    }

    fn on_write(
        &mut self,
        key: &str,
        bytes: u64,
        tier: usize,
        tiers: &[TierView],
    ) -> Vec<Migration> {
        self.tick += 1;
        if tier == first_device_tier(tiers) {
            self.resident.insert(key.to_string(), (bytes, self.tick));
        }
        Vec::new()
    }

    fn on_remove(&mut self, key: &str, tier: usize) {
        // Like `Frequency`: an evicted key re-earns its heat.
        self.counts.remove(key);
        if self.target == Some(tier) {
            self.resident.remove(key);
        }
    }
}

/// Valid policy names, in the order `by_name` accepts them (the list
/// unknown-name errors print).
pub const POLICY_NAMES: [&str; 4] = ["noop", "lru", "freq", "cost"];

/// Resolve a policy by name (default parameters); unknown names list
/// the valid set — the same contract as `profiles::by_name` errors.
pub fn by_name(name: &str) -> Result<Box<dyn PlacementPolicy>> {
    match name {
        "noop" => Ok(Box::new(Noop)),
        "lru" => Ok(Box::new(Lru)),
        "freq" | "frequency" => Ok(Box::<Frequency>::default()),
        "cost" | "cost-aware" => Ok(Box::<CostAware>::default()),
        other => Err(anyhow!(
            "unknown placement policy {other:?} (valid: {})",
            POLICY_NAMES.join(", ")
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiers() -> Vec<TierView> {
        vec![
            TierView {
                name: "optane".into(),
                is_ram: false,
                capacity: 1 << 20,
                used: 0,
            },
            TierView {
                name: "hdd".into(),
                is_ram: false,
                capacity: 0,
                used: 0,
            },
        ]
    }

    #[test]
    fn noop_never_migrates() {
        let mut p = Noop;
        for i in 0..10 {
            assert!(p.on_read(&format!("k{i}"), 100, 1, &tiers()).is_empty());
        }
        assert_eq!(p.place_write("k", 100, &tiers()), 0);
    }

    #[test]
    fn lru_promotes_every_slow_read_but_not_tier0_hits() {
        let mut p = Lru;
        let m = p.on_read("k", 100, 1, &tiers());
        assert_eq!(
            m,
            vec![Migration {
                key: "k".into(),
                from: 1,
                to: 0,
                evict_src: false
            }]
        );
        assert!(p.on_read("k", 100, 0, &tiers()).is_empty());
    }

    #[test]
    fn frequency_promotes_exactly_at_threshold() {
        let mut p = Frequency::new(3, 0);
        assert!(p.on_read("hot", 100, 1, &tiers()).is_empty(), "1st read");
        assert!(p.on_read("hot", 100, 1, &tiers()).is_empty(), "2nd read");
        let m = p.on_read("hot", 100, 1, &tiers());
        assert_eq!(m.len(), 1, "3rd read crosses the threshold");
        assert_eq!(m[0].to, 0);
        // Cold keys interleaved never cross.
        for i in 0..10 {
            assert!(p.on_read(&format!("cold{i}"), 100, 1, &tiers()).is_empty());
        }
        // Already-fast keys count but don't re-migrate from tier 0.
        assert!(p.on_read("hot", 100, 0, &tiers()).is_empty());
    }

    #[test]
    fn frequency_decay_halves_counts() {
        // decay_every = 4: after 4 reads every count halves, so a key
        // warmed to 2 drops back to 1 and needs 2 more reads.
        let mut p = Frequency::new(3, 4);
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty()); // count 1
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty()); // count 2
        assert!(p.on_read("x", 1, 1, &tiers()).is_empty());
        assert!(p.on_read("y", 1, 1, &tiers()).is_empty()); // decay: k -> 1
        assert_eq!(p.count("k"), 1);
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty()); // count 2
        assert_eq!(p.on_read("k", 1, 1, &tiers()).len(), 1); // count 3
    }

    #[test]
    fn frequency_eviction_resets_the_count() {
        let mut p = Frequency::new(2, 0);
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty());
        assert_eq!(p.on_read("k", 1, 1, &tiers()).len(), 1);
        p.on_remove("k", 0);
        assert!(
            p.on_read("k", 1, 1, &tiers()).is_empty(),
            "evicted key must re-earn promotion"
        );
    }

    fn cost_models(fast_write_lat: f64) -> Vec<Option<DeviceModel>> {
        let mk = |name: &str, read_lat: f64, write_lat: f64, bw: f64| {
            DeviceModel {
                name: name.into(),
                read_bw: bw,
                write_bw: bw,
                read_lat,
                write_lat,
                channels: 4,
                elevator: vec![(1, 1.0)],
                time_scale: 1.0,
                lat_tables: None,
            }
        };
        vec![
            Some(mk("fast", 0.1e-3, fast_write_lat, 1e9)),
            Some(mk("slow", 10.0e-3, 10.0e-3, 100e6)),
        ]
    }

    #[test]
    fn cost_aware_promotes_once_gain_clears_migration_cost() {
        // gain(count=2) = 2 x ~10.8 ms beats cost ~11.2 ms, so the
        // 2nd slow read promotes; the 1st (count=1) is below
        // consider_after and is not a rejection.
        let mut p = CostAware::new(2, 0);
        p.calibrate(&cost_models(0.1e-3));
        assert!(p.on_read("hot", 100_000, 1, &tiers()).is_empty());
        let m = p.on_read("hot", 100_000, 1, &tiers());
        assert_eq!(
            m,
            vec![Migration {
                key: "hot".into(),
                from: 1,
                to: 0,
                evict_src: false
            }]
        );
        let d = p.decisions();
        assert_eq!((d.promotions, d.demotions, d.rejected_by_cost), (1, 0, 0));
        assert!(p.predicted_migration_secs() > 0.0);
    }

    #[test]
    fn cost_aware_rejects_swap_when_migration_cost_exceeds_gain() {
        // A 10-second write into the fast tier prices every
        // early-count promotion out of the market.
        let mut p = CostAware::new(2, 0);
        p.calibrate(&cost_models(10.0));
        assert!(p.on_read("hot", 100_000, 1, &tiers()).is_empty());
        for _ in 0..5 {
            assert!(p.on_read("hot", 100_000, 1, &tiers()).is_empty());
        }
        let d = p.decisions();
        assert_eq!(d.promotions, 0);
        assert_eq!(d.rejected_by_cost, 5);
        assert_eq!(p.predicted_migration_secs(), 0.0);
    }

    #[test]
    fn cost_aware_demotes_the_coldest_resident_when_tier0_exactly_full() {
        // consider_after = 3: a count-3 gain (~32 ms) clears the full
        // swap cost (promotion ~11 ms + victim demotion ~11 ms).
        let mut p = CostAware::new(3, 0);
        p.calibrate(&cost_models(0.1e-3));
        // Two residents land in the fast tier; "cold" is touched
        // before "warm", so it is the colder one.
        let mut t = tiers();
        p.on_write("cold", 100_000, 0, &t);
        p.on_write("warm", 100_000, 0, &t);
        // Fast tier is now exactly full.
        t[0].capacity = 200_000;
        t[0].used = 200_000;
        assert!(p.on_read("hot", 100_000, 1, &t).is_empty());
        assert!(p.on_read("hot", 100_000, 1, &t).is_empty());
        let m = p.on_read("hot", 100_000, 1, &t);
        assert_eq!(
            m,
            vec![
                Migration {
                    key: "cold".into(),
                    from: 0,
                    to: 1,
                    evict_src: true
                },
                Migration {
                    key: "hot".into(),
                    from: 1,
                    to: 0,
                    evict_src: false
                },
            ],
            "bidirectional swap: demote the coldest, promote the hot"
        );
        let d = p.decisions();
        assert_eq!((d.promotions, d.demotions), (1, 1));
    }

    #[test]
    fn cost_aware_keeps_a_hotter_victim_over_a_colder_candidate() {
        let mut p = CostAware::new(2, 0);
        p.calibrate(&cost_models(0.1e-3));
        let mut t = tiers();
        // "vip" is read at the fast tier many times: count 5.
        for _ in 0..5 {
            p.on_read("vip", 100_000, 0, &t);
        }
        t[0].capacity = 100_000;
        t[0].used = 100_000;
        // "lukewarm" reaches count 2 < 5: displacing vip would cool
        // the tier, so the swap is refused.
        assert!(p.on_read("lukewarm", 100_000, 1, &t).is_empty());
        assert!(p.on_read("lukewarm", 100_000, 1, &t).is_empty());
        assert_eq!(p.decisions().promotions, 0);
        assert!(p.decisions().rejected_by_cost >= 1);
    }

    #[test]
    fn cost_aware_uncalibrated_falls_back_to_threshold_promotion() {
        // No models handed over: no pricing possible, so behave like
        // threshold promotion (never a cost rejection).
        let mut p = CostAware::new(2, 0);
        assert!(p.on_read("k", 100, 1, &tiers()).is_empty());
        assert_eq!(p.on_read("k", 100, 1, &tiers()).len(), 1);
        assert_eq!(p.decisions().rejected_by_cost, 0);
    }

    #[test]
    fn cost_aware_eviction_resets_count_and_residency() {
        let mut p = CostAware::new(2, 0);
        p.calibrate(&cost_models(0.1e-3));
        assert!(p.on_read("k", 100_000, 1, &tiers()).is_empty());
        assert_eq!(p.on_read("k", 100_000, 1, &tiers()).len(), 1);
        p.on_remove("k", 0);
        assert_eq!(p.count("k"), 0);
        assert!(
            p.on_read("k", 100_000, 1, &tiers()).is_empty(),
            "evicted key re-earns its heat"
        );
    }

    #[test]
    fn by_name_resolves_and_rejects_with_the_valid_list() {
        for n in POLICY_NAMES {
            assert_eq!(by_name(n).unwrap().name(), n);
        }
        let err = by_name("banana").unwrap_err().to_string();
        assert!(err.contains("noop") && err.contains("freq"), "{err}");
    }

    #[test]
    fn promotions_target_the_first_device_tier_not_ram() {
        // [ram, device, device]: promotions land in the device cache
        // (index 1) — the RAM tier fills read-through on its own, so
        // targeting it would make the policy axis a no-op on
        // RAM-topped hierarchies.
        let mut t = tiers();
        t.insert(
            0,
            TierView {
                name: "ram".into(),
                is_ram: true,
                capacity: 1 << 20,
                used: 0,
            },
        );
        let mut lru = Lru;
        assert_eq!(
            lru.on_read("k", 100, 2, &t),
            vec![Migration {
                key: "k".into(),
                from: 2,
                to: 1,
                evict_src: false
            }]
        );
        assert!(
            lru.on_read("k", 100, 1, &t).is_empty(),
            "already in the device cache"
        );
        let mut f = Frequency::new(1, 0);
        assert_eq!(f.on_read("k", 100, 2, &t)[0].to, 1);
    }

    #[test]
    fn first_device_tier_skips_ram() {
        let mut t = tiers();
        t.insert(
            0,
            TierView {
                name: "ram".into(),
                is_ram: true,
                capacity: 1 << 20,
                used: 0,
            },
        );
        assert_eq!(first_device_tier(&t), 1);
        let mut p = Noop;
        assert_eq!(p.place_write("k", 1, &t), 1);
    }
}
