//! Pluggable placement policies for the N-tier
//! [`StorageHierarchy`](super::hierarchy::StorageHierarchy)
//! (DESIGN.md §12).
//!
//! A policy decides *where reads hit* (by proposing promotions after
//! each access), *where writes land* ([`place_write`]), and *what
//! migrates between tiers* — the hierarchy executes the decisions as
//! engine `Drain`-class copies and owns the mechanics (residency,
//! capacity pressure, LRU eviction order).  Modelled on the
//! placement-policy-vivarium split: the stack moves blocks, the policy
//! only ever returns migration messages.
//!
//! Three built-ins, selectable by name ([`by_name`]):
//!
//! * [`Noop`] — data stays where it lands; the baseline every
//!   placement study compares against.
//! * [`Lru`] — classic cache-on-read: every access from a slower tier
//!   promotes the file into the fastest *device* tier (RAM tiers
//!   fill read-through on their own), cold files fall out under the
//!   hierarchy's LRU capacity pressure.
//! * [`Frequency`] — hot-set promotion: a file is promoted only once
//!   it has been read `promote_after` times (with periodic decay), so
//!   one-shot scans cannot flush the hot set — the vivarium
//!   `FrequencyPolicy`, reduced to its threshold form.
//!
//! [`place_write`]: PlacementPolicy::place_write

use std::collections::HashMap;

use anyhow::{anyhow, Result};

/// What a policy sees of one tier when deciding (a snapshot taken
/// under the hierarchy lock — cheap, there are only a handful of
/// tiers).
#[derive(Debug, Clone)]
pub struct TierView {
    pub name: String,
    /// Memory tier (hits are free; never a durable home).
    pub is_ram: bool,
    /// Byte capacity; 0 = unbounded.
    pub capacity: u64,
    /// Bytes currently resident.
    pub used: u64,
}

/// A policy's migration decision: copy `key` from tier `from` to tier
/// `to` (executed asynchronously as an engine `Drain`-class copy;
/// insertions into RAM tiers are free).  `evict_src` drops the source
/// copy once the destination copy has landed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Migration {
    pub key: String,
    pub from: usize,
    pub to: usize,
    pub evict_src: bool,
}

/// Index of the first (fastest) non-RAM tier — the default write
/// target: writes need a durable home, which a RAM tier can't be.
pub fn first_device_tier(tiers: &[TierView]) -> usize {
    tiers
        .iter()
        .position(|t| !t.is_ram)
        .expect("hierarchy has at least one device tier")
}

/// Placement decisions over an ordered (fast → slow) tier list.
pub trait PlacementPolicy: Send {
    fn name(&self) -> &'static str;

    /// A read of `key` (`bytes` long) was served by tier `served`;
    /// return any promotions/demotions it should trigger.
    fn on_read(
        &mut self,
        key: &str,
        bytes: u64,
        served: usize,
        tiers: &[TierView],
    ) -> Vec<Migration>;

    /// A write of `key` landed on tier `tier`.
    fn on_write(
        &mut self,
        _key: &str,
        _bytes: u64,
        _tier: usize,
        _tiers: &[TierView],
    ) -> Vec<Migration> {
        Vec::new()
    }

    /// Tier a fresh write lands on (must be a non-RAM tier).
    fn place_write(
        &mut self,
        _key: &str,
        _bytes: u64,
        tiers: &[TierView],
    ) -> usize {
        first_device_tier(tiers)
    }

    /// `key` left `tier` (evicted, demoted, or deleted): drop any
    /// per-key bookkeeping so a re-ingested key starts cold.
    fn on_remove(&mut self, _key: &str, _tier: usize) {}
}

/// Leave everything where it lands: no promotions, no demotions.
#[derive(Debug, Default)]
pub struct Noop;

impl PlacementPolicy for Noop {
    fn name(&self) -> &'static str {
        "noop"
    }

    fn on_read(
        &mut self,
        _key: &str,
        _bytes: u64,
        _served: usize,
        _tiers: &[TierView],
    ) -> Vec<Migration> {
        Vec::new()
    }
}

/// Cache-on-read: every access served below the fastest *device*
/// tier promotes the file into it (keeping the durable source copy);
/// recency-based eviction is the hierarchy's LRU pressure on that
/// tier's capacity.  RAM tiers above it fill read-through anyway, so
/// promotions target the first device tier — on a RAM-topped
/// hierarchy (`blackdog-tiered`) that is the bounded SSD cache, not
/// the page cache.
#[derive(Debug, Default)]
pub struct Lru;

impl PlacementPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_read(
        &mut self,
        key: &str,
        _bytes: u64,
        served: usize,
        tiers: &[TierView],
    ) -> Vec<Migration> {
        let to = first_device_tier(tiers);
        if served <= to {
            return Vec::new();
        }
        vec![Migration {
            key: key.to_string(),
            from: served,
            to,
            evict_src: false,
        }]
    }
}

/// Hot-set promotion: count reads per key and promote into the
/// fastest device tier (see [`Lru`] on why not a RAM tier) only past
/// `promote_after` accesses, halving every count each `decay_every`
/// reads so yesterday's hot set ages out.  One-shot scans never
/// cross the threshold, so they cannot flush the cache — the
/// property [`Lru`] lacks.
#[derive(Debug)]
pub struct Frequency {
    promote_after: u32,
    /// Reads between decay sweeps; 0 disables decay.
    decay_every: u64,
    counts: HashMap<String, u32>,
    reads: u64,
}

impl Frequency {
    pub fn new(promote_after: u32, decay_every: u64) -> Frequency {
        Frequency {
            promote_after: promote_after.max(1),
            decay_every,
            counts: HashMap::new(),
            reads: 0,
        }
    }

    /// Accesses recorded for `key` so far (tests / introspection).
    pub fn count(&self, key: &str) -> u32 {
        self.counts.get(key).copied().unwrap_or(0)
    }
}

impl Default for Frequency {
    /// Promote on the 3rd access, decay every 1024 reads — hot enough
    /// to catch a training loop's repeated samples, cold enough to
    /// ignore a single epoch-start scan.
    fn default() -> Frequency {
        Frequency::new(3, 1024)
    }
}

impl PlacementPolicy for Frequency {
    fn name(&self) -> &'static str {
        "freq"
    }

    fn on_read(
        &mut self,
        key: &str,
        _bytes: u64,
        served: usize,
        tiers: &[TierView],
    ) -> Vec<Migration> {
        self.reads += 1;
        if self.decay_every > 0 && self.reads % self.decay_every == 0 {
            for c in self.counts.values_mut() {
                *c /= 2;
            }
            self.counts.retain(|_, c| *c > 0);
        }
        let count = {
            let c = self.counts.entry(key.to_string()).or_insert(0);
            *c = c.saturating_add(1);
            *c
        };
        let to = first_device_tier(tiers);
        if served <= to || count < self.promote_after {
            return Vec::new();
        }
        vec![Migration {
            key: key.to_string(),
            from: served,
            to,
            evict_src: false,
        }]
    }

    fn on_remove(&mut self, key: &str, _tier: usize) {
        // Evicted from a tier: reset the count so the key must
        // re-earn promotion (otherwise every post-eviction read
        // immediately re-promotes and the cache thrashes).
        self.counts.remove(key);
    }
}

/// Valid policy names, in the order `by_name` accepts them (the list
/// unknown-name errors print).
pub const POLICY_NAMES: [&str; 3] = ["noop", "lru", "freq"];

/// Resolve a policy by name (default parameters); unknown names list
/// the valid set — the same contract as `profiles::by_name` errors.
pub fn by_name(name: &str) -> Result<Box<dyn PlacementPolicy>> {
    match name {
        "noop" => Ok(Box::new(Noop)),
        "lru" => Ok(Box::new(Lru)),
        "freq" | "frequency" => Ok(Box::<Frequency>::default()),
        other => Err(anyhow!(
            "unknown placement policy {other:?} (valid: {})",
            POLICY_NAMES.join(", ")
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiers() -> Vec<TierView> {
        vec![
            TierView {
                name: "optane".into(),
                is_ram: false,
                capacity: 1 << 20,
                used: 0,
            },
            TierView {
                name: "hdd".into(),
                is_ram: false,
                capacity: 0,
                used: 0,
            },
        ]
    }

    #[test]
    fn noop_never_migrates() {
        let mut p = Noop;
        for i in 0..10 {
            assert!(p.on_read(&format!("k{i}"), 100, 1, &tiers()).is_empty());
        }
        assert_eq!(p.place_write("k", 100, &tiers()), 0);
    }

    #[test]
    fn lru_promotes_every_slow_read_but_not_tier0_hits() {
        let mut p = Lru;
        let m = p.on_read("k", 100, 1, &tiers());
        assert_eq!(
            m,
            vec![Migration {
                key: "k".into(),
                from: 1,
                to: 0,
                evict_src: false
            }]
        );
        assert!(p.on_read("k", 100, 0, &tiers()).is_empty());
    }

    #[test]
    fn frequency_promotes_exactly_at_threshold() {
        let mut p = Frequency::new(3, 0);
        assert!(p.on_read("hot", 100, 1, &tiers()).is_empty(), "1st read");
        assert!(p.on_read("hot", 100, 1, &tiers()).is_empty(), "2nd read");
        let m = p.on_read("hot", 100, 1, &tiers());
        assert_eq!(m.len(), 1, "3rd read crosses the threshold");
        assert_eq!(m[0].to, 0);
        // Cold keys interleaved never cross.
        for i in 0..10 {
            assert!(p.on_read(&format!("cold{i}"), 100, 1, &tiers()).is_empty());
        }
        // Already-fast keys count but don't re-migrate from tier 0.
        assert!(p.on_read("hot", 100, 0, &tiers()).is_empty());
    }

    #[test]
    fn frequency_decay_halves_counts() {
        // decay_every = 4: after 4 reads every count halves, so a key
        // warmed to 2 drops back to 1 and needs 2 more reads.
        let mut p = Frequency::new(3, 4);
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty()); // count 1
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty()); // count 2
        assert!(p.on_read("x", 1, 1, &tiers()).is_empty());
        assert!(p.on_read("y", 1, 1, &tiers()).is_empty()); // decay: k -> 1
        assert_eq!(p.count("k"), 1);
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty()); // count 2
        assert_eq!(p.on_read("k", 1, 1, &tiers()).len(), 1); // count 3
    }

    #[test]
    fn frequency_eviction_resets_the_count() {
        let mut p = Frequency::new(2, 0);
        assert!(p.on_read("k", 1, 1, &tiers()).is_empty());
        assert_eq!(p.on_read("k", 1, 1, &tiers()).len(), 1);
        p.on_remove("k", 0);
        assert!(
            p.on_read("k", 1, 1, &tiers()).is_empty(),
            "evicted key must re-earn promotion"
        );
    }

    #[test]
    fn by_name_resolves_and_rejects_with_the_valid_list() {
        for n in POLICY_NAMES {
            assert_eq!(by_name(n).unwrap().name(), n);
        }
        let err = by_name("banana").unwrap_err().to_string();
        assert!(err.contains("noop") && err.contains("freq"), "{err}");
    }

    #[test]
    fn promotions_target_the_first_device_tier_not_ram() {
        // [ram, device, device]: promotions land in the device cache
        // (index 1) — the RAM tier fills read-through on its own, so
        // targeting it would make the policy axis a no-op on
        // RAM-topped hierarchies.
        let mut t = tiers();
        t.insert(
            0,
            TierView {
                name: "ram".into(),
                is_ram: true,
                capacity: 1 << 20,
                used: 0,
            },
        );
        let mut lru = Lru;
        assert_eq!(
            lru.on_read("k", 100, 2, &t),
            vec![Migration {
                key: "k".into(),
                from: 2,
                to: 1,
                evict_src: false
            }]
        );
        assert!(
            lru.on_read("k", 100, 1, &t).is_empty(),
            "already in the device cache"
        );
        let mut f = Frequency::new(1, 0);
        assert_eq!(f.on_read("k", 100, 2, &t)[0].to, 1);
    }

    #[test]
    fn first_device_tier_skips_ram() {
        let mut t = tiers();
        t.insert(
            0,
            TierView {
                name: "ram".into(),
                is_ram: true,
                capacity: 1 << 20,
                used: 0,
            },
        );
        assert_eq!(first_device_tier(&t), 1);
        let mut p = Noop;
        assert_eq!(p.place_write("k", 1, &t), 1);
    }
}
