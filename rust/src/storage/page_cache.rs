//! Simulated OS page cache.
//!
//! The paper goes out of its way to defeat the page cache
//! (`posix_fadvise(POSIX_FADV_DONTNEED)`, `drop_caches`, one-epoch
//! runs, §IV) because a warm cache hides the device entirely.  We model
//! the cache explicitly so both regimes are measurable: a hit serves
//! the read with **no device charge**; a miss pays the device and
//! inserts the file.  Eviction is LRU over whole files with a byte
//! capacity, which is the granularity that matters for the workloads
//! here (whole-file `tf.read()`s).

use std::collections::HashMap;
use std::sync::Mutex;

struct CacheState {
    /// path -> (bytes, lru tick)
    entries: HashMap<String, (u64, u64)>,
    total: u64,
    tick: u64,
    hits: u64,
    misses: u64,
}

/// LRU whole-file page cache with a byte capacity.
pub struct PageCache {
    capacity: u64,
    state: Mutex<CacheState>,
}

impl PageCache {
    /// `capacity` = 0 disables caching (every access is a miss).
    pub fn new(capacity: u64) -> Self {
        PageCache {
            capacity,
            state: Mutex::new(CacheState {
                entries: HashMap::new(),
                total: 0,
                tick: 0,
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Record an access; returns `true` on hit (no device charge).
    pub fn access(&self, path: &str, bytes: u64) -> bool {
        if self.capacity == 0 {
            let mut st = self.state.lock().unwrap();
            st.misses += 1;
            return false;
        }
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        let cached_size = st.entries.get(path).map(|&(b, _)| b);
        match cached_size {
            Some(b) if b == bytes => {
                st.entries.get_mut(path).expect("entry present").1 = tick;
                st.hits += 1;
                return true;
            }
            Some(b) => {
                // Size changed under us (the file was overwritten via
                // a path that bypassed invalidation): the cached entry
                // is stale — drop it and treat this access as a miss,
                // so accounting can never carry a phantom size.
                st.entries.remove(path);
                st.total -= b;
            }
            None => {}
        }
        st.misses += 1;
        // Insert (files larger than the cache are not cached).
        if bytes <= self.capacity {
            st.total += bytes;
            st.entries.insert(path.to_string(), (bytes, tick));
            while st.total > self.capacity {
                // Evict LRU.
                let victim = st
                    .entries
                    .iter()
                    .min_by_key(|(_, (_, t))| *t)
                    .map(|(k, (b, _))| (k.clone(), *b))
                    .expect("non-empty cache over capacity");
                st.entries.remove(&victim.0);
                st.total -= victim.1;
            }
        }
        false
    }

    /// Invalidate one file (fadvise DONTNEED).
    pub fn invalidate(&self, path: &str) {
        let mut st = self.state.lock().unwrap();
        if let Some((b, _)) = st.entries.remove(path) {
            st.total -= b;
        }
    }

    /// Drop everything (`echo 1 > /proc/sys/vm/drop_caches`).
    pub fn drop_all(&self) {
        let mut st = self.state.lock().unwrap();
        st.entries.clear();
        st.total = 0;
    }

    /// (hits, misses) counters.
    pub fn stats(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.hits, st.misses)
    }

    /// Bytes currently cached.
    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_access_misses_second_hits() {
        let c = PageCache::new(1 << 20);
        assert!(!c.access("a", 100));
        assert!(c.access("a", 100));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn zero_capacity_never_hits() {
        let c = PageCache::new(0);
        assert!(!c.access("a", 1));
        assert!(!c.access("a", 1));
        assert_eq!(c.resident_bytes(), 0);
    }

    #[test]
    fn lru_eviction_under_pressure() {
        let c = PageCache::new(250);
        c.access("a", 100);
        c.access("b", 100);
        c.access("a", 100); // refresh a
        c.access("c", 100); // evicts b (LRU)
        assert!(c.access("a", 100), "a should still be cached");
        assert!(!c.access("b", 100), "b should have been evicted");
    }

    #[test]
    fn oversized_file_not_cached() {
        let c = PageCache::new(50);
        assert!(!c.access("big", 100));
        assert!(!c.access("big", 100));
        assert_eq!(c.resident_bytes(), 0);
    }

    #[test]
    fn drop_all_flushes() {
        let c = PageCache::new(1 << 20);
        c.access("a", 10);
        c.access("b", 20);
        c.drop_all();
        assert_eq!(c.resident_bytes(), 0);
        assert!(!c.access("a", 10));
    }

    #[test]
    fn size_change_is_a_miss_and_reconciles_accounting() {
        let c = PageCache::new(1 << 20);
        assert!(!c.access("a", 100));
        assert!(c.access("a", 100));
        // The file was overwritten with a different size: stale entry
        // must not hit, and the accounting must follow the new size.
        assert!(!c.access("a", 60));
        assert_eq!(c.resident_bytes(), 60);
        assert!(c.access("a", 60));
    }

    #[test]
    fn invalidate_single_path() {
        let c = PageCache::new(1 << 20);
        c.access("a", 10);
        c.access("b", 20);
        c.invalidate("a");
        assert!(!c.access("a", 10));
        assert!(c.access("b", 20));
    }
}
