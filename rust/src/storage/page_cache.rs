//! Simulated OS page cache — now a compatibility wrapper over the
//! storage hierarchy's RAM tier.
//!
//! The paper goes out of its way to defeat the page cache
//! (`posix_fadvise(POSIX_FADV_DONTNEED)`, `drop_caches`, one-epoch
//! runs, §IV) because a warm cache hides the device entirely.  We model
//! the cache explicitly so both regimes are measurable: a hit serves
//! the read with **no device charge**; a miss pays the device and
//! inserts the file.  Eviction is LRU over whole files with a byte
//! capacity, which is the granularity that matters for the workloads
//! here (whole-file `tf.read()`s).
//!
//! Since the N-tier refactor (DESIGN.md §12) this exact model *is*
//! [`RamTier`](super::hierarchy::RamTier) — tier 0 of a
//! [`StorageHierarchy`](super::hierarchy::StorageHierarchy).  The
//! `PageCache` type remains as the sim-level facade (stable API for
//! `StorageSim` and its dirty-key plumbing) and delegates everything.

use super::hierarchy::RamTier;

/// LRU whole-file page cache with a byte capacity: the hierarchy's
/// RAM tier, wearing its original name.
pub struct PageCache {
    tier: RamTier,
}

impl PageCache {
    /// `capacity` = 0 disables caching (every access is a miss).
    pub fn new(capacity: u64) -> Self {
        PageCache { tier: RamTier::new(capacity) }
    }

    /// Record an access; returns `true` on hit (no device charge).
    pub fn access(&self, path: &str, bytes: u64) -> bool {
        self.tier.access(path, bytes)
    }

    /// Invalidate one file (fadvise DONTNEED).
    pub fn invalidate(&self, path: &str) {
        self.tier.invalidate(path)
    }

    /// Drop everything (`echo 1 > /proc/sys/vm/drop_caches`).
    pub fn drop_all(&self) {
        self.tier.drop_all()
    }

    /// (hits, misses) counters.
    pub fn stats(&self) -> (u64, u64) {
        self.tier.stats()
    }

    /// Bytes currently cached.
    pub fn resident_bytes(&self) -> u64 {
        self.tier.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_access_misses_second_hits() {
        let c = PageCache::new(1 << 20);
        assert!(!c.access("a", 100));
        assert!(c.access("a", 100));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn zero_capacity_never_hits() {
        let c = PageCache::new(0);
        assert!(!c.access("a", 1));
        assert!(!c.access("a", 1));
        assert_eq!(c.resident_bytes(), 0);
    }

    #[test]
    fn lru_eviction_under_pressure() {
        let c = PageCache::new(250);
        c.access("a", 100);
        c.access("b", 100);
        c.access("a", 100); // refresh a
        c.access("c", 100); // evicts b (LRU)
        assert!(c.access("a", 100), "a should still be cached");
        assert!(!c.access("b", 100), "b should have been evicted");
    }

    #[test]
    fn oversized_file_not_cached() {
        let c = PageCache::new(50);
        assert!(!c.access("big", 100));
        assert!(!c.access("big", 100));
        assert_eq!(c.resident_bytes(), 0);
    }

    #[test]
    fn drop_all_flushes() {
        let c = PageCache::new(1 << 20);
        c.access("a", 10);
        c.access("b", 20);
        c.drop_all();
        assert_eq!(c.resident_bytes(), 0);
        assert!(!c.access("a", 10));
    }

    #[test]
    fn size_change_is_a_miss_and_reconciles_accounting() {
        let c = PageCache::new(1 << 20);
        assert!(!c.access("a", 100));
        assert!(c.access("a", 100));
        // The file was overwritten with a different size: stale entry
        // must not hit, and the accounting must follow the new size.
        assert!(!c.access("a", 60));
        assert_eq!(c.resident_bytes(), 60);
        assert!(c.access("a", 60));
    }

    #[test]
    fn invalidate_single_path() {
        let c = PageCache::new(1 << 20);
        c.access("a", 10);
        c.access("b", 20);
        c.invalidate("a");
        assert!(!c.access("a", 10));
        assert!(c.access("b", 20));
    }
}
