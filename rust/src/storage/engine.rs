//! [`IoEngine`]: the request-level submission/completion I/O engine
//! (DESIGN.md §9).
//!
//! The paper's central finding is that DL throughput is gated by I/O
//! *concurrency*: thread scaling buys up to 7.8x read bandwidth and
//! overlapping I/O with computation hides its cost entirely.  The
//! original [`StorageSim`](super::sim::StorageSim) surface was a
//! blocking whole-file facade — every in-flight request parked an OS
//! thread for its full modelled service time.  This module replaces
//! that substrate with a submission-queue / completion-ticket design:
//!
//! * [`IoEngine::submit`] enqueues an [`IoRequest`] and returns an
//!   [`IoTicket`] immediately; [`IoTicket::wait`] blocks only the
//!   caller that actually needs the completion.
//! * Each device owns a FIFO submission queue drained by a small
//!   worker pool (≤ the device's `channels`), so any number of
//!   in-flight requests are multiplexed over a bounded set of OS
//!   threads.  Submitted requests join the device queue immediately
//!   ([`Device::queue_enter`]), so the elevator model sees the true
//!   queue depth — queued asynchronous requests speed up an HDD
//!   exactly like the paper's blocked reader threads did.
//! * Reads and writes stream through the backing file in engine-sized
//!   chunks, pacing each chunk against the device's token bucket; a
//!   device-to-device [`IoRequest::Copy`] pipelines chunks from the
//!   source reader to the destination writer through a bounded queue,
//!   so drain memory is bounded by `chunk_size * STREAM_WINDOW`, not
//!   file size, and the read from the fast device overlaps the write
//!   to the slow one.
//! * Every request records queue latency (submit → service) and
//!   service time separately ([`EngineDeviceStats`]), the
//!   fine-grained per-request surface tf-Darshan instruments and the
//!   Fig. 4/8/10 drivers report queue depth from.

use std::collections::{HashMap, VecDeque};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::device::{Device, Dir};

/// Default streaming chunk: 1 MiB.
pub const DEFAULT_CHUNK: usize = 1 << 20;

/// Chunks buffered per stream (copy pipeline / streamed write): the
/// producer blocks once this many chunks are queued, bounding stream
/// memory at `chunk_size * STREAM_WINDOW` regardless of file size.
pub const STREAM_WINDOW: usize = 4;

/// Worker threads per device: one per modelled channel (Lustre's 32
/// OSTs included — fewer workers than channels would understate the
/// modelled concurrency), with a backstop cap for absurd configs.
/// Workers mostly sleep modelled service time, so they are cheap.
const MAX_WORKERS_PER_DEVICE: usize = 64;

// ---------------------------------------------------------------------------
// Public request/completion surface
// ---------------------------------------------------------------------------

/// One I/O request against a simulated device.  Paths are *backing*
/// filesystem paths (the sim resolves `device://rel` before
/// submitting).
pub enum IoRequest {
    /// Whole-file read through the device model; the completion
    /// carries the data.
    ReadFile { device: String, path: PathBuf },
    /// Whole-buffer write.
    WriteFile { device: String, path: PathBuf, data: Vec<u8> },
    /// Pacing-only read probe: service-time envelope without backing
    /// I/O (IOR, Table I).
    ProbeRead { device: String, bytes: u64 },
    /// Pacing-only write probe.
    ProbeWrite { device: String, bytes: u64 },
    /// Chunked device-to-device copy: the source read is pipelined
    /// into the destination write through a bounded chunk queue.
    Copy {
        src_device: String,
        src_path: PathBuf,
        dst_device: String,
        dst_path: PathBuf,
    },
}

/// What a finished request reports.
#[derive(Debug)]
pub struct IoCompletion {
    /// Bytes transferred (for a copy: bytes written to the target).
    pub bytes: u64,
    /// File contents for [`IoRequest::ReadFile`], `None` otherwise.
    pub data: Option<Vec<u8>>,
    /// Submit → service start (time spent queued).
    pub queue_secs: f64,
    /// Service start → completion.
    pub service_secs: f64,
}

struct TicketState {
    result: Option<Result<IoCompletion>>,
}

struct TicketShared {
    state: Mutex<TicketState>,
    done: Condvar,
}

/// Completion handle for a submitted request.  `wait` consumes the
/// ticket and blocks until the engine fills it; `ready` polls.
pub struct IoTicket {
    inner: Arc<TicketShared>,
}

impl IoTicket {
    /// Block until the request completes.
    pub fn wait(self) -> Result<IoCompletion> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(r) = st.result.take() {
                return r;
            }
            st = self.inner.done.wait(st).unwrap();
        }
    }

    /// Non-blocking completion check.
    pub fn ready(&self) -> bool {
        self.inner.state.lock().unwrap().result.is_some()
    }
}

fn new_ticket() -> (IoTicket, Arc<TicketShared>) {
    let shared = Arc::new(TicketShared {
        state: Mutex::new(TicketState { result: None }),
        done: Condvar::new(),
    });
    (IoTicket { inner: Arc::clone(&shared) }, shared)
}

fn complete(ticket: &Arc<TicketShared>, result: Result<IoCompletion>) {
    let mut st = ticket.state.lock().unwrap();
    st.result = Some(result);
    drop(st);
    ticket.done.notify_all();
}

// ---------------------------------------------------------------------------
// Stream buffer gauge
// ---------------------------------------------------------------------------

/// Engine-wide gauge of bytes sitting in stream chunk queues; `peak`
/// is what the bounded-memory acceptance bench asserts on.
struct BufferGauge {
    current: AtomicU64,
    peak: AtomicU64,
}

impl BufferGauge {
    fn new() -> BufferGauge {
        BufferGauge { current: AtomicU64::new(0), peak: AtomicU64::new(0) }
    }

    fn add(&self, n: u64) {
        let now = self.current.fetch_add(n, Ordering::SeqCst) + n;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    fn sub(&self, n: u64) {
        self.current.fetch_sub(n, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Bounded chunk queue (stream producer -> device worker)
// ---------------------------------------------------------------------------

struct ChunkQueueState {
    chunks: VecDeque<Result<Vec<u8>>>,
    /// Producer finished successfully.
    closed: bool,
    /// Consumer gave up (write error / shutdown): producers must stop.
    aborted: bool,
    /// An abort threw away queued chunks, so a `closed` queue can no
    /// longer be treated as fully delivered.
    discarded: bool,
}

struct ChunkQueue {
    state: Mutex<ChunkQueueState>,
    /// Producer waits here for space.
    space: Condvar,
    /// Consumer waits here for chunks.
    filled: Condvar,
    capacity: usize,
    gauge: Arc<BufferGauge>,
}

impl ChunkQueue {
    fn new(capacity: usize, gauge: Arc<BufferGauge>) -> ChunkQueue {
        ChunkQueue {
            state: Mutex::new(ChunkQueueState {
                chunks: VecDeque::new(),
                closed: false,
                aborted: false,
                discarded: false,
            }),
            space: Condvar::new(),
            filled: Condvar::new(),
            capacity: capacity.max(1),
            gauge,
        }
    }

    /// Enqueue a chunk (blocking on a full queue).  Returns `false`
    /// when the consumer aborted — the producer should stop.
    fn push(&self, chunk: Result<Vec<u8>>) -> bool {
        let bytes = chunk.as_ref().map(|c| c.len() as u64).unwrap_or(0);
        let mut st = self.state.lock().unwrap();
        while st.chunks.len() >= self.capacity && !st.aborted {
            st = self.space.wait(st).unwrap();
        }
        if st.aborted {
            return false;
        }
        // Gauge add strictly before the chunk becomes poppable, so the
        // matching sub can never race it below zero.
        self.gauge.add(bytes);
        st.chunks.push_back(chunk);
        drop(st);
        self.filled.notify_one();
        true
    }

    /// Producer-side end-of-stream marker.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.filled.notify_all();
    }

    /// Dequeue the next chunk; `None` = producer closed and queue
    /// drained; `Some(Err)` if the stream was aborted (engine
    /// shutdown) so the consumer fails the ticket instead of
    /// reporting a truncated success.
    fn pop(&self) -> Option<Result<Vec<u8>>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(c) = st.chunks.pop_front() {
                drop(st);
                if let Ok(bytes) = &c {
                    self.gauge.sub(bytes.len() as u64);
                }
                self.space.notify_one();
                return Some(c);
            }
            if st.closed && !st.discarded {
                // Producer finished and everything was delivered:
                // success, even if a shutdown abort landed afterwards.
                return None;
            }
            if st.aborted {
                // Discarded chunks always imply an abort, so this
                // also covers closed-but-truncated streams.
                return Some(Err(anyhow!("stream aborted (engine shutdown)")));
            }
            st = self.filled.wait(st).unwrap();
        }
    }

    /// Consumer-side abort: discard queued chunks and unblock the
    /// producer.
    fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.aborted = true;
        if !st.chunks.is_empty() {
            st.discarded = true;
        }
        let mut freed = 0u64;
        for c in st.chunks.drain(..) {
            if let Ok(bytes) = c {
                freed += bytes.len() as u64;
            }
        }
        drop(st);
        if freed > 0 {
            self.gauge.sub(freed);
        }
        self.space.notify_all();
        self.filled.notify_all();
    }
}

/// Producer handle for a streamed write (`IoEngine::write_stream`).
/// Bytes are buffered into engine-sized chunks and enqueued toward the
/// device worker; `push` blocks once [`STREAM_WINDOW`] chunks are
/// pending, which is the backpressure that bounds memory.
pub struct ChunkWriter {
    queue: Arc<ChunkQueue>,
    chunk_size: usize,
    pending: Vec<u8>,
    finished: bool,
}

impl ChunkWriter {
    /// Append bytes to the stream.
    pub fn push(&mut self, mut bytes: &[u8]) -> Result<()> {
        while !bytes.is_empty() {
            let room = self.chunk_size - self.pending.len();
            let take = room.min(bytes.len());
            self.pending.extend_from_slice(&bytes[..take]);
            bytes = &bytes[take..];
            if self.pending.len() == self.chunk_size {
                self.flush_pending()?;
            }
        }
        Ok(())
    }

    fn flush_pending(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let chunk =
            std::mem::replace(&mut self.pending, Vec::with_capacity(self.chunk_size));
        if !self.queue.push(Ok(chunk)) {
            return Err(anyhow!(
                "stream write aborted by the device worker \
                 (see the ticket for the underlying error)"
            ));
        }
        Ok(())
    }

    /// Flush the tail chunk and mark end-of-stream.  The write is
    /// complete once the associated ticket resolves.
    pub fn finish(mut self) -> Result<()> {
        self.flush_pending()?;
        self.finished = true;
        self.queue.close();
        Ok(())
    }
}

impl Drop for ChunkWriter {
    fn drop(&mut self) {
        if !self.finished {
            // Dropped without finish(): poison the stream so the
            // worker fails the ticket instead of persisting a
            // truncated file as success.
            self.queue.push(Err(anyhow!("stream writer dropped mid-write")));
            self.queue.close();
        }
    }
}

// ---------------------------------------------------------------------------
// Per-device queue + stats
// ---------------------------------------------------------------------------

/// Per-request aggregates for one device (snapshot via
/// [`IoEngine::stats`]).
#[derive(Debug, Clone, Default)]
pub struct EngineDeviceStats {
    pub device: String,
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    /// Total submit → service-start seconds across requests.
    pub queue_secs: f64,
    /// Total service seconds across requests.
    pub service_secs: f64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Deepest device queue observed at submit time.
    pub max_queue_depth: u32,
}

impl EngineDeviceStats {
    /// Mean queue wait per completed request, seconds.
    pub fn mean_queue_secs(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_secs / self.completed as f64
        }
    }

    /// Mean service time per completed request, seconds.
    pub fn mean_service_secs(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.service_secs / self.completed as f64
        }
    }
}

enum JobOp {
    Read { path: PathBuf },
    Write { path: PathBuf, data: Vec<u8> },
    Probe { dir: Dir, bytes: u64 },
}

struct Job {
    op: JobOp,
    ticket: Arc<TicketShared>,
    submitted: Instant,
    /// Queue depth when this request joined the device queue (0 for
    /// streams, which enter per chunk): the elevator gain floor for
    /// co-queued bursts.
    enq_depth: u32,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct DeviceQueue {
    device: Arc<Device>,
    state: Mutex<QueueState>,
    available: Condvar,
    stats: Mutex<EngineDeviceStats>,
}

impl DeviceQueue {
    fn push(&self, job: Job) {
        {
            let mut st = self.state.lock().unwrap();
            st.jobs.push_back(job);
        }
        self.available.notify_one();
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// Request-level I/O engine over the simulated devices.
pub struct IoEngine {
    queues: HashMap<String, Arc<DeviceQueue>>,
    workers: Vec<JoinHandle<()>>,
    chunk_size: usize,
    gauge: Arc<BufferGauge>,
    /// Live stream queues, aborted at shutdown so a producer that
    /// outlives the engine can never leave a stream thread parked in
    /// `pop`.
    streams: Mutex<Vec<std::sync::Weak<ChunkQueue>>>,
    /// Stream service threads (writers + copy readers), joined at
    /// shutdown.  Streams run on dedicated threads, NOT the unit
    /// worker pool: a long-lived or producer-stalled stream must
    /// never starve unit requests of workers.
    stream_threads: Mutex<Vec<JoinHandle<()>>>,
}

impl IoEngine {
    /// Build an engine over `devices` with the default chunk size.
    pub fn new(devices: &HashMap<String, Arc<Device>>) -> IoEngine {
        Self::with_chunk_size(devices, DEFAULT_CHUNK)
    }

    /// Build an engine with an explicit streaming chunk size.
    pub fn with_chunk_size(
        devices: &HashMap<String, Arc<Device>>,
        chunk_size: usize,
    ) -> IoEngine {
        let chunk_size = chunk_size.max(4 * 1024);
        let gauge = Arc::new(BufferGauge::new());
        let mut queues = HashMap::new();
        let mut workers = Vec::new();
        for (name, device) in devices {
            let q = Arc::new(DeviceQueue {
                device: Arc::clone(device),
                state: Mutex::new(QueueState {
                    jobs: VecDeque::new(),
                    shutdown: false,
                }),
                available: Condvar::new(),
                stats: Mutex::new(EngineDeviceStats {
                    device: name.clone(),
                    ..EngineDeviceStats::default()
                }),
            });
            let n_workers = device
                .model
                .channels
                .clamp(1, MAX_WORKERS_PER_DEVICE);
            for i in 0..n_workers {
                let q = Arc::clone(&q);
                let chunk = chunk_size;
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("dlio-io-{name}-{i}"))
                        .spawn(move || worker_loop(q, chunk))
                        .expect("spawn io-engine worker"),
                );
            }
            queues.insert(name.clone(), q);
        }
        IoEngine {
            queues,
            workers,
            chunk_size,
            gauge,
            streams: Mutex::new(Vec::new()),
            stream_threads: Mutex::new(Vec::new()),
        }
    }

    /// Track a stream queue for shutdown aborts (pruning dead ones).
    fn register_stream(&self, rx: &Arc<ChunkQueue>) {
        let mut streams = self.streams.lock().unwrap();
        streams.retain(|w| w.upgrade().is_some());
        streams.push(Arc::downgrade(rx));
    }

    fn track_thread(&self, handle: JoinHandle<()>) {
        let mut threads = self.stream_threads.lock().unwrap();
        // Drop handles of finished streams so a long run of saves
        // doesn't accumulate dead JoinHandles.
        threads.retain(|t| !t.is_finished());
        threads.push(handle);
    }

    /// Spawn the consumer half of a stream write on its own thread:
    /// claims the device per chunk, fills `ticket` on completion.
    fn spawn_stream_writer(
        &self,
        q: &Arc<DeviceQueue>,
        path: PathBuf,
        rx: Arc<ChunkQueue>,
        enq_depth: u32,
        ticket: Arc<TicketShared>,
    ) {
        let q = Arc::clone(q);
        let submitted = Instant::now();
        let handle = std::thread::Builder::new()
            .name(format!("dlio-io-stream-{}", q.device.name()))
            .spawn(move || {
                let t0 = Instant::now();
                let queue_secs = t0.duration_since(submitted).as_secs_f64();
                let result = write_stream_paced(&q.device, &path, &rx, enq_depth);
                if result.is_err() {
                    // Unblock and drain the producer before failing.
                    rx.abort();
                }
                let service_secs = t0.elapsed().as_secs_f64();
                {
                    let mut stats = q.stats.lock().unwrap();
                    stats.completed += 1;
                    stats.queue_secs += queue_secs;
                    stats.service_secs += service_secs;
                    match &result {
                        Ok(total) => stats.bytes_written += total,
                        Err(_) => stats.errors += 1,
                    }
                }
                complete(
                    &ticket,
                    result.map(|total| IoCompletion {
                        bytes: total,
                        data: None,
                        queue_secs,
                        service_secs,
                    }),
                );
            })
            .expect("spawn stream writer");
        self.track_thread(handle);
    }

    /// Streaming chunk size in force.
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    fn queue(&self, device: &str) -> Result<&Arc<DeviceQueue>> {
        self.queues
            .get(device)
            .ok_or_else(|| anyhow!("unknown device {device:?}"))
    }

    /// Submit a request; returns its completion ticket immediately.
    pub fn submit(&self, req: IoRequest) -> Result<IoTicket> {
        match req {
            IoRequest::ReadFile { device, path } => {
                self.submit_unit(&device, JobOp::Read { path })
            }
            IoRequest::WriteFile { device, path, data } => {
                self.submit_unit(&device, JobOp::Write { path, data })
            }
            IoRequest::ProbeRead { device, bytes } => {
                self.submit_unit(&device, JobOp::Probe { dir: Dir::Read, bytes })
            }
            IoRequest::ProbeWrite { device, bytes } => {
                self.submit_unit(&device, JobOp::Probe { dir: Dir::Write, bytes })
            }
            IoRequest::Copy { src_device, src_path, dst_device, dst_path } => {
                self.submit_copy(&src_device, src_path, &dst_device, dst_path)
            }
        }
    }

    /// Unit jobs join the device queue at submit time so the elevator
    /// model sees queued requests (the paper's queue-depth effect).
    fn submit_unit(&self, device: &str, op: JobOp) -> Result<IoTicket> {
        let q = self.queue(device)?;
        let (ticket, shared) = new_ticket();
        let enq_depth = q.device.queue_enter();
        {
            let mut stats = q.stats.lock().unwrap();
            stats.submitted += 1;
            if enq_depth > stats.max_queue_depth {
                stats.max_queue_depth = enq_depth;
            }
        }
        q.push(Job {
            op,
            ticket: Arc::clone(&shared),
            submitted: Instant::now(),
            enq_depth,
        });
        Ok(ticket)
    }

    /// Submit several requests through one doorbell: every request
    /// joins its device queue *before* any is serviced, so the
    /// elevator model sees the whole burst (io_uring's
    /// many-SQEs-one-doorbell semantics).  This is what makes an
    /// overlapped checkpoint triple on an HDD faster than three serial
    /// writes even with a single channel.  Tickets are returned in
    /// request order.
    pub fn submit_batch(&self, reqs: Vec<IoRequest>) -> Result<Vec<IoTicket>> {
        // Validate every target device before entering any queue.
        for req in &reqs {
            match req {
                IoRequest::ReadFile { device, .. }
                | IoRequest::WriteFile { device, .. }
                | IoRequest::ProbeRead { device, .. }
                | IoRequest::ProbeWrite { device, .. } => {
                    self.queue(device)?;
                }
                IoRequest::Copy { src_device, dst_device, .. } => {
                    self.queue(src_device)?;
                    self.queue(dst_device)?;
                }
            }
        }
        // Phase 1: enter every unit request's device queue.
        let mut slots: Vec<(Option<(String, JobOp)>, Option<IoTicket>)> =
            Vec::with_capacity(reqs.len());
        let mut burst_depth: HashMap<String, u32> = HashMap::new();
        for req in reqs {
            let unit = match req {
                IoRequest::ReadFile { device, path } => {
                    (device, JobOp::Read { path })
                }
                IoRequest::WriteFile { device, path, data } => {
                    (device, JobOp::Write { path, data })
                }
                IoRequest::ProbeRead { device, bytes } => {
                    (device, JobOp::Probe { dir: Dir::Read, bytes })
                }
                IoRequest::ProbeWrite { device, bytes } => {
                    (device, JobOp::Probe { dir: Dir::Write, bytes })
                }
                copy @ IoRequest::Copy { .. } => {
                    // Copies are stream pairs; they don't take part in
                    // the unit doorbell.
                    slots.push((None, Some(self.submit(copy)?)));
                    continue;
                }
            };
            let (device, op) = unit;
            let depth = self
                .queue(&device)
                .expect("validated above")
                .device
                .queue_enter();
            let entry = burst_depth.entry(device.clone()).or_insert(0);
            *entry = (*entry).max(depth);
            slots.push((Some((device, op)), None));
        }
        // Phase 2: push jobs, every one carrying its device's full
        // burst depth.
        let mut tickets = Vec::with_capacity(slots.len());
        for (unit, ready) in slots {
            match (unit, ready) {
                (None, Some(t)) => tickets.push(t),
                (Some((device, op)), None) => {
                    let q = self.queue(&device).expect("validated above");
                    let enq_depth = burst_depth[&device];
                    let (ticket, shared) = new_ticket();
                    {
                        let mut stats = q.stats.lock().unwrap();
                        stats.submitted += 1;
                        if enq_depth > stats.max_queue_depth {
                            stats.max_queue_depth = enq_depth;
                        }
                    }
                    q.push(Job {
                        op,
                        ticket: Arc::clone(&shared),
                        submitted: Instant::now(),
                        enq_depth,
                    });
                    tickets.push(ticket);
                }
                _ => unreachable!("slot is either unit or ready"),
            }
        }
        Ok(tickets)
    }

    /// Open a streamed write: returns the producer handle and the
    /// completion ticket.  The stream runs on a dedicated thread and
    /// claims the device per chunk, so a stalled producer holds
    /// neither a channel nor a pool worker hostage.
    pub fn write_stream(
        &self,
        device: &str,
        path: PathBuf,
    ) -> Result<(ChunkWriter, IoTicket)> {
        let q = self.queue(device)?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        let rx = Arc::new(ChunkQueue::new(STREAM_WINDOW, Arc::clone(&self.gauge)));
        self.register_stream(&rx);
        let (ticket, shared) = new_ticket();
        // The stream joins the device queue now (its first chunk
        // consumes the membership), so it counts toward any burst
        // submitted alongside it.
        let enq_depth = q.device.queue_enter();
        {
            let mut stats = q.stats.lock().unwrap();
            stats.submitted += 1;
            if enq_depth > stats.max_queue_depth {
                stats.max_queue_depth = enq_depth;
            }
        }
        self.spawn_stream_writer(q, path, Arc::clone(&rx), enq_depth, shared);
        let writer = ChunkWriter {
            queue: rx,
            chunk_size: self.chunk_size,
            pending: Vec::with_capacity(self.chunk_size),
            finished: false,
        };
        Ok((writer, ticket))
    }

    /// Streamed write fed from a backing file *without* charging any
    /// read device — the page-cache-warm copy source.  Chunks flow
    /// through the bounded window, so peak memory stays bounded by
    /// the chunk size even for warm multi-GB files.
    pub fn write_from_file(
        &self,
        device: &str,
        src_path: PathBuf,
        dst_path: PathBuf,
    ) -> Result<IoTicket> {
        let q = self.queue(device)?;
        if let Some(parent) = dst_path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        let rx = Arc::new(ChunkQueue::new(STREAM_WINDOW, Arc::clone(&self.gauge)));
        self.register_stream(&rx);
        let (ticket, shared) = new_ticket();
        let enq_depth = q.device.queue_enter();
        {
            let mut stats = q.stats.lock().unwrap();
            stats.submitted += 1;
            if enq_depth > stats.max_queue_depth {
                stats.max_queue_depth = enq_depth;
            }
        }
        self.spawn_stream_writer(q, dst_path, Arc::clone(&rx), enq_depth, shared);
        let chunk_size = self.chunk_size;
        let handle = std::thread::Builder::new()
            .name("dlio-io-warmread".into())
            .spawn(move || unpaced_file_reader(src_path, rx, chunk_size))
            .expect("spawn warm copy reader");
        self.track_thread(handle);
        Ok(ticket)
    }

    /// Copy = source reader thread feeding a bounded chunk queue into
    /// a destination stream-write job: read-from-src overlaps
    /// write-to-dst, memory bounded by the stream window.
    fn submit_copy(
        &self,
        src_device: &str,
        src_path: PathBuf,
        dst_device: &str,
        dst_path: PathBuf,
    ) -> Result<IoTicket> {
        let src_q = Arc::clone(self.queue(src_device)?);
        let dst_q = self.queue(dst_device)?;
        if let Some(parent) = dst_path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        let rx = Arc::new(ChunkQueue::new(STREAM_WINDOW, Arc::clone(&self.gauge)));
        self.register_stream(&rx);
        let (ticket, shared) = new_ticket();
        let dst_enq = dst_q.device.queue_enter();
        {
            let mut stats = dst_q.stats.lock().unwrap();
            stats.submitted += 1;
            if dst_enq > stats.max_queue_depth {
                stats.max_queue_depth = dst_enq;
            }
        }
        self.spawn_stream_writer(dst_q, dst_path, Arc::clone(&rx), dst_enq, shared);
        let src_enq = src_q.device.queue_enter();
        let chunk_size = self.chunk_size;
        let handle = std::thread::Builder::new()
            .name("dlio-io-copy".into())
            .spawn(move || copy_reader(src_q, src_path, rx, chunk_size, src_enq))
            .expect("spawn copy reader");
        self.track_thread(handle);
        Ok(ticket)
    }

    /// Per-device request aggregates.
    pub fn stats(&self) -> Vec<EngineDeviceStats> {
        let mut out: Vec<EngineDeviceStats> = self
            .queues
            .values()
            .map(|q| q.stats.lock().unwrap().clone())
            .collect();
        out.sort_by(|a, b| a.device.cmp(&b.device));
        out
    }

    /// Peak bytes ever buffered in stream chunk queues (the
    /// bounded-memory guarantee: ≤ chunk_size * STREAM_WINDOW + one
    /// in-flight chunk per stream).
    pub fn peak_stream_bytes(&self) -> u64 {
        self.gauge.peak.load(Ordering::SeqCst)
    }

    /// Reset the peak gauge (bench bracketing).
    pub fn reset_peak_stream_bytes(&self) {
        self.gauge
            .peak
            .store(self.gauge.current.load(Ordering::SeqCst), Ordering::SeqCst);
    }
}

impl Drop for IoEngine {
    fn drop(&mut self) {
        // Fail any still-open streams so no stream thread stays parked
        // in `pop`/`push` waiting on a peer that will never finish.
        for weak in self.streams.lock().unwrap().drain(..) {
            if let Some(rx) = weak.upgrade() {
                rx.abort();
            }
        }
        for q in self.queues.values() {
            let mut st = q.state.lock().unwrap();
            st.shutdown = true;
            drop(st);
            q.available.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        for t in self.stream_threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

fn worker_loop(q: Arc<DeviceQueue>, chunk_size: usize) {
    loop {
        let job = {
            let mut st = q.state.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = q.available.wait(st).unwrap();
            }
        };
        let queue_secs = job.submitted.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let outcome = run_job(&q.device, job.op, job.enq_depth, chunk_size);
        let service_secs = t0.elapsed().as_secs_f64();
        {
            let mut stats = q.stats.lock().unwrap();
            stats.queue_secs += queue_secs;
            stats.service_secs += service_secs;
            match &outcome {
                Ok((bytes, dir, _)) => {
                    stats.completed += 1;
                    match dir {
                        Dir::Read => stats.bytes_read += bytes,
                        Dir::Write => stats.bytes_written += bytes,
                    }
                }
                Err(_) => {
                    stats.completed += 1;
                    stats.errors += 1;
                }
            }
        }
        complete(
            &job.ticket,
            outcome.map(|(bytes, _, data)| IoCompletion {
                bytes,
                data,
                queue_secs,
                service_secs,
            }),
        );
    }
}

/// Execute one job; returns (bytes, direction, data).
fn run_job(
    dev: &Arc<Device>,
    op: JobOp,
    enq_depth: u32,
    chunk_size: usize,
) -> Result<(u64, Dir, Option<Vec<u8>>)> {
    match op {
        JobOp::Read { path } => {
            // Queue membership was taken at submit; claim a channel
            // and balance the gate whatever happens during service.
            let depth = dev.service_begin(enq_depth);
            dev.latency_phase(Dir::Read, depth);
            let res = read_paced(dev, &path, chunk_size);
            dev.service_end();
            let data = res?;
            Ok((data.len() as u64, Dir::Read, Some(data)))
        }
        JobOp::Write { path, data } => {
            let depth = dev.service_begin(enq_depth);
            dev.latency_phase(Dir::Write, depth);
            let res = write_paced(dev, &path, &data, chunk_size);
            dev.service_end();
            res?;
            Ok((data.len() as u64, Dir::Write, None))
        }
        JobOp::Probe { dir, bytes } => {
            let depth = dev.service_begin(enq_depth);
            dev.latency_phase(dir, depth);
            let chunk = dev.pacing_chunk(bytes).max(chunk_size as u64);
            let mut remaining = bytes;
            while remaining > 0 {
                let take = remaining.min(chunk);
                dev.pace(dir, take, 0.0);
                remaining -= take;
            }
            dev.service_end();
            Ok((bytes, dir, None))
        }
    }
}

/// Chunked paced whole-file read (the worker holds a channel).
fn read_paced(dev: &Arc<Device>, path: &Path, chunk_size: usize) -> Result<Vec<u8>> {
    let mut file = std::fs::File::open(path)
        .with_context(|| format!("read {}", path.display()))?;
    let size = file
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len() as usize;
    let mut out = Vec::with_capacity(size);
    let mut buf = vec![0u8; chunk_size];
    loop {
        let t0 = Instant::now();
        let n = file
            .read(&mut buf)
            .with_context(|| format!("read {}", path.display()))?;
        if n == 0 {
            break;
        }
        dev.pace(Dir::Read, n as u64, t0.elapsed().as_secs_f64());
        out.extend_from_slice(&buf[..n]);
    }
    Ok(out)
}

/// Chunked paced whole-buffer write (the worker holds a channel).
fn write_paced(
    dev: &Arc<Device>,
    path: &Path,
    data: &[u8],
    chunk_size: usize,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    for chunk in data.chunks(chunk_size.max(1)) {
        let t0 = Instant::now();
        file.write_all(chunk)
            .with_context(|| format!("write {}", path.display()))?;
        dev.pace(Dir::Write, chunk.len() as u64, t0.elapsed().as_secs_f64());
    }
    // A zero-byte payload still creates the file (no pacing charge).
    Ok(())
}

/// Streamed write: claims the device *per chunk* so a slow producer
/// (or a cross-device copy peer) can never deadlock two channel gates
/// against each other.  The latency phase is charged once, on the
/// first chunk, at the submit-time burst depth (`enq_depth`) or
/// deeper.  The stream's submit-time queue membership is consumed by
/// the first chunk's service (or released if no chunk arrives).
fn write_stream_paced(
    dev: &Arc<Device>,
    path: &Path,
    rx: &Arc<ChunkQueue>,
    enq_depth: u32,
) -> Result<u64> {
    let mut first = true;
    let result = write_stream_chunks(dev, path, rx, enq_depth, &mut first);
    if first {
        // No chunk ever claimed the submit-time queue membership.
        dev.queue_leave();
    }
    result
}

fn write_stream_chunks(
    dev: &Arc<Device>,
    path: &Path,
    rx: &Arc<ChunkQueue>,
    enq_depth: u32,
    first: &mut bool,
) -> Result<u64> {
    let mut file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut total = 0u64;
    while let Some(chunk) = rx.pop() {
        let chunk = chunk.context("stream source failed")?;
        if chunk.is_empty() {
            continue;
        }
        let depth = if *first {
            dev.service_begin(enq_depth)
        } else {
            let enq = dev.queue_enter();
            dev.service_begin(enq)
        };
        if *first {
            dev.latency_phase(Dir::Write, depth);
            *first = false;
        }
        let t0 = Instant::now();
        let io = file
            .write_all(&chunk)
            .with_context(|| format!("write {}", path.display()));
        if io.is_ok() {
            dev.pace(Dir::Write, chunk.len() as u64, t0.elapsed().as_secs_f64());
        }
        dev.service_end();
        io?;
        total += chunk.len() as u64;
    }
    Ok(total)
}

/// Source half of a warm copy: read the file in chunks with **no**
/// device pacing (the page cache already holds it) and feed the
/// bounded stream queue.
fn unpaced_file_reader(path: PathBuf, tx: Arc<ChunkQueue>, chunk_size: usize) {
    let result = (|| -> Result<()> {
        let mut file = std::fs::File::open(&path)
            .with_context(|| format!("read {}", path.display()))?;
        loop {
            let mut buf = vec![0u8; chunk_size];
            let n = file
                .read(&mut buf)
                .with_context(|| format!("read {}", path.display()))?;
            if n == 0 {
                return Ok(());
            }
            buf.truncate(n);
            if !tx.push(Ok(buf)) {
                return Ok(()); // consumer aborted
            }
        }
    })();
    if let Err(e) = result {
        tx.push(Err(e));
    }
    tx.close();
}

/// Source half of a copy: chunked paced read pushed into the bounded
/// queue.  Claims the source device per chunk (see
/// [`write_stream_paced`] for why), charging the read latency once at
/// the submit-time depth.
fn copy_reader(
    q: Arc<DeviceQueue>,
    path: PathBuf,
    tx: Arc<ChunkQueue>,
    chunk_size: usize,
    src_enq: u32,
) {
    let dev = &q.device;
    let mut first = true;
    let result = (|| -> Result<u64> {
        let mut file = std::fs::File::open(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let mut total = 0u64;
        loop {
            let mut buf = vec![0u8; chunk_size];
            let depth = if first {
                dev.service_begin(src_enq)
            } else {
                let enq = dev.queue_enter();
                dev.service_begin(enq)
            };
            if first {
                dev.latency_phase(Dir::Read, depth);
                first = false;
            }
            let t0 = Instant::now();
            let io = file
                .read(&mut buf)
                .with_context(|| format!("read {}", path.display()));
            let n = match io {
                Ok(n) => {
                    if n > 0 {
                        dev.pace(Dir::Read, n as u64, t0.elapsed().as_secs_f64());
                    }
                    dev.service_end();
                    n
                }
                Err(e) => {
                    dev.service_end();
                    return Err(e);
                }
            };
            if n == 0 {
                break;
            }
            buf.truncate(n);
            total += n as u64;
            if !tx.push(Ok(buf)) {
                break; // consumer aborted
            }
        }
        Ok(total)
    })();
    if first {
        // File-open failure: the submit-time membership was never
        // consumed by a read.
        dev.queue_leave();
    }
    match result {
        Ok(bytes) => {
            // The read half is a request against the source device:
            // account it so copy traffic shows up in stats().
            let mut stats = q.stats.lock().unwrap();
            stats.submitted += 1;
            stats.completed += 1;
            stats.bytes_read += bytes;
            drop(stats);
            tx.close();
        }
        Err(e) => {
            let mut stats = q.stats.lock().unwrap();
            stats.submitted += 1;
            stats.completed += 1;
            stats.errors += 1;
            drop(stats);
            tx.push(Err(e));
            tx.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::device::{DeviceModel, NullObserver};

    fn model(name: &str, channels: usize, time_scale: f64) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels,
            elevator: vec![(1, 1.0)],
            time_scale,
        }
    }

    fn engine_with(
        models: Vec<DeviceModel>,
        chunk: usize,
    ) -> (IoEngine, HashMap<String, Arc<Device>>) {
        let mut devices = HashMap::new();
        for m in models {
            devices.insert(
                m.name.clone(),
                Arc::new(Device::new(m, Arc::new(NullObserver))),
            );
        }
        let engine = IoEngine::with_chunk_size(&devices, chunk);
        (engine, devices)
    }

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dlio-engine-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_then_read_roundtrip() {
        let (eng, _) = engine_with(vec![model("d", 4, 1000.0)], 8 * 1024);
        let dir = scratch("rw");
        let path = dir.join("x.bin");
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let t = eng
            .submit(IoRequest::WriteFile {
                device: "d".into(),
                path: path.clone(),
                data: payload.clone(),
            })
            .unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.bytes, payload.len() as u64);
        let t = eng
            .submit(IoRequest::ReadFile { device: "d".into(), path })
            .unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.data.unwrap(), payload);
    }

    #[test]
    fn submit_is_asynchronous() {
        // A slow device (50 ms of modelled transfer) must not block
        // submit(): the ticket returns immediately and resolves later.
        let mut m = model("slow", 1, 1.0);
        m.read_bw = 20e6; // 1 MB at 20 MB/s = 50 ms
        let (eng, _) = engine_with(vec![m], 256 * 1024);
        let t0 = Instant::now();
        let t = eng
            .submit(IoRequest::ProbeRead { device: "slow".into(), bytes: 1_000_000 })
            .unwrap();
        assert!(
            t0.elapsed().as_secs_f64() < 0.03,
            "submit blocked: {:?}",
            t0.elapsed()
        );
        t.wait().unwrap();
        assert!(t0.elapsed().as_secs_f64() > 0.03, "no pacing applied");
    }

    #[test]
    fn unknown_device_rejected_at_submit() {
        let (eng, _) = engine_with(vec![model("d", 1, 1000.0)], 8 * 1024);
        assert!(eng
            .submit(IoRequest::ProbeRead { device: "nope".into(), bytes: 1 })
            .is_err());
    }

    #[test]
    fn read_missing_file_fails_ticket_not_engine() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 8 * 1024);
        let dir = scratch("missing");
        let t = eng
            .submit(IoRequest::ReadFile {
                device: "d".into(),
                path: dir.join("absent.bin"),
            })
            .unwrap();
        assert!(t.wait().is_err());
        // The engine keeps serving after a failed request.
        let t = eng
            .submit(IoRequest::ProbeRead { device: "d".into(), bytes: 1024 })
            .unwrap();
        assert!(t.wait().is_ok());
    }

    #[test]
    fn copy_larger_than_chunk_roundtrips_bit_exact() {
        // Satellite: chunked cross-device copy, payload >> chunk.
        let chunk = 16 * 1024;
        let (eng, _) = engine_with(
            vec![model("a", 2, 1000.0), model("b", 2, 1000.0)],
            chunk,
        );
        let dir = scratch("copy");
        let src = dir.join("src.bin");
        let dst = dir.join("dst.bin");
        let mut payload = vec![0u8; chunk * 7 + 311]; // not chunk-aligned
        for (i, b) in payload.iter_mut().enumerate() {
            *b = (i * 31 % 257) as u8;
        }
        std::fs::write(&src, &payload).unwrap();
        let t = eng
            .submit(IoRequest::Copy {
                src_device: "a".into(),
                src_path: src,
                dst_device: "b".into(),
                dst_path: dst.clone(),
            })
            .unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.bytes, payload.len() as u64);
        assert_eq!(std::fs::read(&dst).unwrap(), payload);
        // Stream memory stayed bounded by the window, not file size.
        assert!(
            eng.peak_stream_bytes() <= (chunk * (STREAM_WINDOW + 1)) as u64,
            "peak {} exceeds window {}",
            eng.peak_stream_bytes(),
            chunk * (STREAM_WINDOW + 1)
        );
    }

    #[test]
    fn same_device_copy_does_not_deadlock() {
        let chunk = 8 * 1024;
        let (eng, _) = engine_with(vec![model("one", 1, 1000.0)], chunk);
        let dir = scratch("selfcopy");
        let src = dir.join("src.bin");
        let payload = vec![7u8; chunk * 5];
        std::fs::write(&src, &payload).unwrap();
        let t = eng
            .submit(IoRequest::Copy {
                src_device: "one".into(),
                src_path: src,
                dst_device: "one".into(),
                dst_path: dir.join("dst.bin"),
            })
            .unwrap();
        assert_eq!(t.wait().unwrap().bytes, payload.len() as u64);
    }

    #[test]
    fn stream_write_assembles_chunks_in_order() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 4 * 1024);
        let dir = scratch("stream");
        let path = dir.join("s.bin");
        let (mut w, t) = eng.write_stream("d", path.clone()).unwrap();
        let mut expect = Vec::new();
        for i in 0..40u32 {
            let piece = vec![(i % 256) as u8; 700]; // misaligned pieces
            w.push(&piece).unwrap();
            expect.extend_from_slice(&piece);
        }
        w.finish().unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.bytes, expect.len() as u64);
        assert_eq!(std::fs::read(&path).unwrap(), expect);
    }

    #[test]
    fn dropped_stream_writer_fails_the_ticket() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 4 * 1024);
        let dir = scratch("dropstream");
        let (mut w, t) = eng.write_stream("d", dir.join("s.bin")).unwrap();
        w.push(&[1u8; 100]).unwrap();
        drop(w); // no finish()
        assert!(t.wait().is_err());
    }

    #[test]
    fn overlapped_submissions_beat_serial_on_latency_device() {
        // 20 ms latency, 4 channels: 4 overlapped probes ≈ 1 serial.
        let mut m = model("lat", 4, 1.0);
        m.read_lat = 0.02;
        m.read_bw = 1e12;
        let (eng, _) = engine_with(vec![m], 64 * 1024);
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..4)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead { device: "lat".into(), bytes: 1 })
                    .unwrap()
            })
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let overlapped = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        for _ in 0..4 {
            eng.submit(IoRequest::ProbeRead { device: "lat".into(), bytes: 1 })
                .unwrap()
                .wait()
                .unwrap();
        }
        let serial = t0.elapsed().as_secs_f64();
        assert!(
            overlapped < serial * 0.7,
            "overlapped {overlapped:.4}s !< serial {serial:.4}s"
        );
    }

    #[test]
    fn stats_record_queue_and_service_per_device() {
        let (eng, _) = engine_with(vec![model("d", 1, 1000.0)], 8 * 1024);
        for _ in 0..3 {
            eng.submit(IoRequest::ProbeWrite { device: "d".into(), bytes: 100_000 })
                .unwrap()
                .wait()
                .unwrap();
        }
        let stats = eng.stats();
        assert_eq!(stats.len(), 1);
        let s = &stats[0];
        assert_eq!(s.device, "d");
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 3);
        assert_eq!(s.errors, 0);
        assert_eq!(s.bytes_written, 300_000);
        assert!(s.service_secs >= 0.0 && s.queue_secs >= 0.0);
        assert!(s.max_queue_depth >= 1);
    }

    #[test]
    fn batch_doorbell_shares_burst_elevator_gain() {
        // Single-channel 20 ms-latency device with elevator gain: a
        // batched triple must beat three serial submissions because
        // every member sees the burst depth (gain ~1.67 at depth 3).
        let mut m = model("elev", 1, 1.0);
        m.read_lat = 0.02;
        m.read_bw = 1e12;
        m.elevator = vec![(1, 1.0), (4, 2.0)];
        let (eng, _) = engine_with(vec![m], 64 * 1024);
        let t0 = Instant::now();
        for _ in 0..3 {
            eng.submit(IoRequest::ProbeRead { device: "elev".into(), bytes: 1 })
                .unwrap()
                .wait()
                .unwrap();
        }
        let serial = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let tickets = eng
            .submit_batch(
                (0..3)
                    .map(|_| IoRequest::ProbeRead {
                        device: "elev".into(),
                        bytes: 1,
                    })
                    .collect(),
            )
            .unwrap();
        for t in tickets {
            t.wait().unwrap();
        }
        let batched = t0.elapsed().as_secs_f64();
        // Modelled: serial 60 ms vs batched ~36 ms.
        assert!(
            batched < serial * 0.8,
            "batched {batched:.4}s !< serial {serial:.4}s"
        );
    }

    #[test]
    fn queued_submissions_raise_observed_depth() {
        // A single-channel device with many outstanding requests must
        // report a deep queue (what the elevator model feeds on).
        let mut m = model("q", 1, 1.0);
        m.read_bw = 50e6; // each 500 KB probe ≈ 10 ms
        let (eng, devices) = engine_with(vec![m], 64 * 1024);
        let tickets: Vec<_> = (0..6)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead { device: "q".into(), bytes: 500_000 })
                    .unwrap()
            })
            .collect();
        // While the first is in service, the rest are queued.
        let depth_seen = devices["q"].queue_depth();
        for t in tickets {
            t.wait().unwrap();
        }
        assert!(depth_seen >= 4, "depth {depth_seen}");
        assert_eq!(devices["q"].queue_depth(), 0, "gate drained");
        let s = &eng.stats()[0];
        assert!(s.max_queue_depth >= 4, "stat depth {}", s.max_queue_depth);
    }
}
