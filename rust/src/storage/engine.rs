//! [`IoEngine`]: the request-level submission/completion I/O engine
//! (DESIGN.md §9).
//!
//! The paper's central finding is that DL throughput is gated by I/O
//! *concurrency*: thread scaling buys up to 7.8x read bandwidth and
//! overlapping I/O with computation hides its cost entirely.  The
//! original [`StorageSim`](super::sim::StorageSim) surface was a
//! blocking whole-file facade — every in-flight request parked an OS
//! thread for its full modelled service time.  This module replaces
//! that substrate with a submission-queue / completion-ticket design:
//!
//! * [`IoEngine::submit`] enqueues an [`IoRequest`] and returns an
//!   [`IoTicket`] immediately; [`IoTicket::wait`] blocks only the
//!   caller that actually needs the completion.
//! * Every request carries an [`IoClass`] and each device schedules a
//!   weighted deficit-round-robin over per-class queues
//!   ([`QosConfig`]), so a checkpoint burst can no longer
//!   head-of-line-block ingest reads — the §V interference the paper
//!   measures.  Streams yield to queued higher-priority work at
//!   configurable chunk-boundary preemption points.
//! * Each device's class queues are drained by a small
//!   worker pool (≤ the device's `channels`), so any number of
//!   in-flight requests are multiplexed over a bounded set of OS
//!   threads.  Submitted requests join the device queue immediately
//!   ([`Device::queue_enter`]), so the elevator model sees the true
//!   queue depth — queued asynchronous requests speed up an HDD
//!   exactly like the paper's blocked reader threads did.
//! * Reads and writes stream through the backing file in engine-sized
//!   chunks, pacing each chunk against the device's token bucket; a
//!   device-to-device [`IoRequest::Copy`] pipelines chunks from the
//!   source reader to the destination writer through a bounded queue,
//!   so drain memory is bounded by `chunk_size * STREAM_WINDOW`, not
//!   file size, and the read from the fast device overlaps the write
//!   to the slow one.
//! * Every request records queue latency (submit → service) and
//!   service time separately ([`EngineDeviceStats`]), the
//!   fine-grained per-request surface tf-Darshan instruments and the
//!   Fig. 4/8/10 drivers report queue depth from.

use std::collections::{HashMap, VecDeque};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::metrics::LatencyHistogram;

use super::clock::{Clock, SimCondvar};
use super::device::{Device, Dir, TokenBucket};

// ---------------------------------------------------------------------------
// Traffic classes + QoS configuration
// ---------------------------------------------------------------------------

/// Traffic class of an I/O request — the paper's central contention
/// pair plus the two background flows around it:
///
/// * `Ingest`     — dataset reads feeding training (latency-critical:
///   a stalled read stalls the accelerator, §V-A).
/// * `Checkpoint` — saver writes (training is paused while they run,
///   §V-C, so they deserve bandwidth but must not head-of-line-block
///   ingest once training resumes).
/// * `Drain`      — burst-buffer stage→archive copies ("continues
///   after the application ends", §V-C: pure background bandwidth).
/// * `Background` — maintenance and any explicitly-tagged low-priority
///   traffic.  Probes deliberately default to their direction's class
///   (reads → `Ingest`, writes → `Checkpoint`): they emulate real
///   ingest/checkpoint requests, and the IOR bounds they measure must
///   not run at starvation weight.
///
/// Order is priority order: preemption points let a stream yield to
/// any strictly-lower-index class with queued work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IoClass {
    Ingest,
    Checkpoint,
    Drain,
    Background,
}

impl IoClass {
    pub const COUNT: usize = 4;
    pub const ALL: [IoClass; IoClass::COUNT] = [
        IoClass::Ingest,
        IoClass::Checkpoint,
        IoClass::Drain,
        IoClass::Background,
    ];

    pub fn index(self) -> usize {
        match self {
            IoClass::Ingest => 0,
            IoClass::Checkpoint => 1,
            IoClass::Drain => 2,
            IoClass::Background => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            IoClass::Ingest => "ingest",
            IoClass::Checkpoint => "checkpoint",
            IoClass::Drain => "drain",
            IoClass::Background => "background",
        }
    }

    /// Inverse of [`name`](Self::name) (trace files carry class names,
    /// not indices, so a reader of a different build stays compatible).
    pub fn parse(s: &str) -> Option<IoClass> {
        IoClass::ALL.into_iter().find(|c| c.name() == s)
    }
}

impl std::fmt::Display for IoClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Hard per-class throughput cap (the knob that turns "de-prioritized"
/// into "bounded"): `bytes_per_sec` is a **modelled** rate — the
/// per-device bucket refills at `bytes_per_sec * time_scale` wall
/// bytes/sec, so caps keep their meaning on accelerated testbeds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateCap {
    /// Modelled bytes per second granted to the class.
    pub bytes_per_sec: f64,
    /// Bucket capacity, bytes: how much a class that went idle can
    /// burst before the cap bites again.
    pub burst_bytes: u64,
}

/// Bounded retry-with-backoff for failed unit requests (reads, writes,
/// probes) — the degraded-mode half of the fault seam (DESIGN.md §15).
/// A failed request is re-run up to `budget[class]` times with
/// exponential backoff before its error surfaces; every re-attempt is
/// counted in the device/class `retries` counters, while `errors`
/// stays exactly-once per finally-failed request.  Streams (chunked
/// writes, copy halves) fail fast: a mid-stream retry would replay
/// already-consumed chunks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Re-attempts after the first failure, indexed by
    /// [`IoClass::index`] (0 disables retries for the class).
    pub budget: [u32; IoClass::COUNT],
    /// First backoff sleep, **modelled** seconds (doubles per
    /// attempt; divided by the device's `time_scale` at the sleep
    /// point, like [`QosConfig::max_yield_wait`]).
    pub backoff: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { budget: [2; IoClass::COUNT], backoff: 0.002 }
    }
}

impl RetryPolicy {
    /// Disable retries entirely (every failure surfaces immediately —
    /// the pre-fault-seam behaviour, kept for A/B comparisons).
    pub fn none() -> RetryPolicy {
        RetryPolicy { budget: [0; IoClass::COUNT], backoff: 0.002 }
    }
}

/// Identity of the job (tenant) a request belongs to — the outer key
/// of the hierarchical `(TenantId, IoClass)` scheduler.  Cheap to
/// clone (a shared string).  The default (empty) tenant is the
/// tenant-blind path every untagged caller lands on; a single-tenant
/// engine therefore runs the exact flat per-class scheduler.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(Arc<str>);

impl TenantId {
    pub fn new(name: &str) -> TenantId {
        TenantId(Arc::from(name))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }

    /// The default (untagged) tenant.
    pub fn is_default(&self) -> bool {
        self.0.is_empty()
    }
}

impl Default for TenantId {
    fn default() -> Self {
        TenantId(Arc::from(""))
    }
}

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_default() {
            f.write_str("-")
        } else {
            f.write_str(&self.0)
        }
    }
}

/// Per-tenant scheduling configuration ([`QosConfig::tenants`]): the
/// outer deficit-round-robin's share table, optional per-tenant hard
/// rate caps, and per-tenant adaptive ingest targets.  Tenants not
/// listed in `shares` fall back to `default_share`; untagged traffic
/// schedules as the default tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantQos {
    /// `(tenant, share)` outer-DRR weights: a tenant's slot is
    /// granted `share * chunk_size` bytes per outer round, so device
    /// bandwidth converges to the share ratio under saturation.
    pub shares: Vec<(String, u32)>,
    /// Share for tenants without an explicit entry (including the
    /// default tenant untagged traffic schedules under).
    pub default_share: u32,
    /// Optional per-tenant hard rate caps (**modelled** bytes/sec,
    /// same semantics as the per-class [`RateCap`]s): a tenant whose
    /// bucket is in debt is skipped by the outer round without losing
    /// its accumulated share deficit.
    pub rate_caps: Vec<(String, RateCap)>,
    /// Per-tenant adaptive ingest p99 targets, **modelled** seconds:
    /// the AIMD controller is instanced per tenant, and a tenant
    /// listed here is steered toward its own bar.  Tenants not listed
    /// use the device-resolved global target.
    pub adaptive_targets: Vec<(String, f64)>,
}

impl Default for TenantQos {
    fn default() -> Self {
        TenantQos {
            shares: Vec::new(),
            default_share: 1,
            rate_caps: Vec::new(),
            adaptive_targets: Vec::new(),
        }
    }
}

impl TenantQos {
    /// Outer-DRR share for `tenant` (the default share when no entry
    /// lists it; never zero).
    pub fn share_for(&self, tenant: &str) -> u32 {
        self.shares
            .iter()
            .find(|(t, _)| t.as_str() == tenant)
            .map(|(_, s)| *s)
            .unwrap_or(self.default_share)
            .max(1)
    }

    /// Hard rate cap for `tenant`, when one is configured.
    pub fn rate_cap_for(&self, tenant: &str) -> Option<RateCap> {
        self.rate_caps
            .iter()
            .find(|(t, _)| t.as_str() == tenant)
            .map(|(_, c)| *c)
    }

    /// Adaptive ingest p99 target override for `tenant`, modelled
    /// seconds.
    pub fn adaptive_target_for(&self, tenant: &str) -> Option<f64> {
        self.adaptive_targets
            .iter()
            .find(|(t, _)| t.as_str() == tenant)
            .map(|(_, x)| *x)
    }

    /// Builder: set `tenant`'s outer-DRR share.
    pub fn with_share(mut self, tenant: &str, share: u32) -> TenantQos {
        self.shares.retain(|(t, _)| t.as_str() != tenant);
        self.shares.push((tenant.to_string(), share.max(1)));
        self
    }

    /// Builder: hard-cap `tenant` at `bytes_per_sec` **modelled**
    /// bytes/sec with a `burst_bytes` bucket.
    pub fn with_rate_cap(
        mut self,
        tenant: &str,
        bytes_per_sec: f64,
        burst_bytes: u64,
    ) -> TenantQos {
        self.rate_caps.retain(|(t, _)| t.as_str() != tenant);
        self.rate_caps.push((
            tenant.to_string(),
            RateCap {
                bytes_per_sec: bytes_per_sec.max(1.0),
                burst_bytes: burst_bytes.max(1),
            },
        ));
        self
    }

    /// Builder: per-tenant adaptive ingest p99 target (modelled
    /// seconds).
    pub fn with_adaptive_target(
        mut self,
        tenant: &str,
        target: f64,
    ) -> TenantQos {
        self.adaptive_targets.retain(|(t, _)| t.as_str() != tenant);
        self.adaptive_targets
            .push((tenant.to_string(), target.max(1e-6)));
        self
    }
}

/// AIMD controller parameters for [`QosConfig::adaptive`]: raise the
/// Ingest DRR quantum additively while the windowed ingest p99 queue
/// wait exceeds `target_ingest_p99`, decay it multiplicatively back
/// toward the static weight when the pressure is gone.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveQos {
    /// Ingest p99 queue-wait target, **modelled** seconds (compared
    /// against wall waits scaled by the device's `time_scale`).
    pub target_ingest_p99: f64,
    /// Per-device target overrides, `(device name, modelled secs)`: a
    /// seek-bound HDD cannot hold the sub-ms bar a deep-parallel
    /// Optane can, so each device class gets its own target
    /// (`profiles::adaptive_ingest_target` carries the paper-profile
    /// presets).  Devices not listed fall back to
    /// `target_ingest_p99`.
    pub per_device: Vec<(String, f64)>,
    /// Ceiling on the effective Ingest weight.
    pub max_weight: u32,
    /// Additive weight step per hot controller tick.
    pub increase: u32,
    /// Multiplicative decay factor toward the base weight per cold
    /// tick (0.5 = halve the excess).
    pub decay: f64,
    /// Controller period, **modelled** seconds: the sliding window of
    /// ingest queue latencies is judged and reset every tick.
    pub tick: f64,
}

impl AdaptiveQos {
    /// Controller target for `device`: the per-device override when
    /// one is configured, else the global target.
    pub fn target_for(&self, device: &str) -> f64 {
        self.per_device
            .iter()
            .find(|(d, _)| d == device)
            .map(|(_, t)| *t)
            .unwrap_or(self.target_ingest_p99)
            .max(1e-6)
    }
}

/// Per-device scheduler configuration.
///
/// The default is a weighted deficit-round-robin over the four class
/// queues: class `c` is granted `weights[c] * chunk_size` bytes of
/// deficit per scheduler round, so bandwidth shares converge to the
/// weight ratio under saturation while every class keeps making
/// progress (no starvation).  `fifo: true` collapses all classes into
/// one arrival-order queue — the pre-QoS behaviour, kept as the
/// baseline the isolation tests and benches compare against.
/// Orthogonally, `rate_caps` hard-bounds a class's throughput and
/// `adaptive` lets an AIMD controller steer the Ingest quantum from
/// measured ingest queue waits.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Single arrival-order queue (the old engine): baseline mode.
    pub fifo: bool,
    /// DRR quantum multipliers, indexed by [`IoClass::index`].
    pub weights: [u32; IoClass::COUNT],
    /// A stream (checkpoint data / drain copy) re-checks for queued
    /// higher-priority work every `preempt_chunks` chunks and yields
    /// until it drains (0 disables preemption points).
    pub preempt_chunks: usize,
    /// Upper bound, **modelled** seconds, on any single preemption
    /// yield — keeps a stream live even under a persistent
    /// higher-class flood.  Divided by the device's `time_scale` at
    /// the yield point, so accelerated testbeds bound the yield at the
    /// same point in modelled time (ratio preservation).
    pub max_yield_wait: f64,
    /// Optional hard rate cap per class, indexed by
    /// [`IoClass::index`].  A class whose bucket is in debt is skipped
    /// by the scheduler round (its DRR deficit is untouched) and its
    /// streams pause at chunk boundaries, even when uncapped classes
    /// are idle — a cap is a bound, not a share.
    pub rate_caps: [Option<RateCap>; IoClass::COUNT],
    /// Feedback-driven Ingest quantum (see [`AdaptiveQos`]); `None`
    /// keeps the static `weights`.
    pub adaptive: Option<AdaptiveQos>,
    /// Hierarchical scheduling: `Some` nests the per-class DRR inside
    /// an outer DRR over tenant shares ([`TenantQos`]); `None` (the
    /// default) keeps the flat tenant-blind scheduler bit-for-bit.
    pub tenants: Option<TenantQos>,
    /// Bounded retry-with-backoff for failed unit requests (the fault
    /// seam's degraded-mode path).
    pub retry: RetryPolicy,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            fifo: false,
            weights: [8, 4, 2, 1],
            preempt_chunks: 4,
            max_yield_wait: 0.25,
            rate_caps: [None; IoClass::COUNT],
            adaptive: None,
            tenants: None,
            retry: RetryPolicy::default(),
        }
    }
}

impl QosConfig {
    /// The pre-QoS single-FIFO baseline.
    pub fn fifo() -> QosConfig {
        QosConfig { fifo: true, ..QosConfig::default() }
    }

    /// Feedback-driven mode: weighted DRR whose Ingest quantum is
    /// steered by an AIMD controller toward `target_ingest_p99`
    /// (modelled seconds) of ingest p99 queue wait.  Under a
    /// checkpoint burst the controller walks the Ingest weight up to
    /// `max_weight`; once ingest waits fall back under the target it
    /// decays toward the static weight.
    pub fn adaptive(target_ingest_p99: f64) -> QosConfig {
        QosConfig {
            adaptive: Some(AdaptiveQos {
                target_ingest_p99: target_ingest_p99.max(1e-6),
                per_device: Vec::new(),
                max_weight: 64,
                increase: 8,
                decay: 0.5,
                tick: 0.01,
            }),
            ..QosConfig::default()
        }
    }

    /// Resolve a scheduler-mode name to the config it denotes — the
    /// one name→config map shared by the sweep driver, the replayer,
    /// and the CLI (so their labels can never drift apart).
    /// `adaptive` uses `adaptive_target` modelled seconds as its
    /// global ingest p99 bar.
    pub fn parse_mode(mode: &str, adaptive_target: f64) -> Result<QosConfig> {
        match mode {
            "fifo" => Ok(QosConfig::fifo()),
            "static" => Ok(QosConfig::default()),
            "adaptive" => Ok(QosConfig::adaptive(adaptive_target)),
            other => Err(anyhow!(
                "unknown qos mode {other:?} (fifo|static|adaptive)"
            )),
        }
    }

    /// Builder: hard-cap `class` at `bytes_per_sec` **modelled**
    /// bytes/sec with a `burst_bytes` bucket.
    pub fn with_rate_cap(
        mut self,
        class: IoClass,
        bytes_per_sec: f64,
        burst_bytes: u64,
    ) -> QosConfig {
        self.rate_caps[class.index()] = Some(RateCap {
            bytes_per_sec: bytes_per_sec.max(1.0),
            burst_bytes: burst_bytes.max(1),
        });
        self
    }

    /// Builder: enable hierarchical `(tenant, class)` scheduling with
    /// per-tenant shares, caps, and adaptive targets.
    pub fn with_tenants(mut self, tenants: TenantQos) -> QosConfig {
        self.tenants = Some(tenants);
        self
    }

    /// Builder: override the bounded-retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> QosConfig {
        self.retry = retry;
        self
    }

    /// Scheduler-mode label for sweep outputs and tables.
    pub fn mode_name(&self) -> &'static str {
        if self.fifo {
            "fifo"
        } else if self.adaptive.is_some() {
            "adaptive"
        } else {
            "static"
        }
    }
}

/// Default streaming chunk: 1 MiB.
pub const DEFAULT_CHUNK: usize = 1 << 20;

/// Chunks buffered per stream (copy pipeline / streamed write): the
/// producer blocks once this many chunks are queued, bounding stream
/// memory at `chunk_size * STREAM_WINDOW` regardless of file size.
pub const STREAM_WINDOW: usize = 4;

/// Worker threads per device: one per modelled channel (Lustre's 32
/// OSTs included — fewer workers than channels would understate the
/// modelled concurrency), with a backstop cap for absurd configs.
/// Workers mostly sleep modelled service time, so they are cheap.
const MAX_WORKERS_PER_DEVICE: usize = 64;

// ---------------------------------------------------------------------------
// Public request/completion surface
// ---------------------------------------------------------------------------

/// One I/O request against a simulated device.  Paths are *backing*
/// filesystem paths (the sim resolves `device://rel` before
/// submitting).
pub enum IoRequest {
    /// Whole-file read through the device model; the completion
    /// carries the data.
    ReadFile { device: String, path: PathBuf },
    /// Whole-buffer write.
    WriteFile { device: String, path: PathBuf, data: Vec<u8> },
    /// Pacing-only read probe: service-time envelope without backing
    /// I/O (IOR, Table I).
    ProbeRead { device: String, bytes: u64 },
    /// Pacing-only write probe.
    ProbeWrite { device: String, bytes: u64 },
    /// Chunked device-to-device copy: the source read is pipelined
    /// into the destination write through a bounded chunk queue.
    Copy {
        src_device: String,
        src_path: PathBuf,
        dst_device: String,
        dst_path: PathBuf,
    },
}

impl IoRequest {
    /// Class used when the caller doesn't tag explicitly: reads are
    /// ingest traffic, writes checkpoint traffic, copies drains.
    pub fn default_class(&self) -> IoClass {
        match self {
            IoRequest::ReadFile { .. } | IoRequest::ProbeRead { .. } => {
                IoClass::Ingest
            }
            IoRequest::WriteFile { .. } | IoRequest::ProbeWrite { .. } => {
                IoClass::Checkpoint
            }
            IoRequest::Copy { .. } => IoClass::Drain,
        }
    }
}

/// What a finished request reports.
#[derive(Debug)]
pub struct IoCompletion {
    /// Bytes transferred (for a copy: bytes written to the target).
    pub bytes: u64,
    /// File contents for [`IoRequest::ReadFile`], `None` otherwise.
    pub data: Option<Vec<u8>>,
    /// Submit → service start (time spent queued).
    pub queue_secs: f64,
    /// Service start → completion.
    pub service_secs: f64,
}

struct TicketState {
    result: Option<Result<IoCompletion>>,
}

struct TicketShared {
    state: Mutex<TicketState>,
    done: SimCondvar,
    clock: Clock,
}

/// Completion handle for a submitted request.  `wait` consumes the
/// ticket and blocks until the engine fills it; `ready` polls.
pub struct IoTicket {
    inner: Arc<TicketShared>,
}

impl IoTicket {
    /// Block until the request completes.
    pub fn wait(self) -> Result<IoCompletion> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(r) = st.result.take() {
                return r;
            }
            st = self.inner.done.wait(
                &self.inner.clock,
                &self.inner.state,
                st,
            );
        }
    }

    /// Non-blocking completion check.
    pub fn ready(&self) -> bool {
        self.inner.state.lock().unwrap().result.is_some()
    }
}

fn new_ticket(clock: &Clock) -> (IoTicket, Arc<TicketShared>) {
    let shared = Arc::new(TicketShared {
        state: Mutex::new(TicketState { result: None }),
        done: SimCondvar::new(),
        clock: clock.clone(),
    });
    (IoTicket { inner: Arc::clone(&shared) }, shared)
}

fn complete(ticket: &Arc<TicketShared>, result: Result<IoCompletion>) {
    let mut st = ticket.state.lock().unwrap();
    st.result = Some(result);
    drop(st);
    ticket.done.notify_all(&ticket.clock);
}

// ---------------------------------------------------------------------------
// Request-level event stream (the trace subsystem's hook)
// ---------------------------------------------------------------------------

/// What kind of engine request a completion event describes.  A copy
/// surfaces as two events — its paced read half ([`CopyRead`]) on the
/// source device and its streamed write half ([`StreamWrite`]) on the
/// destination — because that is how the engine schedules (and
/// charges) it.
///
/// [`CopyRead`]: EngineOp::CopyRead
/// [`StreamWrite`]: EngineOp::StreamWrite
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineOp {
    /// Whole-file read.
    Read,
    /// Whole-buffer write.
    Write,
    /// Pacing-only read probe.
    ProbeRead,
    /// Pacing-only write probe.
    ProbeWrite,
    /// Read half of a device-to-device copy.
    CopyRead,
    /// Streamed chunked write (saver `.data`, copy/warm-copy
    /// destination).
    StreamWrite,
}

impl EngineOp {
    pub const ALL: [EngineOp; 6] = [
        EngineOp::Read,
        EngineOp::Write,
        EngineOp::ProbeRead,
        EngineOp::ProbeWrite,
        EngineOp::CopyRead,
        EngineOp::StreamWrite,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EngineOp::Read => "read",
            EngineOp::Write => "write",
            EngineOp::ProbeRead => "probe_read",
            EngineOp::ProbeWrite => "probe_write",
            EngineOp::CopyRead => "copy_read",
            EngineOp::StreamWrite => "stream_write",
        }
    }

    /// Inverse of [`name`](Self::name) (trace files carry op names).
    pub fn parse(s: &str) -> Option<EngineOp> {
        EngineOp::ALL.into_iter().find(|o| o.name() == s)
    }

    /// Transfer direction of the op (what a replayer probes as).
    pub fn dir(self) -> Dir {
        match self {
            EngineOp::Read | EngineOp::ProbeRead | EngineOp::CopyRead => {
                Dir::Read
            }
            EngineOp::Write | EngineOp::ProbeWrite | EngineOp::StreamWrite => {
                Dir::Write
            }
        }
    }
}

impl std::fmt::Display for EngineOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One completed engine request, as handed to an [`EngineObserver`]:
/// the tf-Darshan-style per-request record (who, what, how many bytes,
/// and the full submit → dispatch → complete timing split).
#[derive(Debug, Clone)]
pub struct EngineEvent {
    pub device: String,
    pub class: IoClass,
    pub op: EngineOp,
    /// Submitter tag (see [`with_origin`]): which subsystem issued the
    /// request (`"sharded-reader"`, `"saver"`, `"bb-drain"`, ...).
    /// Empty when the submitter didn't tag.
    pub origin: &'static str,
    /// Storage-hierarchy tier the submitter accounted this request to
    /// (see [`with_tier`]); `None` when the request didn't flow
    /// through a [`StorageHierarchy`](super::hierarchy::StorageHierarchy).
    /// For migration copies both halves carry the *destination* tier
    /// (the tier being drained/promoted into).
    pub tier: Option<u32>,
    /// Tenant the submitter tagged this request with (see
    /// [`with_tenant`]); the default tenant when the submitter didn't
    /// tag.
    pub tenant: TenantId,
    /// Bytes transferred.  On failure: for unit requests, the bytes
    /// the request intended to move (its DRR cost), so a replay
    /// offers the same load; failed streams report 0 (the transferred
    /// total is lost with the failure) — `ok: false` flags the event
    /// either way.
    pub bytes: u64,
    pub ok: bool,
    /// Submit time, engine-clock seconds since the engine started
    /// (wall seconds under `WallClock`, virtual seconds under
    /// `VirtualClock` — same meaning, same schema).
    pub submit_secs: f64,
    /// Submit → service start (dispatch), engine-clock seconds.
    pub queue_secs: f64,
    /// Service start → completion, engine-clock seconds.
    pub service_secs: f64,
}

impl EngineEvent {
    /// Completion time on the engine's clock, seconds.
    pub fn complete_secs(&self) -> f64 {
        self.submit_secs + self.queue_secs + self.service_secs
    }
}

/// Request-level completion observer ([`IoEngine::set_observer`]).
/// Called once per finished request, on the completing thread, before
/// the ticket resolves — a caller that waited a ticket is guaranteed
/// the event was already delivered.
pub trait EngineObserver: Send + Sync {
    fn record(&self, event: EngineEvent);
}

thread_local! {
    /// Origin tag for engine submissions made on this thread.
    static ORIGIN: std::cell::Cell<&'static str> =
        const { std::cell::Cell::new("") };
    /// Hierarchy tier tag for engine submissions made on this thread
    /// (`-1` = untiered).
    static TIER: std::cell::Cell<i64> = const { std::cell::Cell::new(-1) };
    /// Tenant tag for engine submissions made on this thread (`None`
    /// = the default tenant).
    static TENANT: std::cell::RefCell<Option<TenantId>> =
        const { std::cell::RefCell::new(None) };
}

/// Tag every engine submission made inside `f` (on the calling thread)
/// with `origin`, so trace events can attribute requests to the
/// subsystem that issued them.  Nested scopes restore the outer tag.
pub fn with_origin<T>(origin: &'static str, f: impl FnOnce() -> T) -> T {
    ORIGIN.with(|o| {
        let prev = o.replace(origin);
        let out = f();
        o.set(prev);
        out
    })
}

fn current_origin() -> &'static str {
    ORIGIN.with(|o| o.get())
}

/// Tag every engine submission made inside `f` (on the calling
/// thread) with a storage-hierarchy tier id, so trace events and the
/// per-tier stats rows can attribute requests to the tier the
/// hierarchy accounted them to.  Nested scopes restore the outer tag.
pub fn with_tier<T>(tier: u32, f: impl FnOnce() -> T) -> T {
    TIER.with(|t| {
        let prev = t.replace(tier as i64);
        let out = f();
        t.set(prev);
        out
    })
}

fn current_tier() -> Option<u32> {
    TIER.with(|t| {
        let v = t.get();
        if v < 0 { None } else { Some(v as u32) }
    })
}

/// Tag every engine submission made inside `f` (on the calling
/// thread) with `tenant` — the outer key of the hierarchical
/// scheduler.  Rides the same thread-scoped seam as [`with_origin`]
/// and [`with_tier`]; nested scopes restore the outer tag.
pub fn with_tenant<T>(tenant: &TenantId, f: impl FnOnce() -> T) -> T {
    TENANT.with(|t| {
        let prev = t.replace(Some(tenant.clone()));
        let out = f();
        t.replace(prev);
        out
    })
}

fn current_tenant() -> TenantId {
    TENANT.with(|t| t.borrow().clone().unwrap_or_default())
}

/// The engine-wide observer slot: attached/cleared at runtime, read
/// (uncontended) on every completion.
type ObserverSlot = Arc<RwLock<Option<Arc<dyn EngineObserver>>>>;

// ---------------------------------------------------------------------------
// Stream buffer gauge
// ---------------------------------------------------------------------------

/// Engine-wide gauge of bytes sitting in stream chunk queues; `peak`
/// is what the bounded-memory acceptance bench asserts on.
struct BufferGauge {
    current: AtomicU64,
    peak: AtomicU64,
}

impl BufferGauge {
    fn new() -> BufferGauge {
        BufferGauge { current: AtomicU64::new(0), peak: AtomicU64::new(0) }
    }

    fn add(&self, n: u64) {
        let now = self.current.fetch_add(n, Ordering::SeqCst) + n;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    fn sub(&self, n: u64) {
        self.current.fetch_sub(n, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Bounded chunk queue (stream producer -> device worker)
// ---------------------------------------------------------------------------

/// A failed stream, tagged with whether some stats counter already
/// charged the error (`counted: true` -> the paced producer recorded
/// it against *its* device; the consumer must fail the ticket without
/// double-counting).  This is what makes `EngineDeviceStats::errors`
/// exactly-once across the read and write halves of a copy.
struct StreamFailure {
    error: anyhow::Error,
    counted: bool,
}

impl StreamFailure {
    fn new(error: anyhow::Error, counted: bool) -> StreamFailure {
        StreamFailure { error, counted }
    }

    fn context(self, msg: &'static str) -> StreamFailure {
        StreamFailure { error: self.error.context(msg), counted: self.counted }
    }
}

enum StreamChunk {
    Data(Vec<u8>),
    Fail(StreamFailure),
}

struct ChunkQueueState {
    chunks: VecDeque<StreamChunk>,
    /// Producer finished successfully.
    closed: bool,
    /// Consumer gave up (write error / shutdown): producers must stop.
    aborted: bool,
    /// An abort threw away queued chunks, so a `closed` queue can no
    /// longer be treated as fully delivered.
    discarded: bool,
}

struct ChunkQueue {
    state: Mutex<ChunkQueueState>,
    /// Producer waits here for space.
    space: SimCondvar,
    /// Consumer waits here for chunks.
    filled: SimCondvar,
    capacity: usize,
    gauge: Arc<BufferGauge>,
    clock: Clock,
}

impl ChunkQueue {
    fn new(capacity: usize, gauge: Arc<BufferGauge>, clock: Clock) -> ChunkQueue {
        ChunkQueue {
            state: Mutex::new(ChunkQueueState {
                chunks: VecDeque::new(),
                closed: false,
                aborted: false,
                discarded: false,
            }),
            space: SimCondvar::new(),
            filled: SimCondvar::new(),
            capacity: capacity.max(1),
            gauge,
            clock,
        }
    }

    /// Enqueue a chunk (blocking on a full queue).  Returns `false`
    /// when the consumer aborted — the producer should stop.
    fn push(&self, chunk: StreamChunk) -> bool {
        let bytes = match &chunk {
            StreamChunk::Data(c) => c.len() as u64,
            StreamChunk::Fail(_) => 0,
        };
        let mut st = self.state.lock().unwrap();
        while st.chunks.len() >= self.capacity && !st.aborted {
            st = self.space.wait(&self.clock, &self.state, st);
        }
        if st.aborted {
            return false;
        }
        // Gauge add strictly before the chunk becomes poppable, so the
        // matching sub can never race it below zero.
        self.gauge.add(bytes);
        st.chunks.push_back(chunk);
        drop(st);
        self.filled.notify_one(&self.clock);
        true
    }

    fn push_data(&self, chunk: Vec<u8>) -> bool {
        self.push(StreamChunk::Data(chunk))
    }

    /// Fail the stream; `counted` = the producer already charged this
    /// error to its own device's stats.
    fn push_fail(&self, error: anyhow::Error, counted: bool) -> bool {
        self.push(StreamChunk::Fail(StreamFailure::new(error, counted)))
    }

    /// Producer-side end-of-stream marker.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.filled.notify_all(&self.clock);
    }

    /// Dequeue the next chunk; `None` = producer closed and queue
    /// drained; `Some(Err)` if the stream was aborted (engine
    /// shutdown) so the consumer fails the ticket instead of
    /// reporting a truncated success.
    fn pop(&self) -> Option<Result<Vec<u8>, StreamFailure>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(c) = st.chunks.pop_front() {
                drop(st);
                self.space.notify_one(&self.clock);
                return match c {
                    StreamChunk::Data(bytes) => {
                        self.gauge.sub(bytes.len() as u64);
                        Some(Ok(bytes))
                    }
                    StreamChunk::Fail(f) => Some(Err(f)),
                };
            }
            if st.closed && !st.discarded {
                // Producer finished and everything was delivered:
                // success, even if a shutdown abort landed afterwards.
                return None;
            }
            if st.aborted {
                // Discarded chunks always imply an abort, so this
                // also covers closed-but-truncated streams.
                return Some(Err(StreamFailure::new(
                    anyhow!("stream aborted (engine shutdown)"),
                    false,
                )));
            }
            st = self.filled.wait(&self.clock, &self.state, st);
        }
    }

    /// Consumer-side abort: discard queued chunks and unblock the
    /// producer.
    fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.aborted = true;
        if !st.chunks.is_empty() {
            st.discarded = true;
        }
        let mut freed = 0u64;
        for c in st.chunks.drain(..) {
            if let StreamChunk::Data(bytes) = c {
                freed += bytes.len() as u64;
            }
        }
        drop(st);
        if freed > 0 {
            self.gauge.sub(freed);
        }
        self.space.notify_all(&self.clock);
        self.filled.notify_all(&self.clock);
    }
}

/// Producer handle for a streamed write (`IoEngine::write_stream`).
/// Bytes are buffered into engine-sized chunks and enqueued toward the
/// device worker; `push` blocks once [`STREAM_WINDOW`] chunks are
/// pending, which is the backpressure that bounds memory.
pub struct ChunkWriter {
    queue: Arc<ChunkQueue>,
    chunk_size: usize,
    pending: Vec<u8>,
    finished: bool,
}

impl ChunkWriter {
    /// Append bytes to the stream.
    pub fn push(&mut self, mut bytes: &[u8]) -> Result<()> {
        while !bytes.is_empty() {
            let room = self.chunk_size - self.pending.len();
            let take = room.min(bytes.len());
            self.pending.extend_from_slice(&bytes[..take]);
            bytes = &bytes[take..];
            if self.pending.len() == self.chunk_size {
                self.flush_pending()?;
            }
        }
        Ok(())
    }

    fn flush_pending(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let chunk =
            std::mem::replace(&mut self.pending, Vec::with_capacity(self.chunk_size));
        if !self.queue.push_data(chunk) {
            return Err(anyhow!(
                "stream write aborted by the device worker \
                 (see the ticket for the underlying error)"
            ));
        }
        Ok(())
    }

    /// Flush the tail chunk and mark end-of-stream.  The write is
    /// complete once the associated ticket resolves.
    pub fn finish(mut self) -> Result<()> {
        self.flush_pending()?;
        self.finished = true;
        self.queue.close();
        Ok(())
    }
}

impl Drop for ChunkWriter {
    fn drop(&mut self) {
        if !self.finished {
            // Dropped without finish(): poison the stream so the
            // worker fails the ticket instead of persisting a
            // truncated file as success.
            self.queue
                .push_fail(anyhow!("stream writer dropped mid-write"), false);
            self.queue.close();
        }
    }
}

// ---------------------------------------------------------------------------
// Per-device queue + stats
// ---------------------------------------------------------------------------

/// Per-class aggregates for one device (the tf-Darshan-style
/// per-queue surface: depth, queue/service time, bytes, tail
/// latency).
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    /// Failed attempts that were re-run under the bounded retry
    /// policy ([`RetryPolicy`]).  A request retried twice then
    /// succeeding contributes `retries: 2, errors: 0`; one exhausting
    /// its budget contributes `retries: budget, errors: 1` — errors
    /// stay exactly-once per finally-failed request.
    pub retries: u64,
    /// Total submit → service-start seconds across requests.
    pub queue_secs: f64,
    /// Total service seconds across requests.
    pub service_secs: f64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Deepest scheduler queue this class ever reached (requests
    /// submitted but not yet picked by a worker).
    pub max_queue_depth: u32,
    /// Queue-latency distribution (log2 buckets) — p99 comes from
    /// here.
    pub queue_hist: LatencyHistogram,
}

impl ClassStats {
    pub fn mean_queue_secs(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_secs / self.completed as f64
        }
    }

    pub fn mean_service_secs(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.service_secs / self.completed as f64
        }
    }

    /// p99 queue latency, seconds (conservative bucket upper bound).
    pub fn p99_queue_secs(&self) -> f64 {
        self.queue_hist.p99()
    }
}

/// Per-tier request aggregates for one device: which hierarchy tier
/// the completed requests were accounted to (see [`with_tier`]).
/// Devices serving untiered traffic have no rows here.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TierIoStats {
    pub tier: u32,
    pub completed: u64,
    pub errors: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

/// Per-tenant request aggregates for one device, with the same
/// per-class breakdown (queue-latency histograms included) the
/// device-level stats carry — the `tenant x class` surface
/// `--engine-stats` prints for fleet runs.  Untagged (default-tenant)
/// traffic has no row here, so single-tenant output is unchanged.
#[derive(Debug, Clone, Default)]
pub struct TenantIoStats {
    pub tenant: String,
    pub completed: u64,
    pub errors: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Per-class breakdown, indexed by [`IoClass::index`].
    pub classes: [ClassStats; IoClass::COUNT],
}

/// Per-request aggregates for one device (snapshot via
/// [`IoEngine::stats`]), with a per-[`IoClass`] breakdown.
#[derive(Debug, Clone, Default)]
pub struct EngineDeviceStats {
    pub device: String,
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    /// Failed attempts re-run under the retry policy (see
    /// [`ClassStats::retries`] for the exactly-once error contract).
    pub retries: u64,
    /// Total submit → service-start seconds across requests.
    pub queue_secs: f64,
    /// Total service seconds across requests.
    pub service_secs: f64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Deepest device queue observed — sampled at submit time *and*
    /// folded with the device's own entry-side peak gauge, so bursts
    /// that drain between submits (stream chunks, copy read halves)
    /// are never under-reported.
    pub max_queue_depth: u32,
    /// Per-class breakdown, indexed by [`IoClass::index`].
    pub classes: [ClassStats; IoClass::COUNT],
    /// Per-hierarchy-tier breakdown (sorted by tier id); empty when
    /// no request on this device carried a tier tag.
    pub tiers: Vec<TierIoStats>,
    /// Per-tenant breakdown (sorted by tenant name); empty when no
    /// request on this device carried a tenant tag.
    pub tenants: Vec<TenantIoStats>,
    /// Effective Ingest DRR weight in force when the snapshot was
    /// taken (the static weight unless [`QosConfig::adaptive`] is on).
    pub ingest_weight: u32,
    /// AIMD controller trajectory: `(secs since engine start, new
    /// ingest weight)` per weight change, capped at
    /// [`MAX_WEIGHT_TRAJECTORY`] points.  Empty when the controller is
    /// off.
    pub weight_trajectory: Vec<(f64, u32)>,
}

/// Retained weight-change points per device (a run long enough to
/// exceed this keeps the earliest changes, which contain the
/// adaptation story).
pub const MAX_WEIGHT_TRAJECTORY: usize = 4096;

impl EngineDeviceStats {
    /// Mean queue wait per completed request, seconds.
    pub fn mean_queue_secs(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_secs / self.completed as f64
        }
    }

    /// Mean service time per completed request, seconds.
    pub fn mean_service_secs(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.service_secs / self.completed as f64
        }
    }

    /// Stats row for one class.
    pub fn class(&self, class: IoClass) -> &ClassStats {
        &self.classes[class.index()]
    }

    /// Stats row for one hierarchy tier (`None` when the device never
    /// served requests tagged with that tier).
    pub fn tier(&self, tier: u32) -> Option<&TierIoStats> {
        self.tiers.iter().find(|t| t.tier == tier)
    }

    /// Stats row for one tenant (`None` when the device never served
    /// requests tagged with that tenant).
    pub fn tenant(&self, name: &str) -> Option<&TenantIoStats> {
        self.tenants.iter().find(|t| t.tenant == name)
    }
}

/// Submit-side accounting (aggregate + class), shared by every submit
/// path so no request can enter a queue untracked.
fn record_submit(stats: &mut EngineDeviceStats, class: IoClass, enq_depth: u32) {
    stats.submitted += 1;
    if enq_depth > stats.max_queue_depth {
        stats.max_queue_depth = enq_depth;
    }
    stats.classes[class.index()].submitted += 1;
}

/// Completion-side accounting.  `ok` carries (bytes, direction) on
/// success; on failure `count_error` is false when the error was
/// already charged elsewhere (the copy read half), keeping `errors`
/// exactly-once per failed request.
#[allow(clippy::too_many_arguments)]
fn record_done(
    stats: &mut EngineDeviceStats,
    class: IoClass,
    tier: Option<u32>,
    tenant: &TenantId,
    queue_secs: f64,
    service_secs: f64,
    ok: Option<(u64, Dir)>,
    count_error: bool,
) {
    stats.completed += 1;
    stats.queue_secs += queue_secs;
    stats.service_secs += service_secs;
    // Tier row (find-or-insert, kept sorted by tier id): the
    // per-tier surface `--engine-stats` prints for hierarchy runs.
    let ts = tier.map(|id| {
        match stats.tiers.binary_search_by_key(&id, |t| t.tier) {
            Ok(at) => at,
            Err(at) => {
                stats.tiers.insert(
                    at,
                    TierIoStats { tier: id, ..TierIoStats::default() },
                );
                at
            }
        }
    });
    if let Some(at) = ts {
        stats.tiers[at].completed += 1;
    }
    let cs = &mut stats.classes[class.index()];
    cs.completed += 1;
    cs.queue_secs += queue_secs;
    cs.service_secs += service_secs;
    cs.queue_hist.record(queue_secs);
    match ok {
        Some((bytes, Dir::Read)) => {
            stats.bytes_read += bytes;
            cs.bytes_read += bytes;
            if let Some(at) = ts {
                stats.tiers[at].bytes_read += bytes;
            }
        }
        Some((bytes, Dir::Write)) => {
            stats.bytes_written += bytes;
            cs.bytes_written += bytes;
            if let Some(at) = ts {
                stats.tiers[at].bytes_written += bytes;
            }
        }
        None => {
            if count_error {
                stats.errors += 1;
                cs.errors += 1;
                if let Some(at) = ts {
                    stats.tiers[at].errors += 1;
                }
            }
        }
    }
    // Tenant row (find-or-insert, kept sorted by name): the
    // tenant x class surface fleet runs report from.  Default-tenant
    // traffic stays off this ledger, keeping single-tenant output
    // byte-identical.
    if !tenant.is_default() {
        let at = match stats
            .tenants
            .binary_search_by(|t| t.tenant.as_str().cmp(tenant.as_str()))
        {
            Ok(at) => at,
            Err(at) => {
                stats.tenants.insert(
                    at,
                    TenantIoStats {
                        tenant: tenant.as_str().to_string(),
                        ..TenantIoStats::default()
                    },
                );
                at
            }
        };
        let row = &mut stats.tenants[at];
        row.completed += 1;
        let tc = &mut row.classes[class.index()];
        tc.completed += 1;
        tc.queue_secs += queue_secs;
        tc.service_secs += service_secs;
        tc.queue_hist.record(queue_secs);
        match ok {
            Some((bytes, Dir::Read)) => {
                row.bytes_read += bytes;
                row.classes[class.index()].bytes_read += bytes;
            }
            Some((bytes, Dir::Write)) => {
                row.bytes_written += bytes;
                row.classes[class.index()].bytes_written += bytes;
            }
            None => {
                if count_error {
                    row.errors += 1;
                    row.classes[class.index()].errors += 1;
                }
            }
        }
    }
}

/// One retried attempt's accounting (device + class rows): kept next
/// to [`record_done`] so the retry/error split stays in one place.
fn record_retry(stats: &mut EngineDeviceStats, class: IoClass) {
    stats.retries += 1;
    stats.classes[class.index()].retries += 1;
}

enum JobOp {
    Read { path: PathBuf },
    Write { path: PathBuf, data: Vec<u8> },
    Probe { dir: Dir, bytes: u64 },
}

struct Job {
    op: JobOp,
    class: IoClass,
    /// DRR cost, bytes (known payload size, or the chunk size for
    /// reads whose backing file can't be statted).
    cost: u64,
    /// Arrival order across all classes (the FIFO-baseline sort key).
    seq: u64,
    ticket: Arc<TicketShared>,
    /// Engine-clock submit time, seconds since the engine started.
    submitted: f64,
    /// Submitter tag for trace events (see [`with_origin`]).
    origin: &'static str,
    /// Hierarchy tier tag for trace events and per-tier stats rows
    /// (see [`with_tier`]).
    tier: Option<u32>,
    /// Tenant tag (see [`with_tenant`]): the outer scheduling key.
    tenant: TenantId,
    /// Queue depth when this request joined the device queue (0 for
    /// streams, which enter per chunk): the elevator gain floor for
    /// co-queued bursts.
    enq_depth: u32,
}

impl JobOp {
    /// The event-stream kind of this job.
    fn engine_op(&self) -> EngineOp {
        match self {
            JobOp::Read { .. } => EngineOp::Read,
            JobOp::Write { .. } => EngineOp::Write,
            JobOp::Probe { dir: Dir::Read, .. } => EngineOp::ProbeRead,
            JobOp::Probe { dir: Dir::Write, .. } => EngineOp::ProbeWrite,
        }
    }
}

/// One tenant's scheduling slot: the inner per-class DRR (the old
/// flat scheduler, one tenant deep) plus the outer round's share
/// deficit.  Slots are created on first submission and never removed
/// (an idle tenant's slot is skipped with zero cost).
struct TenantSlot {
    tenant: TenantId,
    /// Outer-DRR share weight ([`TenantQos::share_for`]); 1 for the
    /// default tenant of a tenant-blind engine.
    share: u32,
    /// Outer DRR byte deficit (unused while the engine has a single
    /// slot — the flat fast path).
    tenant_deficit: u64,
    /// One queue per class, indexed by [`IoClass::index`].
    classes: [VecDeque<Job>; IoClass::COUNT],
    /// Inner DRR byte deficits per class.
    deficit: [u64; IoClass::COUNT],
    /// Class the inner scheduler is currently visiting.
    cursor: usize,
    /// Whether the cursor class already received its quantum for the
    /// current inner visit.
    visit_granted: bool,
    /// Effective Ingest weight for this tenant (steered by its AIMD
    /// controller instance; the static base weight otherwise).
    eff_weight: u32,
    /// Jobs queued across this slot's class queues.
    queued: usize,
    /// Scratch: tenant rate bucket in debt (snapshotted once per
    /// `sched_pop` call, like the per-class eligibility array).
    bucket_dry: bool,
}

impl TenantSlot {
    fn new(tenant: TenantId, share: u32, eff_weight: u32) -> TenantSlot {
        TenantSlot {
            tenant,
            share,
            tenant_deficit: 0,
            classes: std::array::from_fn(|_| VecDeque::new()),
            deficit: [0; IoClass::COUNT],
            cursor: 0,
            visit_granted: false,
            eff_weight,
            queued: 0,
            bucket_dry: false,
        }
    }
}

struct QueueState {
    /// One slot per tenant seen on this device.  Slot 0 is always the
    /// default tenant, pre-created at engine construction, so a
    /// tenant-blind config (`qos.tenants: None`) routes every job to
    /// slot 0 and the scheduler degenerates to the flat per-class
    /// DRR.
    slots: Vec<TenantSlot>,
    /// Outer DRR cursor over `slots`.
    tcursor: usize,
    /// Whether the cursor slot already received its tenant quantum
    /// for the current outer visit.
    tenant_granted: bool,
    /// Total jobs across all slots.
    queued: usize,
    /// Arrival counter feeding `Job::seq`.
    next_seq: u64,
    /// Streams (chunked writes / copy read halves) currently live per
    /// class: they occupy the device without sitting in a scheduler
    /// queue, but the per-class depth gauge must still see them.
    class_live: [u32; IoClass::COUNT],
    /// Deepest each class has been (queued jobs across slots + live
    /// streams).
    class_peak: [u32; IoClass::COUNT],
    shutdown: bool,
}

/// Sliding-window state for the AIMD weight controller (one per
/// tenant per device when [`QosConfig::adaptive`] is on).
struct AdaptiveState {
    /// Effective Ingest weight, kept as f64 so the multiplicative
    /// decay converges smoothly.
    weight: f64,
    /// Ingest queue latencies observed since the last tick.
    window: LatencyHistogram,
    /// Engine-clock time of the last controller tick, seconds.
    last_tick: f64,
    trajectory: Vec<(f64, u32)>,
}

/// One tenant's AIMD controller instance.  Tenant-blind engines keep
/// exactly one (the default tenant's, pre-created at construction);
/// tenant-aware engines grow one per tenant on first completion.
struct AdaptiveSlot {
    tenant: TenantId,
    /// Resolved ingest p99 target for this tenant on this device,
    /// modelled seconds (per-tenant override, else the device's
    /// global target).
    target: f64,
    state: AdaptiveState,
}

/// What the scheduler hands a worker.
enum Sched {
    Job(Job),
    /// Work is queued, but every queued class's rate bucket is in
    /// debt: re-poll once the earliest bucket turns positive.
    Throttled(Duration),
    /// Nothing queued.
    Idle,
}

struct DeviceQueue {
    device: Arc<Device>,
    state: Mutex<QueueState>,
    /// Workers wait here for jobs.
    available: SimCondvar,
    /// Yielded streams wait here for higher-priority queues to drain.
    drained: SimCondvar,
    /// Rate-capped streams wait here while their bucket is in debt
    /// (woken by shutdown; buckets otherwise turn positive on a clock
    /// deadline).  Separate from `available` so a bucket wakeup can
    /// never be stolen by an idle worker (or vice versa).
    throttled: SimCondvar,
    stats: Mutex<EngineDeviceStats>,
    qos: QosConfig,
    /// Per-round DRR byte grants (`weights[c] * chunk_size`).
    quanta: [u64; IoClass::COUNT],
    /// Streaming chunk size (the adaptive quantum is computed from it
    /// on the fly).
    chunk_size: usize,
    /// Per-class rate-cap buckets (wall rates: modelled cap *
    /// time_scale), present only for capped classes.
    buckets: [Option<TokenBucket>; IoClass::COUNT],
    /// Per-tenant rate-cap buckets (same wall-rate semantics), one
    /// entry per tenant listed in [`TenantQos::rate_caps`].
    tenant_buckets: Vec<(TenantId, TokenBucket)>,
    /// AIMD controller instances (one per tenant); `None` when
    /// `qos.adaptive` is off.
    adaptive: Option<Mutex<Vec<AdaptiveSlot>>>,
    /// Resolved controller target for THIS device, modelled seconds
    /// ([`AdaptiveQos::target_for`]); 0 when the controller is off.
    adaptive_target: f64,
    /// Cached effective Ingest weight so the scheduler reads it
    /// without touching the controller mutex.
    eff_ingest_weight: AtomicU32,
    /// Engine construction time on the engine clock (shared across
    /// the engine's devices so event timestamps are one clock): the
    /// trajectory's time axis.
    started: f64,
    /// The engine's time source (wall or virtual), shared with every
    /// device.
    clock: Clock,
    /// Request-level event observer (trace recorder), engine-wide.
    observer: ObserverSlot,
}

impl DeviceQueue {
    /// Deliver a request-level completion event to the attached
    /// observer (no-op without one — one uncontended read-lock on the
    /// hot path).  Called before the ticket resolves, so a caller that
    /// waited the ticket has the event too.
    #[allow(clippy::too_many_arguments)]
    fn emit(
        &self,
        class: IoClass,
        op: EngineOp,
        origin: &'static str,
        tier: Option<u32>,
        tenant: &TenantId,
        bytes: u64,
        ok: bool,
        submitted: f64,
        queue_secs: f64,
        service_secs: f64,
    ) {
        let obs = self.observer.read().unwrap().clone();
        if let Some(obs) = obs {
            obs.record(EngineEvent {
                device: self.device.name().to_string(),
                class,
                op,
                origin,
                tier,
                tenant: tenant.clone(),
                bytes,
                ok,
                submit_secs: (submitted - self.started).max(0.0),
                queue_secs,
                service_secs,
            });
        }
    }

    /// Rate bucket for `tenant`, when [`TenantQos::rate_caps`] lists
    /// one.
    fn tenant_bucket(&self, tenant: &TenantId) -> Option<&TokenBucket> {
        self.tenant_buckets
            .iter()
            .find(|(t, _)| t == tenant)
            .map(|(_, b)| b)
    }

    /// Scheduling slot for `tenant`, creating it on first sight.
    /// Tenant-blind engines route everything to slot 0 (the default
    /// slot) without a lookup.
    fn slot_index(&self, st: &mut QueueState, tenant: &TenantId) -> usize {
        let Some(tq) = &self.qos.tenants else {
            return 0;
        };
        if let Some(at) = st.slots.iter().position(|s| &s.tenant == tenant) {
            return at;
        }
        // Appending never invalidates the outer cursor (slots are
        // never removed; an idle slot costs one skip per round).
        st.slots.push(TenantSlot::new(
            tenant.clone(),
            tq.share_for(tenant.as_str()),
            self.qos.weights[IoClass::Ingest.index()].max(1),
        ));
        st.slots.len() - 1
    }

    /// Scheduler queue depth of class `c` (queued jobs across every
    /// tenant slot + live streams).
    fn class_depth(st: &QueueState, c: usize) -> u32 {
        st.slots
            .iter()
            .map(|s| s.classes[c].len() as u32)
            .sum::<u32>()
            + st.class_live[c]
    }

    fn push(&self, mut job: Job) {
        {
            let mut st = self.state.lock().unwrap();
            job.seq = st.next_seq;
            st.next_seq += 1;
            let c = job.class.index();
            let si = self.slot_index(&mut st, &job.tenant);
            let slot = &mut st.slots[si];
            slot.classes[c].push_back(job);
            slot.queued += 1;
            st.queued += 1;
            let depth = Self::class_depth(&st, c);
            if depth > st.class_peak[c] {
                st.class_peak[c] = depth;
            }
        }
        self.available.notify_one(&self.clock);
    }

    /// A stream joined `class` (called at submit time; balanced by
    /// [`stream_end`](Self::stream_end) when its thread finishes).
    fn stream_begin(&self, class: IoClass) {
        let mut st = self.state.lock().unwrap();
        let c = class.index();
        st.class_live[c] += 1;
        let depth = Self::class_depth(&st, c);
        if depth > st.class_peak[c] {
            st.class_peak[c] = depth;
        }
    }

    fn stream_end(&self, class: IoClass) {
        let mut st = self.state.lock().unwrap();
        st.class_live[class.index()] -= 1;
    }

    /// Inner DRR byte grant for one visit to class `c` of `slot`:
    /// static `quanta` unless the adaptive controller steers the
    /// slot's Ingest quantum (each tenant has its own effective
    /// weight).
    fn quantum(&self, slot: &TenantSlot, c: usize) -> u64 {
        if c == IoClass::Ingest.index() && self.adaptive.is_some() {
            slot.eff_weight.max(1) as u64 * self.chunk_size as u64
        } else {
            self.quanta[c]
        }
    }

    /// Charge a dispatched job's cost to its class bucket and its
    /// tenant's bucket (debt mode: dispatch now, pay in full).
    fn charge_buckets(&self, c: usize, job: &Job) {
        if let Some(b) = &self.buckets[c] {
            b.charge(job.cost);
        }
        if let Some(b) = self.tenant_bucket(&job.tenant) {
            b.charge(job.cost);
        }
    }

    /// Pick the next job.  FIFO mode: global arrival order across
    /// every (tenant, class) queue.  DRR mode: an outer
    /// deficit-round-robin over tenant slots (each outer visit grants
    /// `share * chunk_size` bytes) nests the inner per-class DRR
    /// (each inner visit grants one class quantum; head jobs are
    /// served while both deficits cover them).  Deficits carry over,
    /// so every tenant and every class always progresses; with a
    /// single slot (tenant-blind config) the outer layer is bypassed
    /// entirely and the schedule is the flat per-class DRR.
    ///
    /// A class or tenant whose rate-cap bucket is in debt is skipped
    /// without a grant (its deficits carry over) and without stalling
    /// the round.  Only when *every* queued (tenant, class) pair is
    /// throttled does the worker back off, until the earliest bucket
    /// turns positive.  After shutdown the caps are ignored: the
    /// backlog drains so no ticket can hang.
    fn sched_pop(&self, st: &mut QueueState) -> Sched {
        if st.queued == 0 {
            return Sched::Idle;
        }
        // Snapshot bucket eligibility once per call (the same
        // staleness semantics the flat scheduler had): a dry class
        // bucket blocks that class in every slot; a dry tenant bucket
        // blocks its slot.
        let mut class_dry = [false; IoClass::COUNT];
        if !st.shutdown {
            for (c, bucket) in self.buckets.iter().enumerate() {
                if let Some(b) = bucket {
                    if b.balance() <= 0.0 {
                        class_dry[c] = true;
                    }
                }
            }
            for slot in st.slots.iter_mut() {
                slot.bucket_dry = slot.queued > 0
                    && self
                        .tenant_bucket(&slot.tenant)
                        .map(|b| b.balance() <= 0.0)
                        .unwrap_or(false);
            }
        } else {
            for slot in st.slots.iter_mut() {
                slot.bucket_dry = false;
            }
        }
        let any_eligible = st.slots.iter().any(|slot| {
            !slot.bucket_dry
                && slot
                    .classes
                    .iter()
                    .enumerate()
                    .any(|(c, q)| !q.is_empty() && !class_dry[c])
        });
        if !any_eligible {
            // Every queued (tenant, class) pair is bucket-throttled:
            // back off until the earliest *blocking* bucket turns
            // positive (a positive bucket never contributes a zero
            // wait here).
            let mut wait: Option<Duration> = None;
            let mut fold = |w: Duration| {
                wait = Some(wait.map_or(w, |x| x.min(w)));
            };
            for slot in st.slots.iter() {
                if slot.queued == 0 {
                    continue;
                }
                if slot.bucket_dry {
                    if let Some(b) = self.tenant_bucket(&slot.tenant) {
                        fold(b.until_positive());
                    }
                }
                for (c, q) in slot.classes.iter().enumerate() {
                    if q.is_empty() || !class_dry[c] {
                        continue;
                    }
                    if let Some(b) = &self.buckets[c] {
                        fold(b.until_positive());
                    }
                }
            }
            let wait = wait.unwrap_or(Duration::from_millis(5));
            // No 50 ms cap: the wait is an exact clock deadline (one
            // free event in virtual mode), and pushes/shutdown notify
            // `available` so a sleeping worker never oversleeps work.
            return Sched::Throttled(wait.clamp(
                Duration::from_micros(100),
                Duration::from_secs(3600),
            ));
        }
        if self.qos.fifo {
            // FIFO stays tenant-blind: global arrival order over
            // every eligible queue (the pre-QoS baseline, now also
            // the tenant-blind baseline fleet cells compare against).
            let mut best: Option<(usize, usize, u64)> = None;
            for (si, slot) in st.slots.iter().enumerate() {
                if slot.bucket_dry {
                    continue;
                }
                for (c, queue) in slot.classes.iter().enumerate() {
                    if class_dry[c] {
                        continue;
                    }
                    if let Some(j) = queue.front() {
                        if best.map_or(true, |(_, _, s)| j.seq < s) {
                            best = Some((si, c, j.seq));
                        }
                    }
                }
            }
            // An eligible non-empty queue exists (checked above).
            let (si, c, _) = best.expect("eligible queue with queued work");
            let slot = &mut st.slots[si];
            slot.queued -= 1;
            let job = slot.classes[c].pop_front().expect("non-empty queue");
            st.queued -= 1;
            self.charge_buckets(c, &job);
            return Sched::Job(job);
        }
        let nslots = st.slots.len();
        let single = nslots == 1;
        loop {
            let ti = st.tcursor % nslots;
            let slot = &mut st.slots[ti];
            if slot.queued == 0 {
                // Idle tenants carry no credit into their next burst
                // (work conservation: the busy tenants split the
                // device NOW, and a waking tenant starts from its
                // plain share).
                slot.tenant_deficit = 0;
                st.tenant_granted = false;
                st.tcursor = (ti + 1) % nslots;
                continue;
            }
            let has_eligible = !slot.bucket_dry
                && slot
                    .classes
                    .iter()
                    .enumerate()
                    .any(|(c, q)| !q.is_empty() && !class_dry[c]);
            if !has_eligible {
                // Throttled slot: skip without granting the tenant
                // quantum (its deficit carries over), so one dry
                // tenant can't stall the outer round.
                st.tenant_granted = false;
                st.tcursor = (ti + 1) % nslots;
                continue;
            }
            if !single && !st.tenant_granted {
                slot.tenant_deficit = slot.tenant_deficit.saturating_add(
                    slot.share.max(1) as u64 * self.chunk_size as u64,
                );
                st.tenant_granted = true;
            }
            // Inner per-class DRR (the flat scheduler, one tenant
            // deep).  A mid-visit tenant-quantum exhaustion breaks
            // out *without* resetting the inner cursor or visit
            // grant: the slot resumes exactly where it paused on its
            // next outer visit.
            loop {
                let c = slot.cursor % IoClass::COUNT;
                if slot.classes[c].is_empty() {
                    slot.deficit[c] = 0;
                    slot.visit_granted = false;
                    slot.cursor = (c + 1) % IoClass::COUNT;
                    continue;
                }
                if class_dry[c] {
                    // Empty bucket: skip without granting this
                    // visit's quantum (the deficit carries over) — a
                    // capped backlog can't starve the round.
                    slot.visit_granted = false;
                    slot.cursor = (c + 1) % IoClass::COUNT;
                    continue;
                }
                if !slot.visit_granted {
                    let quantum = self.quantum(slot, c);
                    slot.deficit[c] = slot.deficit[c].saturating_add(quantum);
                    slot.visit_granted = true;
                }
                let cost = slot.classes[c].front().map(|j| j.cost).unwrap_or(1);
                if slot.deficit[c] < cost {
                    // This visit's grant is spent; the deficit
                    // carries over.
                    slot.visit_granted = false;
                    slot.cursor = (c + 1) % IoClass::COUNT;
                    continue;
                }
                if !single && slot.tenant_deficit < cost {
                    // Tenant quantum exhausted mid-visit: pause the
                    // slot and move the outer round on.
                    break;
                }
                slot.deficit[c] -= cost;
                if !single {
                    slot.tenant_deficit -= cost;
                }
                slot.queued -= 1;
                let job = slot.classes[c].pop_front().expect("non-empty queue");
                st.queued -= 1;
                self.charge_buckets(c, &job);
                return Sched::Job(job);
            }
            st.tenant_granted = false;
            st.tcursor = (ti + 1) % nslots;
        }
    }

    /// Rate-cap throttle for streams: block while `class`'s bucket or
    /// `tenant`'s bucket (if configured) is in debt, then charge
    /// `bytes` to each.  Called at chunk boundaries *before* the
    /// stream claims a channel, so a capped stream never holds the
    /// device while it waits.  Shutdown lifts the pacing so stream
    /// threads always drain and join.
    fn bucket_throttle(&self, class: IoClass, tenant: &TenantId, bytes: u64) {
        if let Some(bucket) = &self.buckets[class.index()] {
            self.throttle_one(bucket, bytes);
        }
        if let Some(bucket) = self.tenant_bucket(tenant) {
            self.throttle_one(bucket, bytes);
        }
    }

    fn throttle_one(&self, bucket: &TokenBucket, bytes: u64) {
        loop {
            let st = self.state.lock().unwrap();
            if st.shutdown {
                // Drain unpaced, but keep the books: a post-shutdown
                // chunk still charges its debt.
                drop(st);
                bucket.charge(bytes);
                return;
            }
            // Atomic check-and-charge: concurrent capped streams each
            // admit at most one chunk per positive-balance window
            // instead of all charging against the same observation.
            match bucket.try_charge(bytes) {
                None => return,
                Some(wait) => {
                    // Event wait for the full debt window instead of a
                    // 50 ms sleep-poll: shutdown notifies `throttled`,
                    // so drain latency is no longer quantized — and
                    // the wait is one free clock event in virtual
                    // mode.
                    let (guard, _) = self.throttled.wait_timeout(
                        &self.clock,
                        &self.state,
                        st,
                        wait,
                    );
                    drop(guard);
                }
            }
        }
    }

    /// Feed the AIMD controller one completed request.  Ingest queue
    /// waits accumulate in the sliding window; every `tick` modelled
    /// seconds the window is judged against the target and the
    /// effective Ingest weight moves — additively up while ingest is
    /// hurting, multiplicatively back toward the static weight once
    /// it isn't (or the window is empty: an idle ingest class needs
    /// no boost).  With tenants configured the controller is
    /// instanced per tenant: each tenant's window is judged against
    /// its own target and steers its own slot's effective weight.
    fn adaptive_observe(
        &self,
        class: IoClass,
        queue_secs: f64,
        tenant: &TenantId,
    ) {
        let (Some(cfg), Some(ad)) = (&self.qos.adaptive, &self.adaptive)
        else {
            return;
        };
        // Tenant-blind configs fold every observation into the one
        // default-tenant controller (the pre-tenant behaviour).
        let key = if self.qos.tenants.is_some() {
            tenant.clone()
        } else {
            TenantId::default()
        };
        let base = self.qos.weights[IoClass::Ingest.index()].max(1);
        let mut slots = ad.lock().unwrap();
        let si = match slots.iter().position(|s| s.tenant == key) {
            Some(si) => si,
            None => {
                let target = self
                    .qos
                    .tenants
                    .as_ref()
                    .and_then(|t| t.adaptive_target_for(key.as_str()))
                    .unwrap_or(self.adaptive_target)
                    .max(1e-6);
                slots.push(AdaptiveSlot {
                    tenant: key.clone(),
                    target,
                    state: AdaptiveState {
                        weight: base as f64,
                        window: LatencyHistogram::new(),
                        last_tick: self.started,
                        trajectory: Vec::new(),
                    },
                });
                slots.len() - 1
            }
        };
        let slot = &mut slots[si];
        if class == IoClass::Ingest {
            slot.state.window.record(queue_secs);
        }
        let ts = self.device.model.time_scale.max(1e-9);
        let now = self.clock.now();
        if (now - slot.state.last_tick) * ts < cfg.tick {
            return;
        }
        slot.state.last_tick = now;
        // Judged against THIS slot's resolved target (per-profile and
        // per-tenant overrides: an HDD's bar is not an Optane's).
        let hot = slot.state.window.count() > 0
            && slot.state.window.p99() * ts > slot.target;
        let next = if hot {
            (slot.state.weight + cfg.increase.max(1) as f64)
                .min(cfg.max_weight.max(1) as f64)
        } else {
            (base as f64
                + (slot.state.weight - base as f64)
                    * cfg.decay.clamp(0.0, 1.0))
            .max(base as f64)
        };
        slot.state.window = LatencyHistogram::new();
        if (next - slot.state.weight).abs() >= 0.5
            && slot.state.trajectory.len() < MAX_WEIGHT_TRAJECTORY
        {
            slot.state
                .trajectory
                .push(((now - self.started).max(0.0), next.round() as u32));
        }
        slot.state.weight = next;
        let w = next.round().max(1.0) as u32;
        drop(slots);
        if key.is_default() {
            self.eff_ingest_weight.store(w, Ordering::Relaxed);
        }
        // Push the new weight into the scheduler slot (lock order:
        // adaptive, then state — the scheduler never takes the
        // adaptive lock).
        let mut st = self.state.lock().unwrap();
        if let Some(s) = st.slots.iter_mut().find(|s| s.tenant == key) {
            s.eff_weight = w;
        }
    }

    /// Preemption point: block (bounded) while any strictly
    /// higher-priority class has queued work.  Streams call this at
    /// chunk boundaries *before* claiming the device, so they hold
    /// neither a channel nor a pool worker while yielding — queued
    /// ingest drains through the freed channel.  No-op in FIFO mode.
    fn yield_to_higher(&self, class: IoClass) {
        if self.qos.fifo || self.qos.preempt_chunks == 0 {
            return;
        }
        let hi = class.index();
        if hi == 0 {
            return;
        }
        // max_yield_wait is modelled seconds: convert to wall time at
        // this device's simulation speed-up.  Zero, negative, and
        // non-finite bounds disable the wait outright — they must not
        // reach Duration::from_secs_f64, which panics on them.
        let wall_bound =
            self.qos.max_yield_wait / self.device.model.time_scale.max(1e-9);
        if wall_bound <= 0.0 || !wall_bound.is_finite() {
            return;
        }
        let deadline = self.clock.now() + wall_bound.min(3600.0);
        let mut st = self.state.lock().unwrap();
        while !st.shutdown
            && st
                .slots
                .iter()
                .any(|s| s.classes[..hi].iter().any(|q| !q.is_empty()))
        {
            // An already-expired deadline ends the yield (regression:
            // zero/expired max_yield_wait must not wait at all).
            let remaining = deadline - self.clock.now();
            if remaining <= 0.0 {
                break;
            }
            let (guard, _) = self.drained.wait_timeout(
                &self.clock,
                &self.state,
                st,
                Duration::from_secs_f64(remaining),
            );
            st = guard;
        }
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// Request-level I/O engine over the simulated devices.
pub struct IoEngine {
    queues: HashMap<String, Arc<DeviceQueue>>,
    workers: Vec<JoinHandle<()>>,
    chunk_size: usize,
    qos: QosConfig,
    /// The engine's time source, taken from its devices (all devices
    /// of one engine must share a clock).
    clock: Clock,
    gauge: Arc<BufferGauge>,
    /// Request-level event observer slot, shared with every device
    /// queue ([`set_observer`](Self::set_observer)).
    observer: ObserverSlot,
    /// Live stream queues, aborted at shutdown so a producer that
    /// outlives the engine can never leave a stream thread parked in
    /// `pop`.
    streams: Mutex<Vec<std::sync::Weak<ChunkQueue>>>,
    /// Stream service threads (writers + copy readers), joined at
    /// shutdown.  Streams run on dedicated threads, NOT the unit
    /// worker pool: a long-lived or producer-stalled stream must
    /// never starve unit requests of workers.
    stream_threads: Mutex<Vec<JoinHandle<()>>>,
}

impl IoEngine {
    /// Build an engine over `devices` with the default chunk size.
    pub fn new(devices: &HashMap<String, Arc<Device>>) -> IoEngine {
        Self::with_chunk_size(devices, DEFAULT_CHUNK)
    }

    /// Build an engine with an explicit streaming chunk size and the
    /// default QoS config.
    pub fn with_chunk_size(
        devices: &HashMap<String, Arc<Device>>,
        chunk_size: usize,
    ) -> IoEngine {
        Self::with_config(devices, chunk_size, QosConfig::default())
    }

    /// Build an engine with explicit chunk size and scheduler config.
    pub fn with_config(
        devices: &HashMap<String, Arc<Device>>,
        chunk_size: usize,
        qos: QosConfig,
    ) -> IoEngine {
        let chunk_size = chunk_size.max(4 * 1024);
        let gauge = Arc::new(BufferGauge::new());
        let quanta: [u64; IoClass::COUNT] = std::array::from_fn(|i| {
            qos.weights[i].max(1) as u64 * chunk_size as u64
        });
        let observer: ObserverSlot = Arc::new(RwLock::new(None));
        // The engine runs on its devices' time source (wall or
        // virtual); all devices of one engine share a clock.
        let clock = devices
            .values()
            .next()
            .map(|d| d.clock().clone())
            .unwrap_or_else(Clock::wall);
        debug_assert!(
            devices.values().all(|d| d.clock().same(&clock)),
            "all devices of one engine must share a clock"
        );
        // One epoch for every device's event timestamps.
        let epoch = clock.now();
        let mut queues = HashMap::new();
        let mut workers = Vec::new();
        for (name, device) in devices {
            // Rate caps are modelled bytes/sec; the wall bucket runs
            // at the device's simulation speed-up so the cap keeps
            // its meaning on accelerated testbeds.
            let ts = device.model.time_scale.max(1e-9);
            let buckets: [Option<TokenBucket>; IoClass::COUNT] =
                std::array::from_fn(|i| {
                    qos.rate_caps[i].map(|cap| {
                        TokenBucket::with_burst(
                            cap.bytes_per_sec.max(1.0) * ts,
                            cap.burst_bytes.max(1) as f64,
                            clock.clone(),
                        )
                    })
                });
            // Per-tenant rate caps get their own buckets, found by
            // tenant at dispatch/throttle time.
            let tenant_buckets: Vec<(TenantId, TokenBucket)> = qos
                .tenants
                .as_ref()
                .map(|t| {
                    t.rate_caps
                        .iter()
                        .map(|(name, cap)| {
                            (
                                TenantId::new(name),
                                TokenBucket::with_burst(
                                    cap.bytes_per_sec.max(1.0) * ts,
                                    cap.burst_bytes.max(1) as f64,
                                    clock.clone(),
                                ),
                            )
                        })
                        .collect()
                })
                .unwrap_or_default();
            let base_weight =
                qos.weights[IoClass::Ingest.index()].max(1);
            let adaptive_target = qos
                .adaptive
                .as_ref()
                .map(|a| a.target_for(name))
                .unwrap_or(0.0);
            // The default-tenant AIMD slot is pre-created so
            // tenant-blind configs keep the exact pre-tenant
            // controller; per-tenant slots appear on first
            // observation.
            let adaptive = qos.adaptive.as_ref().map(|_| {
                Mutex::new(vec![AdaptiveSlot {
                    tenant: TenantId::default(),
                    target: adaptive_target.max(1e-6),
                    state: AdaptiveState {
                        weight: base_weight as f64,
                        window: LatencyHistogram::new(),
                        last_tick: epoch,
                        trajectory: Vec::new(),
                    },
                }])
            });
            let q = Arc::new(DeviceQueue {
                device: Arc::clone(device),
                state: Mutex::new(QueueState {
                    slots: vec![TenantSlot::new(
                        TenantId::default(),
                        qos.tenants.as_ref().map_or(1, |t| t.share_for("")),
                        base_weight,
                    )],
                    tcursor: 0,
                    tenant_granted: false,
                    queued: 0,
                    next_seq: 0,
                    class_live: [0; IoClass::COUNT],
                    class_peak: [0; IoClass::COUNT],
                    shutdown: false,
                }),
                available: SimCondvar::new(),
                drained: SimCondvar::new(),
                throttled: SimCondvar::new(),
                stats: Mutex::new(EngineDeviceStats {
                    device: name.clone(),
                    ..EngineDeviceStats::default()
                }),
                qos: qos.clone(),
                quanta,
                chunk_size,
                buckets,
                tenant_buckets,
                adaptive,
                adaptive_target,
                eff_ingest_weight: AtomicU32::new(base_weight),
                started: epoch,
                clock: clock.clone(),
                observer: Arc::clone(&observer),
            });
            let n_workers = device
                .model
                .channels
                .clamp(1, MAX_WORKERS_PER_DEVICE);
            for i in 0..n_workers {
                let q = Arc::clone(&q);
                let chunk = chunk_size;
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("dlio-io-{name}-{i}"))
                        .spawn(move || worker_loop(q, chunk))
                        .expect("spawn io-engine worker"),
                );
            }
            queues.insert(name.clone(), q);
        }
        IoEngine {
            queues,
            workers,
            chunk_size,
            qos,
            clock,
            gauge,
            observer,
            streams: Mutex::new(Vec::new()),
            stream_threads: Mutex::new(Vec::new()),
        }
    }

    /// Scheduler configuration in force.
    pub fn qos(&self) -> &QosConfig {
        &self.qos
    }

    /// The engine's time source.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Attach a request-level event observer (the trace recorder's
    /// hook), replacing any existing one.  Events flow for every
    /// request that *completes* after the attach; a request that
    /// resolved before sees nothing.
    pub fn set_observer(&self, obs: Arc<dyn EngineObserver>) {
        *self.observer.write().unwrap() = Some(obs);
    }

    /// Detach the event observer: recording stops (in-flight
    /// completions racing the detach may still deliver).
    pub fn clear_observer(&self) {
        *self.observer.write().unwrap() = None;
    }

    /// Track a stream queue for shutdown aborts (pruning dead ones).
    fn register_stream(&self, rx: &Arc<ChunkQueue>) {
        let mut streams = self.streams.lock().unwrap();
        streams.retain(|w| w.upgrade().is_some());
        streams.push(Arc::downgrade(rx));
    }

    fn track_thread(&self, handle: JoinHandle<()>) {
        let mut threads = self.stream_threads.lock().unwrap();
        // Drop handles of finished streams so a long run of saves
        // doesn't accumulate dead JoinHandles.
        threads.retain(|t| !t.is_finished());
        threads.push(handle);
    }

    /// Spawn the consumer half of a stream write on its own thread:
    /// claims the device per chunk (yielding to higher classes at
    /// preemption points), fills `ticket` on completion.
    #[allow(clippy::too_many_arguments)]
    fn spawn_stream_writer(
        &self,
        q: &Arc<DeviceQueue>,
        path: PathBuf,
        rx: Arc<ChunkQueue>,
        enq_depth: u32,
        class: IoClass,
        origin: &'static str,
        tier: Option<u32>,
        tenant: TenantId,
        ticket: Arc<TicketShared>,
    ) {
        let q = Arc::clone(q);
        let submitted = q.clock.now();
        q.stream_begin(class);
        let handle = std::thread::Builder::new()
            .name(format!("dlio-io-stream-{}", q.device.name()))
            .spawn(move || {
                // Stream writers live on the engine clock: registered
                // so virtual time can't advance past a runnable one.
                let _reg = q.clock.enter();
                let mut first_service: Option<f64> = None;
                let result = write_stream_paced(&q, &path, &rx, enq_depth,
                                                class, &tenant,
                                                &mut first_service);
                if result.is_err() {
                    // Unblock and drain the producer before failing.
                    rx.abort();
                }
                // Queue time = submit -> first chunk claiming the
                // device (channel contention + preemption yields show
                // up here, where tf-Darshan-style analysis expects
                // them); everything after is service.
                let t_end = q.clock.now();
                let (queue_secs, service_secs) = match first_service {
                    Some(ts) => (ts - submitted, t_end - ts),
                    None => (t_end - submitted, 0.0),
                };
                q.stream_end(class);
                {
                    let mut stats = q.stats.lock().unwrap();
                    match &result {
                        Ok(total) => record_done(
                            &mut stats,
                            class,
                            tier,
                            &tenant,
                            queue_secs,
                            service_secs,
                            Some((*total, Dir::Write)),
                            false,
                        ),
                        // A failure whose producer already charged it
                        // (copy read half) must not be double-counted
                        // here.
                        Err(f) => record_done(
                            &mut stats,
                            class,
                            tier,
                            &tenant,
                            queue_secs,
                            service_secs,
                            None,
                            !f.counted,
                        ),
                    }
                }
                q.adaptive_observe(class, queue_secs, &tenant);
                let (ev_bytes, ev_ok) = match &result {
                    Ok(total) => (*total, true),
                    Err(_) => (0, false),
                };
                q.emit(class, EngineOp::StreamWrite, origin, tier, &tenant,
                       ev_bytes, ev_ok, submitted, queue_secs, service_secs);
                complete(
                    &ticket,
                    result
                        .map(|total| IoCompletion {
                            bytes: total,
                            data: None,
                            queue_secs,
                            service_secs,
                        })
                        .map_err(|f| f.error),
                );
            })
            .expect("spawn stream writer");
        self.track_thread(handle);
    }

    /// Streaming chunk size in force.
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    fn queue(&self, device: &str) -> Result<&Arc<DeviceQueue>> {
        self.queues
            .get(device)
            .ok_or_else(|| anyhow!("unknown device {device:?}"))
    }

    /// Submit a request under its default class; returns its
    /// completion ticket immediately.
    pub fn submit(&self, req: IoRequest) -> Result<IoTicket> {
        let class = req.default_class();
        self.submit_class(req, class)
    }

    /// Submit a request under an explicit traffic class.
    pub fn submit_class(&self, req: IoRequest, class: IoClass) -> Result<IoTicket> {
        match req {
            IoRequest::ReadFile { device, path } => {
                self.submit_unit(&device, JobOp::Read { path }, class)
            }
            IoRequest::WriteFile { device, path, data } => {
                self.submit_unit(&device, JobOp::Write { path, data }, class)
            }
            IoRequest::ProbeRead { device, bytes } => self.submit_unit(
                &device,
                JobOp::Probe { dir: Dir::Read, bytes },
                class,
            ),
            IoRequest::ProbeWrite { device, bytes } => self.submit_unit(
                &device,
                JobOp::Probe { dir: Dir::Write, bytes },
                class,
            ),
            IoRequest::Copy { src_device, src_path, dst_device, dst_path } => {
                self.submit_copy(&src_device, src_path, &dst_device, dst_path,
                                 class)
            }
        }
    }

    /// DRR cost of a unit job, bytes.
    fn job_cost(op: &JobOp, chunk_size: usize) -> u64 {
        match op {
            JobOp::Read { path } => std::fs::metadata(path)
                .map(|m| m.len())
                .unwrap_or(chunk_size as u64),
            JobOp::Write { data, .. } => data.len() as u64,
            JobOp::Probe { bytes, .. } => *bytes,
        }
        .max(1)
    }

    /// Submit a whole-file read whose size the caller already knows
    /// (the sim's cache check statted the file an instant ago): skips
    /// `job_cost`'s metadata lookup on the hot ingest path.
    pub fn submit_read_sized(
        &self,
        device: &str,
        path: PathBuf,
        size: u64,
        class: IoClass,
    ) -> Result<IoTicket> {
        self.submit_unit_with_cost(device, JobOp::Read { path }, class,
                                   size.max(1))
    }

    /// Unit jobs join the device queue at submit time so the elevator
    /// model sees queued requests (the paper's queue-depth effect).
    fn submit_unit(
        &self,
        device: &str,
        op: JobOp,
        class: IoClass,
    ) -> Result<IoTicket> {
        let cost = Self::job_cost(&op, self.chunk_size);
        self.submit_unit_with_cost(device, op, class, cost)
    }

    fn submit_unit_with_cost(
        &self,
        device: &str,
        op: JobOp,
        class: IoClass,
        cost: u64,
    ) -> Result<IoTicket> {
        let q = self.queue(device)?;
        let (ticket, shared) = new_ticket(&self.clock);
        let enq_depth = q.device.queue_enter();
        record_submit(&mut q.stats.lock().unwrap(), class, enq_depth);
        q.push(Job {
            op,
            class,
            cost,
            seq: 0, // assigned by push
            ticket: Arc::clone(&shared),
            submitted: self.clock.now(),
            origin: current_origin(),
            tier: current_tier(),
            tenant: current_tenant(),
            enq_depth,
        });
        Ok(ticket)
    }

    /// Submit several requests through one doorbell: every request
    /// joins its device queue *before* any is serviced, so the
    /// elevator model sees the whole burst (io_uring's
    /// many-SQEs-one-doorbell semantics).  This is what makes an
    /// overlapped checkpoint triple on an HDD faster than three serial
    /// writes even with a single channel.  Tickets are returned in
    /// request order.  Each request runs under its default class; use
    /// [`submit_batch_class`](Self::submit_batch_class) to override.
    pub fn submit_batch(&self, reqs: Vec<IoRequest>) -> Result<Vec<IoTicket>> {
        self.submit_batch_tagged(reqs, None)
    }

    /// One-doorbell batch with every request under `class`.
    pub fn submit_batch_class(
        &self,
        reqs: Vec<IoRequest>,
        class: IoClass,
    ) -> Result<Vec<IoTicket>> {
        self.submit_batch_tagged(reqs, Some(class))
    }

    fn submit_batch_tagged(
        &self,
        reqs: Vec<IoRequest>,
        class: Option<IoClass>,
    ) -> Result<Vec<IoTicket>> {
        // Validate every target device before entering any queue.
        for req in &reqs {
            match req {
                IoRequest::ReadFile { device, .. }
                | IoRequest::WriteFile { device, .. }
                | IoRequest::ProbeRead { device, .. }
                | IoRequest::ProbeWrite { device, .. } => {
                    self.queue(device)?;
                }
                IoRequest::Copy { src_device, dst_device, .. } => {
                    self.queue(src_device)?;
                    self.queue(dst_device)?;
                }
            }
        }
        // Phase 1: enter every unit request's device queue.  A copy
        // submission mid-batch can still fail (dst directory
        // creation), so memberships taken so far are tracked and
        // released on that path — an early return must never leave a
        // device's queue depth permanently inflated.
        type UnitSlot = (String, JobOp, IoClass);
        let mut slots: Vec<(Option<UnitSlot>, Option<IoTicket>)> =
            Vec::with_capacity(reqs.len());
        let mut burst_depth: HashMap<String, u32> = HashMap::new();
        let mut entered: Vec<String> = Vec::new();
        for req in reqs {
            let req_class = class.unwrap_or_else(|| req.default_class());
            let unit = match req {
                IoRequest::ReadFile { device, path } => {
                    (device, JobOp::Read { path })
                }
                IoRequest::WriteFile { device, path, data } => {
                    (device, JobOp::Write { path, data })
                }
                IoRequest::ProbeRead { device, bytes } => {
                    (device, JobOp::Probe { dir: Dir::Read, bytes })
                }
                IoRequest::ProbeWrite { device, bytes } => {
                    (device, JobOp::Probe { dir: Dir::Write, bytes })
                }
                copy @ IoRequest::Copy { .. } => {
                    // Copies are stream pairs; they don't take part in
                    // the unit doorbell.
                    match self.submit_class(copy, req_class) {
                        Ok(t) => slots.push((None, Some(t))),
                        Err(e) => {
                            for device in entered {
                                self.queue(&device)
                                    .expect("validated above")
                                    .device
                                    .queue_leave();
                            }
                            return Err(e);
                        }
                    }
                    continue;
                }
            };
            let (device, op) = unit;
            let depth = self
                .queue(&device)
                .expect("validated above")
                .device
                .queue_enter();
            entered.push(device.clone());
            let entry = burst_depth.entry(device.clone()).or_insert(0);
            *entry = (*entry).max(depth);
            slots.push((Some((device, op, req_class)), None));
        }
        // Phase 2: push jobs, every one carrying its device's full
        // burst depth.
        let mut tickets = Vec::with_capacity(slots.len());
        for (unit, ready) in slots {
            match (unit, ready) {
                (None, Some(t)) => tickets.push(t),
                (Some((device, op, req_class)), None) => {
                    let q = self.queue(&device).expect("validated above");
                    let enq_depth = burst_depth[&device];
                    let (ticket, shared) = new_ticket(&self.clock);
                    let cost = Self::job_cost(&op, self.chunk_size);
                    record_submit(
                        &mut q.stats.lock().unwrap(),
                        req_class,
                        enq_depth,
                    );
                    q.push(Job {
                        op,
                        class: req_class,
                        cost,
                        seq: 0, // assigned by push
                        ticket: Arc::clone(&shared),
                        submitted: self.clock.now(),
                        origin: current_origin(),
                        tier: current_tier(),
                        tenant: current_tenant(),
                        enq_depth,
                    });
                    tickets.push(ticket);
                }
                _ => unreachable!("slot is either unit or ready"),
            }
        }
        Ok(tickets)
    }

    /// Open a streamed write under [`IoClass::Checkpoint`] (the saver
    /// `.data` path): returns the producer handle and the completion
    /// ticket.  The stream runs on a dedicated thread and claims the
    /// device per chunk, so a stalled producer holds neither a channel
    /// nor a pool worker hostage.
    pub fn write_stream(
        &self,
        device: &str,
        path: PathBuf,
    ) -> Result<(ChunkWriter, IoTicket)> {
        self.write_stream_class(device, path, IoClass::Checkpoint)
    }

    /// Streamed write under an explicit class.
    pub fn write_stream_class(
        &self,
        device: &str,
        path: PathBuf,
        class: IoClass,
    ) -> Result<(ChunkWriter, IoTicket)> {
        let q = self.queue(device)?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        let rx = Arc::new(ChunkQueue::new(
            STREAM_WINDOW,
            Arc::clone(&self.gauge),
            self.clock.clone(),
        ));
        self.register_stream(&rx);
        let (ticket, shared) = new_ticket(&self.clock);
        // The stream joins the device queue now (its first chunk
        // consumes the membership), so it counts toward any burst
        // submitted alongside it.
        let enq_depth = q.device.queue_enter();
        record_submit(&mut q.stats.lock().unwrap(), class, enq_depth);
        self.spawn_stream_writer(q, path, Arc::clone(&rx), enq_depth, class,
                                 current_origin(), current_tier(),
                                 current_tenant(), shared);
        let writer = ChunkWriter {
            queue: rx,
            chunk_size: self.chunk_size,
            pending: Vec::with_capacity(self.chunk_size),
            finished: false,
        };
        Ok((writer, ticket))
    }

    /// Streamed write fed from a backing file *without* charging any
    /// read device — the page-cache-warm copy source.  Chunks flow
    /// through the bounded window, so peak memory stays bounded by
    /// the chunk size even for warm multi-GB files.
    pub fn write_from_file(
        &self,
        device: &str,
        src_path: PathBuf,
        dst_path: PathBuf,
    ) -> Result<IoTicket> {
        self.write_from_file_class(device, src_path, dst_path, IoClass::Drain)
    }

    /// [`write_from_file`](Self::write_from_file) under an explicit
    /// class.
    pub fn write_from_file_class(
        &self,
        device: &str,
        src_path: PathBuf,
        dst_path: PathBuf,
        class: IoClass,
    ) -> Result<IoTicket> {
        let q = self.queue(device)?;
        if let Some(parent) = dst_path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        let rx = Arc::new(ChunkQueue::new(
            STREAM_WINDOW,
            Arc::clone(&self.gauge),
            self.clock.clone(),
        ));
        self.register_stream(&rx);
        let (ticket, shared) = new_ticket(&self.clock);
        let enq_depth = q.device.queue_enter();
        record_submit(&mut q.stats.lock().unwrap(), class, enq_depth);
        self.spawn_stream_writer(q, dst_path, Arc::clone(&rx), enq_depth,
                                 class, current_origin(), current_tier(),
                                 current_tenant(), shared);
        let chunk_size = self.chunk_size;
        let clock = self.clock.clone();
        let handle = std::thread::Builder::new()
            .name("dlio-io-warmread".into())
            .spawn(move || {
                let _reg = clock.enter();
                unpaced_file_reader(src_path, rx, chunk_size)
            })
            .expect("spawn warm copy reader");
        self.track_thread(handle);
        Ok(ticket)
    }

    /// Copy = source reader thread feeding a bounded chunk queue into
    /// a destination stream-write job: read-from-src overlaps
    /// write-to-dst, memory bounded by the stream window.
    fn submit_copy(
        &self,
        src_device: &str,
        src_path: PathBuf,
        dst_device: &str,
        dst_path: PathBuf,
        class: IoClass,
    ) -> Result<IoTicket> {
        let src_q = Arc::clone(self.queue(src_device)?);
        let dst_q = self.queue(dst_device)?;
        if let Some(parent) = dst_path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("mkdir {}", parent.display()))?;
        }
        let rx = Arc::new(ChunkQueue::new(
            STREAM_WINDOW,
            Arc::clone(&self.gauge),
            self.clock.clone(),
        ));
        self.register_stream(&rx);
        let (ticket, shared) = new_ticket(&self.clock);
        let origin = current_origin();
        // Both halves of a migration copy carry the destination tier:
        // "drain into tier N" is the attribution a hierarchy wants.
        let tier = current_tier();
        let tenant = current_tenant();
        let dst_enq = dst_q.device.queue_enter();
        record_submit(&mut dst_q.stats.lock().unwrap(), class, dst_enq);
        self.spawn_stream_writer(dst_q, dst_path, Arc::clone(&rx), dst_enq,
                                 class, origin, tier, tenant.clone(), shared);
        let src_enq = src_q.device.queue_enter();
        // The read half is a request against the source device:
        // account its submission now (completion lands in
        // `copy_reader`), so src stats can never miss an in-flight
        // copy.
        record_submit(&mut src_q.stats.lock().unwrap(), class, src_enq);
        src_q.stream_begin(class);
        let submitted = self.clock.now();
        let chunk_size = self.chunk_size;
        let handle = std::thread::Builder::new()
            .name("dlio-io-copy".into())
            .spawn(move || {
                let _reg = src_q.clock.enter();
                copy_reader(src_q, src_path, rx, chunk_size, src_enq, class,
                            origin, tier, tenant, submitted)
            })
            .expect("spawn copy reader");
        self.track_thread(handle);
        Ok(ticket)
    }

    /// Per-device request aggregates (with per-class breakdown).
    pub fn stats(&self) -> Vec<EngineDeviceStats> {
        let mut out: Vec<EngineDeviceStats> = self
            .queues
            .values()
            .map(|q| {
                let mut s = q.stats.lock().unwrap().clone();
                {
                    let st = q.state.lock().unwrap();
                    for (cs, peak) in
                        s.classes.iter_mut().zip(st.class_peak.iter())
                    {
                        cs.max_queue_depth = *peak;
                    }
                }
                // Fold in the device's entry-side peak gauge: stream
                // chunks and copy read halves enter the device queue
                // without passing a submit path, and bursts can drain
                // between submits — the gauge sees every entry.
                s.max_queue_depth =
                    s.max_queue_depth.max(q.device.peak_queue_depth());
                s.ingest_weight =
                    q.eff_ingest_weight.load(Ordering::Relaxed);
                if let Some(ad) = &q.adaptive {
                    let slots = ad.lock().unwrap();
                    if let Some(slot) =
                        slots.iter().find(|s| s.tenant.is_default())
                    {
                        s.weight_trajectory = slot.state.trajectory.clone();
                    }
                }
                s
            })
            .collect();
        out.sort_by(|a, b| a.device.cmp(&b.device));
        out
    }

    /// Zero every device's counters, histograms, and depth peaks so a
    /// driver can bracket a measured phase after fixture setup (call
    /// at quiescence: an in-flight request would complete into the
    /// fresh counters).  The adaptive controller's weight and
    /// trajectory survive — they are control state, not measurements.
    pub fn reset_stats(&self) {
        for q in self.queues.values() {
            {
                let mut st = q.state.lock().unwrap();
                // Re-seed the class peaks from what is live right now.
                let peaks: [u32; IoClass::COUNT] = std::array::from_fn(|c| {
                    st.slots
                        .iter()
                        .map(|s| s.classes[c].len() as u32)
                        .sum::<u32>()
                        + st.class_live[c]
                });
                st.class_peak = peaks;
            }
            {
                let mut stats = q.stats.lock().unwrap();
                let device = stats.device.clone();
                *stats = EngineDeviceStats {
                    device,
                    ..EngineDeviceStats::default()
                };
            }
            q.device.reset_peak_queue_depth();
        }
    }

    /// Peak bytes ever buffered in stream chunk queues (the
    /// bounded-memory guarantee: ≤ chunk_size * STREAM_WINDOW + one
    /// in-flight chunk per stream).
    pub fn peak_stream_bytes(&self) -> u64 {
        self.gauge.peak.load(Ordering::SeqCst)
    }

    /// Reset the peak gauge (bench bracketing).
    pub fn reset_peak_stream_bytes(&self) {
        self.gauge
            .peak
            .store(self.gauge.current.load(Ordering::SeqCst), Ordering::SeqCst);
    }
}

impl Drop for IoEngine {
    fn drop(&mut self) {
        // Fail any still-open streams so no stream thread stays parked
        // in `pop`/`push` waiting on a peer that will never finish.
        for weak in self.streams.lock().unwrap().drain(..) {
            if let Some(rx) = weak.upgrade() {
                rx.abort();
            }
        }
        for q in self.queues.values() {
            let mut st = q.state.lock().unwrap();
            st.shutdown = true;
            drop(st);
            q.available.notify_all(&self.clock);
            // Wake any stream parked at a preemption point or
            // throttled against a rate cap.
            q.drained.notify_all(&self.clock);
            q.throttled.notify_all(&self.clock);
        }
        // Joining is a foreign blocking primitive: drop any clock
        // registration first so virtual time keeps advancing while the
        // workers drain their backlog.
        let _suspended = self.clock.suspend();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        for t in self.stream_threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

fn worker_loop(q: Arc<DeviceQueue>, chunk_size: usize) {
    // Workers live on the engine clock for their whole lifetime, so
    // virtual time only advances when every worker is parked or
    // sleeping through the clock.
    let _reg = q.clock.enter();
    loop {
        let job = {
            let mut st = q.state.lock().unwrap();
            loop {
                match q.sched_pop(&mut st) {
                    Sched::Job(job) => break job,
                    Sched::Throttled(wait) => {
                        // Every queued class is rate-capped dry:
                        // sleep until the earliest bucket refills (a
                        // shutdown notify re-polls immediately, and
                        // sched_pop ignores caps once shut down).
                        let (guard, _) = q.available.wait_timeout(
                            &q.clock, &q.state, st, wait,
                        );
                        st = guard;
                    }
                    Sched::Idle => {
                        if st.shutdown {
                            return;
                        }
                        st = q.available.wait(&q.clock, &q.state, st);
                    }
                }
            }
        };
        // A queue may just have emptied: wake streams parked at a
        // preemption point so they re-check their yield predicate.
        q.drained.notify_all(&q.clock);
        let op_kind = job.op.engine_op();
        let queue_secs = (q.clock.now() - job.submitted).max(0.0);
        let t0 = q.clock.now();
        // Bounded retry-with-backoff (the fault seam's degraded-mode
        // path): a failed attempt is re-run up to the class's budget
        // with doubling modelled backoff before its error surfaces.
        // The backoff sleeps on the engine clock, so virtual-clock
        // fault runs stay deterministic.
        let budget = q.qos.retry.budget[job.class.index()];
        let mut attempt = 0u32;
        // Each attempt consumes one queue membership (service_end
        // leaves the queue), so every retry re-enters before re-running
        // — the elevator model sees retries as fresh arrivals.
        let mut enq_depth = job.enq_depth;
        let outcome = loop {
            let res = run_job(&q.device, &job.op, enq_depth, chunk_size);
            match res {
                Ok(v) => break Ok(v),
                Err(e) => {
                    if attempt >= budget {
                        break Err(e);
                    }
                    attempt += 1;
                    record_retry(
                        &mut q.stats.lock().unwrap(),
                        job.class,
                    );
                    let backoff = q.qos.retry.backoff
                        * (1u64 << (attempt - 1).min(16)) as f64
                        / q.device.model.time_scale;
                    q.clock.sleep_secs(backoff);
                    enq_depth = q.device.queue_enter();
                }
            }
        };
        let service_secs = (q.clock.now() - t0).max(0.0);
        {
            let mut stats = q.stats.lock().unwrap();
            match &outcome {
                Ok((bytes, dir, _)) => record_done(
                    &mut stats,
                    job.class,
                    job.tier,
                    &job.tenant,
                    queue_secs,
                    service_secs,
                    Some((*bytes, *dir)),
                    false,
                ),
                Err(_) => record_done(
                    &mut stats,
                    job.class,
                    job.tier,
                    &job.tenant,
                    queue_secs,
                    service_secs,
                    None,
                    true,
                ),
            }
        }
        q.adaptive_observe(job.class, queue_secs, &job.tenant);
        // Event bytes on failure: what the request *meant* to move
        // (its DRR cost), so a trace replay offers the same load.
        let (ev_bytes, ev_ok) = match &outcome {
            Ok((bytes, _, _)) => (*bytes, true),
            Err(_) => (job.cost, false),
        };
        q.emit(job.class, op_kind, job.origin, job.tier, &job.tenant,
               ev_bytes, ev_ok, job.submitted, queue_secs, service_secs);
        complete(
            &job.ticket,
            outcome.map(|(bytes, _, data)| IoCompletion {
                bytes,
                data,
                queue_secs,
                service_secs,
            }),
        );
    }
}

/// Execute one job; returns (bytes, direction, data).  Borrows the op
/// so the worker's bounded-retry loop can re-run a failed attempt.
/// Each attempt passes the device's fault gate after claiming a
/// channel — an injected denial (offline, read-only write, transient
/// error) fails like a command error, with the gate balanced.
fn run_job(
    dev: &Arc<Device>,
    op: &JobOp,
    enq_depth: u32,
    chunk_size: usize,
) -> Result<(u64, Dir, Option<Vec<u8>>)> {
    match op {
        JobOp::Read { path } => {
            // Queue membership was taken at submit; claim a channel
            // and balance the gate whatever happens during service.
            let depth = dev.service_begin(enq_depth);
            if let Err(e) = dev.fault_gate(Dir::Read) {
                dev.service_end();
                return Err(e);
            }
            // Per-block-size calibrated models price the setup phase
            // by request size; stat only when a table makes it matter.
            let size_hint = if dev.model.has_lat_table(Dir::Read) {
                std::fs::metadata(path).map(|m| m.len()).unwrap_or(0)
            } else {
                0
            };
            dev.latency_phase_sized(Dir::Read, depth, size_hint);
            let res = read_paced(dev, path, chunk_size);
            dev.service_end();
            let data = res?;
            Ok((data.len() as u64, Dir::Read, Some(data)))
        }
        JobOp::Write { path, data } => {
            let depth = dev.service_begin(enq_depth);
            if let Err(e) = dev.fault_gate(Dir::Write) {
                dev.service_end();
                return Err(e);
            }
            dev.latency_phase_sized(Dir::Write, depth, data.len() as u64);
            let res = write_paced(dev, path, data, chunk_size);
            dev.service_end();
            res?;
            Ok((data.len() as u64, Dir::Write, None))
        }
        JobOp::Probe { dir, bytes } => {
            let (dir, bytes) = (*dir, *bytes);
            let depth = dev.service_begin(enq_depth);
            if let Err(e) = dev.fault_gate(dir) {
                dev.service_end();
                return Err(e);
            }
            dev.latency_phase_sized(dir, depth, bytes);
            let chunk = dev.pacing_chunk(bytes).max(chunk_size as u64);
            let mut remaining = bytes;
            while remaining > 0 {
                let take = remaining.min(chunk);
                dev.pace(dir, take, 0.0);
                remaining -= take;
            }
            dev.service_end();
            Ok((bytes, dir, None))
        }
    }
}

/// Chunked paced whole-file read (the worker holds a channel).
fn read_paced(dev: &Arc<Device>, path: &Path, chunk_size: usize) -> Result<Vec<u8>> {
    let mut file = std::fs::File::open(path)
        .with_context(|| format!("read {}", path.display()))?;
    let size = file
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len() as usize;
    let mut out = Vec::with_capacity(size);
    let mut buf = vec![0u8; chunk_size];
    loop {
        let t0 = dev.clock().now();
        let n = file
            .read(&mut buf)
            .with_context(|| format!("read {}", path.display()))?;
        if n == 0 {
            break;
        }
        dev.pace(Dir::Read, n as u64, dev.clock().now() - t0);
        out.extend_from_slice(&buf[..n]);
    }
    Ok(out)
}

/// Chunked paced whole-buffer write (the worker holds a channel).
fn write_paced(
    dev: &Arc<Device>,
    path: &Path,
    data: &[u8],
    chunk_size: usize,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    for chunk in data.chunks(chunk_size.max(1)) {
        let t0 = dev.clock().now();
        file.write_all(chunk)
            .with_context(|| format!("write {}", path.display()))?;
        dev.pace(Dir::Write, chunk.len() as u64, dev.clock().now() - t0);
    }
    // A zero-byte payload still creates the file (no pacing charge).
    Ok(())
}

/// Streamed write: claims the device *per chunk* so a slow producer
/// (or a cross-device copy peer) can never deadlock two channel gates
/// against each other.  The latency phase is charged once, on the
/// first chunk, at the submit-time burst depth (`enq_depth`) or
/// deeper.  The stream's submit-time queue membership is consumed by
/// the first chunk's service (or released if no chunk arrives).
/// Every `preempt_chunks` chunks the stream yields to queued
/// higher-priority classes before re-claiming the device — the
/// configurable preemption point that stops a large checkpoint from
/// head-of-line-blocking ingest.
fn write_stream_paced(
    q: &Arc<DeviceQueue>,
    path: &Path,
    rx: &Arc<ChunkQueue>,
    enq_depth: u32,
    class: IoClass,
    tenant: &TenantId,
    first_service: &mut Option<f64>,
) -> Result<u64, StreamFailure> {
    let mut first = true;
    let result = write_stream_chunks(q, path, rx, enq_depth, &mut first,
                                     class, tenant, first_service);
    if first {
        // No chunk ever claimed the submit-time queue membership.
        q.device.queue_leave();
    }
    result
}

#[allow(clippy::too_many_arguments)]
fn write_stream_chunks(
    q: &Arc<DeviceQueue>,
    path: &Path,
    rx: &Arc<ChunkQueue>,
    enq_depth: u32,
    first: &mut bool,
    class: IoClass,
    tenant: &TenantId,
    first_service: &mut Option<f64>,
) -> Result<u64, StreamFailure> {
    let dev = &q.device;
    let preempt = q.qos.preempt_chunks;
    let mut file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))
        .map_err(|e| StreamFailure::new(e, false))?;
    let mut total = 0u64;
    let mut chunk_idx = 0usize;
    while let Some(chunk) = rx.pop() {
        let chunk = match chunk {
            Ok(c) => c,
            Err(fail) => return Err(fail.context("stream source failed")),
        };
        if chunk.is_empty() {
            continue;
        }
        if chunk_idx > 0 && preempt != 0 && chunk_idx % preempt == 0 {
            q.yield_to_higher(class);
        }
        chunk_idx += 1;
        // Rate cap (if configured): pause before claiming the device,
        // so a throttled checkpoint stream holds no channel hostage.
        q.bucket_throttle(class, tenant, chunk.len() as u64);
        let depth = if *first {
            dev.service_begin(enq_depth)
        } else {
            let enq = dev.queue_enter();
            dev.service_begin(enq)
        };
        if let Err(e) = dev.fault_gate(Dir::Write) {
            dev.service_end();
            if *first {
                // The submit-time queue membership was consumed by
                // the service_begin/service_end pair above — make
                // sure the caller does not release it again.
                *first = false;
            }
            return Err(StreamFailure::new(e, false));
        }
        if *first {
            // The stream's queue phase ends here: the first chunk
            // holds the device.
            *first_service = Some(q.clock.now());
            dev.latency_phase(Dir::Write, depth);
            *first = false;
        }
        let t0 = q.clock.now();
        let io = file
            .write_all(&chunk)
            .with_context(|| format!("write {}", path.display()));
        if io.is_ok() {
            dev.pace(Dir::Write, chunk.len() as u64, q.clock.now() - t0);
        }
        dev.service_end();
        io.map_err(|e| StreamFailure::new(e, false))?;
        total += chunk.len() as u64;
    }
    Ok(total)
}

/// Source half of a warm copy: read the file in chunks with **no**
/// device pacing (the page cache already holds it) and feed the
/// bounded stream queue.
fn unpaced_file_reader(path: PathBuf, tx: Arc<ChunkQueue>, chunk_size: usize) {
    let result = (|| -> Result<()> {
        let mut file = std::fs::File::open(&path)
            .with_context(|| format!("read {}", path.display()))?;
        loop {
            let mut buf = vec![0u8; chunk_size];
            let n = file
                .read(&mut buf)
                .with_context(|| format!("read {}", path.display()))?;
            if n == 0 {
                return Ok(());
            }
            buf.truncate(n);
            if !tx.push_data(buf) {
                return Ok(()); // consumer aborted
            }
        }
    })();
    if let Err(e) = result {
        // Unpaced reads charge no device, so the error has no stats
        // row of its own: the destination writer counts it.
        tx.push_fail(e, false);
    }
    tx.close();
}

/// Source half of a copy: chunked paced read pushed into the bounded
/// queue.  Claims the source device per chunk (see
/// [`write_stream_paced`] for why), charging the read latency once at
/// the submit-time depth.
#[allow(clippy::too_many_arguments)]
fn copy_reader(
    q: Arc<DeviceQueue>,
    path: PathBuf,
    tx: Arc<ChunkQueue>,
    chunk_size: usize,
    src_enq: u32,
    class: IoClass,
    origin: &'static str,
    tier: Option<u32>,
    tenant: TenantId,
    submitted: f64,
) {
    let dev = &q.device;
    let preempt = q.qos.preempt_chunks;
    let mut first = true;
    let mut first_service: Option<f64> = None;
    let result = (|| -> Result<u64> {
        let mut file = std::fs::File::open(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let mut total = 0u64;
        let mut chunk_idx = 0usize;
        loop {
            if chunk_idx > 0 && preempt != 0 && chunk_idx % preempt == 0 {
                q.yield_to_higher(class);
            }
            chunk_idx += 1;
            // Rate cap: charge a full chunk before claiming the
            // device (the final short chunk is over-charged — the cap
            // errs on the strict side, never the loose one).
            q.bucket_throttle(class, &tenant, chunk_size as u64);
            let mut buf = vec![0u8; chunk_size];
            let depth = if first {
                dev.service_begin(src_enq)
            } else {
                let enq = dev.queue_enter();
                dev.service_begin(enq)
            };
            if let Err(e) = dev.fault_gate(Dir::Read) {
                dev.service_end();
                if first {
                    // Submit-time membership consumed above; the
                    // post-closure queue_leave must not fire.
                    first = false;
                }
                return Err(e);
            }
            if first {
                first_service = Some(q.clock.now());
                dev.latency_phase(Dir::Read, depth);
                first = false;
            }
            let t0 = q.clock.now();
            let io = file
                .read(&mut buf)
                .with_context(|| format!("read {}", path.display()));
            let n = match io {
                Ok(n) => {
                    if n > 0 {
                        dev.pace(Dir::Read, n as u64, q.clock.now() - t0);
                    }
                    dev.service_end();
                    n
                }
                Err(e) => {
                    dev.service_end();
                    return Err(e);
                }
            };
            if n == 0 {
                break;
            }
            buf.truncate(n);
            total += n as u64;
            if !tx.push_data(buf) {
                break; // consumer aborted
            }
        }
        Ok(total)
    })();
    if first {
        // File-open failure: the submit-time membership was never
        // consumed by a read.
        dev.queue_leave();
    }
    // Queue = submit -> first chunk holding the device; the rest is
    // service (mirrors the stream writer's accounting).
    let t_end = q.clock.now();
    let (queue_secs, service_secs) = match first_service {
        Some(ts) => (ts - submitted, t_end - ts),
        None => (t_end - submitted, 0.0),
    };
    q.stream_end(class);
    q.adaptive_observe(class, queue_secs, &tenant);
    // The read half is a request against the source device (its
    // submission was recorded in submit_copy): account the completion
    // — and on failure, charge the error HERE, exactly once, then
    // hand the destination a `counted` failure so the write side
    // fails its ticket without double-counting.
    match result {
        Ok(bytes) => {
            record_done(
                &mut q.stats.lock().unwrap(),
                class,
                tier,
                &tenant,
                queue_secs,
                service_secs,
                Some((bytes, Dir::Read)),
                false,
            );
            q.emit(class, EngineOp::CopyRead, origin, tier, &tenant, bytes,
                   true, submitted, queue_secs, service_secs);
            tx.close();
        }
        Err(e) => {
            record_done(
                &mut q.stats.lock().unwrap(),
                class,
                tier,
                &tenant,
                queue_secs,
                service_secs,
                None,
                true,
            );
            q.emit(class, EngineOp::CopyRead, origin, tier, &tenant, 0,
                   false, submitted, queue_secs, service_secs);
            tx.push_fail(e, true);
            tx.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::device::{DeviceModel, NullObserver};
    use std::time::Instant;

    fn model(name: &str, channels: usize, time_scale: f64) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels,
            elevator: vec![(1, 1.0)],
            time_scale,
            lat_tables: None,
        }
    }

    fn engine_with(
        models: Vec<DeviceModel>,
        chunk: usize,
    ) -> (IoEngine, HashMap<String, Arc<Device>>) {
        engine_with_qos(models, chunk, QosConfig::default())
    }

    fn engine_with_qos(
        models: Vec<DeviceModel>,
        chunk: usize,
        qos: QosConfig,
    ) -> (IoEngine, HashMap<String, Arc<Device>>) {
        let mut devices = HashMap::new();
        for m in models {
            devices.insert(
                m.name.clone(),
                Arc::new(Device::new(m, Arc::new(NullObserver))),
            );
        }
        let engine = IoEngine::with_config(&devices, chunk, qos);
        (engine, devices)
    }

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dlio-engine-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_then_read_roundtrip() {
        let (eng, _) = engine_with(vec![model("d", 4, 1000.0)], 8 * 1024);
        let dir = scratch("rw");
        let path = dir.join("x.bin");
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let t = eng
            .submit(IoRequest::WriteFile {
                device: "d".into(),
                path: path.clone(),
                data: payload.clone(),
            })
            .unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.bytes, payload.len() as u64);
        let t = eng
            .submit(IoRequest::ReadFile { device: "d".into(), path })
            .unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.data.unwrap(), payload);
    }

    #[test]
    fn submit_is_asynchronous() {
        // A slow device (50 ms of modelled transfer) must not block
        // submit(): the ticket returns immediately and resolves later.
        let mut m = model("slow", 1, 1.0);
        m.read_bw = 20e6; // 1 MB at 20 MB/s = 50 ms
        let (eng, _) = engine_with(vec![m], 256 * 1024);
        let t0 = Instant::now();
        let t = eng
            .submit(IoRequest::ProbeRead { device: "slow".into(), bytes: 1_000_000 })
            .unwrap();
        assert!(
            t0.elapsed().as_secs_f64() < 0.03,
            "submit blocked: {:?}",
            t0.elapsed()
        );
        t.wait().unwrap();
        assert!(t0.elapsed().as_secs_f64() > 0.03, "no pacing applied");
    }

    #[test]
    fn unknown_device_rejected_at_submit() {
        let (eng, _) = engine_with(vec![model("d", 1, 1000.0)], 8 * 1024);
        assert!(eng
            .submit(IoRequest::ProbeRead { device: "nope".into(), bytes: 1 })
            .is_err());
    }

    #[test]
    fn read_missing_file_fails_ticket_not_engine() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 8 * 1024);
        let dir = scratch("missing");
        let t = eng
            .submit(IoRequest::ReadFile {
                device: "d".into(),
                path: dir.join("absent.bin"),
            })
            .unwrap();
        assert!(t.wait().is_err());
        // The engine keeps serving after a failed request.
        let t = eng
            .submit(IoRequest::ProbeRead { device: "d".into(), bytes: 1024 })
            .unwrap();
        assert!(t.wait().is_ok());
    }

    #[test]
    fn copy_larger_than_chunk_roundtrips_bit_exact() {
        // Satellite: chunked cross-device copy, payload >> chunk.
        let chunk = 16 * 1024;
        let (eng, _) = engine_with(
            vec![model("a", 2, 1000.0), model("b", 2, 1000.0)],
            chunk,
        );
        let dir = scratch("copy");
        let src = dir.join("src.bin");
        let dst = dir.join("dst.bin");
        let mut payload = vec![0u8; chunk * 7 + 311]; // not chunk-aligned
        for (i, b) in payload.iter_mut().enumerate() {
            *b = (i * 31 % 257) as u8;
        }
        std::fs::write(&src, &payload).unwrap();
        let t = eng
            .submit(IoRequest::Copy {
                src_device: "a".into(),
                src_path: src,
                dst_device: "b".into(),
                dst_path: dst.clone(),
            })
            .unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.bytes, payload.len() as u64);
        assert_eq!(std::fs::read(&dst).unwrap(), payload);
        // Stream memory stayed bounded by the window, not file size.
        assert!(
            eng.peak_stream_bytes() <= (chunk * (STREAM_WINDOW + 1)) as u64,
            "peak {} exceeds window {}",
            eng.peak_stream_bytes(),
            chunk * (STREAM_WINDOW + 1)
        );
    }

    #[test]
    fn same_device_copy_does_not_deadlock() {
        let chunk = 8 * 1024;
        let (eng, _) = engine_with(vec![model("one", 1, 1000.0)], chunk);
        let dir = scratch("selfcopy");
        let src = dir.join("src.bin");
        let payload = vec![7u8; chunk * 5];
        std::fs::write(&src, &payload).unwrap();
        let t = eng
            .submit(IoRequest::Copy {
                src_device: "one".into(),
                src_path: src,
                dst_device: "one".into(),
                dst_path: dir.join("dst.bin"),
            })
            .unwrap();
        assert_eq!(t.wait().unwrap().bytes, payload.len() as u64);
    }

    #[test]
    fn stream_write_assembles_chunks_in_order() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 4 * 1024);
        let dir = scratch("stream");
        let path = dir.join("s.bin");
        let (mut w, t) = eng.write_stream("d", path.clone()).unwrap();
        let mut expect = Vec::new();
        for i in 0..40u32 {
            let piece = vec![(i % 256) as u8; 700]; // misaligned pieces
            w.push(&piece).unwrap();
            expect.extend_from_slice(&piece);
        }
        w.finish().unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.bytes, expect.len() as u64);
        assert_eq!(std::fs::read(&path).unwrap(), expect);
    }

    #[test]
    fn dropped_stream_writer_fails_the_ticket() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 4 * 1024);
        let dir = scratch("dropstream");
        let (mut w, t) = eng.write_stream("d", dir.join("s.bin")).unwrap();
        w.push(&[1u8; 100]).unwrap();
        drop(w); // no finish()
        assert!(t.wait().is_err());
        // The abandoned stream is one failed request: one error.
        let s = &eng.stats()[0];
        assert_eq!(s.errors, 1);
        assert_eq!(s.class(IoClass::Checkpoint).errors, 1);
    }

    #[test]
    fn overlapped_submissions_beat_serial_on_latency_device() {
        // 20 ms latency, 4 channels: 4 overlapped probes ≈ 1 serial.
        let mut m = model("lat", 4, 1.0);
        m.read_lat = 0.02;
        m.read_bw = 1e12;
        let (eng, _) = engine_with(vec![m], 64 * 1024);
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..4)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead { device: "lat".into(), bytes: 1 })
                    .unwrap()
            })
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let overlapped = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        for _ in 0..4 {
            eng.submit(IoRequest::ProbeRead { device: "lat".into(), bytes: 1 })
                .unwrap()
                .wait()
                .unwrap();
        }
        let serial = t0.elapsed().as_secs_f64();
        assert!(
            overlapped < serial * 0.7,
            "overlapped {overlapped:.4}s !< serial {serial:.4}s"
        );
    }

    #[test]
    fn stats_record_queue_and_service_per_device() {
        let (eng, _) = engine_with(vec![model("d", 1, 1000.0)], 8 * 1024);
        for _ in 0..3 {
            eng.submit(IoRequest::ProbeWrite { device: "d".into(), bytes: 100_000 })
                .unwrap()
                .wait()
                .unwrap();
        }
        let stats = eng.stats();
        assert_eq!(stats.len(), 1);
        let s = &stats[0];
        assert_eq!(s.device, "d");
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 3);
        assert_eq!(s.errors, 0);
        assert_eq!(s.bytes_written, 300_000);
        assert!(s.service_secs >= 0.0 && s.queue_secs >= 0.0);
        assert!(s.max_queue_depth >= 1);
    }

    #[test]
    fn batch_doorbell_shares_burst_elevator_gain() {
        // Single-channel 20 ms-latency device with elevator gain: a
        // batched triple must beat three serial submissions because
        // every member sees the burst depth (gain ~1.67 at depth 3).
        let mut m = model("elev", 1, 1.0);
        m.read_lat = 0.02;
        m.read_bw = 1e12;
        m.elevator = vec![(1, 1.0), (4, 2.0)];
        let (eng, _) = engine_with(vec![m], 64 * 1024);
        let t0 = Instant::now();
        for _ in 0..3 {
            eng.submit(IoRequest::ProbeRead { device: "elev".into(), bytes: 1 })
                .unwrap()
                .wait()
                .unwrap();
        }
        let serial = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let tickets = eng
            .submit_batch(
                (0..3)
                    .map(|_| IoRequest::ProbeRead {
                        device: "elev".into(),
                        bytes: 1,
                    })
                    .collect(),
            )
            .unwrap();
        for t in tickets {
            t.wait().unwrap();
        }
        let batched = t0.elapsed().as_secs_f64();
        // Modelled: serial 60 ms vs batched ~36 ms.
        assert!(
            batched < serial * 0.8,
            "batched {batched:.4}s !< serial {serial:.4}s"
        );
    }

    #[test]
    fn queued_submissions_raise_observed_depth() {
        // A single-channel device with many outstanding requests must
        // report a deep queue (what the elevator model feeds on).
        let mut m = model("q", 1, 1.0);
        m.read_bw = 50e6; // each 500 KB probe ≈ 10 ms
        let (eng, devices) = engine_with(vec![m], 64 * 1024);
        let tickets: Vec<_> = (0..6)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead { device: "q".into(), bytes: 500_000 })
                    .unwrap()
            })
            .collect();
        // While the first is in service, the rest are queued.
        let depth_seen = devices["q"].queue_depth();
        for t in tickets {
            t.wait().unwrap();
        }
        assert!(depth_seen >= 4, "depth {depth_seen}");
        assert_eq!(devices["q"].queue_depth(), 0, "gate drained");
        let s = &eng.stats()[0];
        assert!(s.max_queue_depth >= 4, "stat depth {}", s.max_queue_depth);
    }

    // -- satellite: every failed request counts exactly one error ----

    #[test]
    fn copy_read_failure_counts_error_exactly_once() {
        let (eng, _) = engine_with(
            vec![model("a", 2, 1000.0), model("b", 2, 1000.0)],
            8 * 1024,
        );
        let dir = scratch("copyerr");
        let t = eng
            .submit(IoRequest::Copy {
                src_device: "a".into(),
                src_path: dir.join("absent.bin"),
                dst_device: "b".into(),
                dst_path: dir.join("dst.bin"),
            })
            .unwrap();
        assert!(t.wait().is_err());
        let stats = eng.stats(); // sorted: a, b
        let (a, b) = (&stats[0], &stats[1]);
        // The failing read half charges the source device, once; the
        // destination write half fails its ticket WITHOUT recounting.
        assert_eq!(a.errors, 1, "src errors");
        assert_eq!(b.errors, 0, "dst must not double-count");
        assert_eq!(a.errors + b.errors, 1, "exactly once");
        assert_eq!(a.submitted, 1);
        assert_eq!(a.completed, 1);
        assert_eq!(b.submitted, 1);
        assert_eq!(b.completed, 1);
        assert_eq!(a.class(IoClass::Drain).errors, 1);
        assert_eq!(b.class(IoClass::Drain).errors, 0);
    }

    #[test]
    fn warm_copy_read_failure_counts_on_destination() {
        // write_from_file has no paced read half, so its source
        // failure is charged to the destination — still exactly once.
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 8 * 1024);
        let dir = scratch("warmerr");
        let t = eng
            .write_from_file("d", dir.join("absent.bin"), dir.join("out.bin"))
            .unwrap();
        assert!(t.wait().is_err());
        let s = &eng.stats()[0];
        assert_eq!(s.errors, 1);
        assert_eq!(s.class(IoClass::Drain).errors, 1);
    }

    #[test]
    fn failed_chunked_read_counts_error_once() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 8 * 1024);
        let dir = scratch("readerr");
        let t = eng
            .submit(IoRequest::ReadFile {
                device: "d".into(),
                path: dir.join("absent.bin"),
            })
            .unwrap();
        assert!(t.wait().is_err());
        let s = &eng.stats()[0];
        assert_eq!(s.errors, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.class(IoClass::Ingest).errors, 1);
    }

    // -- satellite: queue depth tracked beyond submit-time samples ---

    #[test]
    fn max_depth_sees_copy_read_halves_and_is_monotone() {
        // Three concurrent copies raise the SOURCE device's queue to 3
        // at submit time, but no unit submit ever samples that side:
        // the entry-side peak gauge must catch it.
        let mut src = model("src", 1, 1.0);
        src.read_lat = 0.002;
        let (eng, devices) =
            engine_with(vec![src, model("dst", 4, 1.0)], 8 * 1024);
        let dir = scratch("depthcopy");
        let file = dir.join("s.bin");
        std::fs::write(&file, vec![3u8; 8 * 1024]).unwrap();
        let tickets: Vec<_> = (0..3)
            .map(|i| {
                eng.submit(IoRequest::Copy {
                    src_device: "src".into(),
                    src_path: file.clone(),
                    dst_device: "dst".into(),
                    dst_path: dir.join(format!("d{i}.bin")),
                })
                .unwrap()
            })
            .collect();
        // Mid-flight snapshot, then settle.
        let mid = eng.stats();
        let mid_src = mid.iter().find(|s| s.device == "src").unwrap().clone();
        for t in tickets {
            t.wait().unwrap();
        }
        let fin = eng.stats();
        let fin_src = fin.iter().find(|s| s.device == "src").unwrap();
        // All three memberships were taken synchronously at submit.
        assert!(
            fin_src.max_queue_depth >= 3,
            "src depth {} missed the copy read halves",
            fin_src.max_queue_depth
        );
        // Monotone across snapshots, and never below the live gate.
        assert!(fin_src.max_queue_depth >= mid_src.max_queue_depth);
        assert!(fin_src.max_queue_depth >= devices["src"].queue_depth());
    }

    // -- tentpole: per-class stats + DRR isolation -------------------

    #[test]
    fn per_class_stats_tag_rows_by_class() {
        let (eng, _) = engine_with(vec![model("d", 4, 1000.0)], 8 * 1024);
        eng.submit(IoRequest::ProbeRead { device: "d".into(), bytes: 1000 })
            .unwrap()
            .wait()
            .unwrap();
        eng.submit(IoRequest::ProbeWrite { device: "d".into(), bytes: 2000 })
            .unwrap()
            .wait()
            .unwrap();
        eng.submit_class(
            IoRequest::ProbeRead { device: "d".into(), bytes: 3000 },
            IoClass::Background,
        )
        .unwrap()
        .wait()
        .unwrap();
        let s = &eng.stats()[0];
        assert_eq!(s.class(IoClass::Ingest).completed, 1);
        assert_eq!(s.class(IoClass::Ingest).bytes_read, 1000);
        assert_eq!(s.class(IoClass::Checkpoint).completed, 1);
        assert_eq!(s.class(IoClass::Checkpoint).bytes_written, 2000);
        assert_eq!(s.class(IoClass::Background).completed, 1);
        assert_eq!(s.class(IoClass::Background).bytes_read, 3000);
        assert_eq!(s.class(IoClass::Drain).completed, 0);
        // Aggregates are the sum of the class rows.
        let sum: u64 = IoClass::ALL.iter().map(|c| s.class(*c).completed).sum();
        assert_eq!(s.completed, sum);
        assert_eq!(s.class(IoClass::Ingest).queue_hist.count(), 1);
    }

    /// Mixed checkpoint+ingest load; returns (ingest p99 queue secs,
    /// checkpoint makespan secs).
    fn isolation_run(qos: QosConfig) -> (f64, f64) {
        // 1-channel 50 MB/s device: each 250 KB checkpoint write is
        // ~5 ms of modelled service, each 50 KB ingest read ~1 ms.
        let mut m = model("d", 1, 1.0);
        m.read_bw = 50e6;
        m.write_bw = 50e6;
        let (eng, _) = engine_with_qos(vec![m], 64 * 1024, qos);
        let t0 = Instant::now();
        let writes: Vec<_> = (0..10)
            .map(|_| {
                eng.submit(IoRequest::ProbeWrite {
                    device: "d".into(),
                    bytes: 250_000,
                })
                .unwrap()
            })
            .collect();
        let reads: Vec<_> = (0..4)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "d".into(),
                    bytes: 50_000,
                })
                .unwrap()
            })
            .collect();
        for t in writes {
            t.wait().unwrap();
        }
        let ckpt_makespan = t0.elapsed().as_secs_f64();
        for t in reads {
            t.wait().unwrap();
        }
        let s = &eng.stats()[0];
        assert_eq!(s.class(IoClass::Ingest).completed, 4);
        assert_eq!(s.class(IoClass::Checkpoint).completed, 10);
        (s.class(IoClass::Ingest).p99_queue_secs(), ckpt_makespan)
    }

    #[test]
    fn drr_halves_ingest_tail_latency_under_checkpoint_backlog() {
        // FIFO: ingest reads submitted behind a 50 ms checkpoint
        // backlog wait for all of it.  DRR: they are served after the
        // in-flight write, ~an order of magnitude earlier — the §V
        // interference the QoS layer exists to remove.
        let (fifo_p99, fifo_makespan) = isolation_run(QosConfig::fifo());
        let (drr_p99, drr_makespan) = isolation_run(QosConfig::default());
        assert!(
            drr_p99 <= 0.5 * fifo_p99,
            "ingest p99 {:.1} ms !<= 0.5 * fifo {:.1} ms",
            drr_p99 * 1e3,
            fifo_p99 * 1e3
        );
        // Work conservation: prioritizing ~4 ms of reads costs the
        // checkpoint stream at most that plus noise.
        assert!(
            drr_makespan <= 1.25 * fifo_makespan,
            "checkpoint makespan {:.1} ms degraded past 25% vs {:.1} ms",
            drr_makespan * 1e3,
            fifo_makespan * 1e3
        );
    }

    #[test]
    fn background_still_completes_under_ingest_flood() {
        // 12 x 4 ms ingest reads saturate the single channel; DRR's
        // per-round quantum still serves the background probe within a
        // couple of rounds instead of after the whole flood.
        let mut m = model("d", 1, 1.0);
        m.read_bw = 50e6;
        let (eng, _) = engine_with_qos(vec![m], 8 * 1024, QosConfig::default());
        let reads: Vec<_> = (0..12)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "d".into(),
                    bytes: 200_000,
                })
                .unwrap()
            })
            .collect();
        let bg = eng
            .submit_class(
                IoRequest::ProbeRead { device: "d".into(), bytes: 10_000 },
                IoClass::Background,
            )
            .unwrap();
        bg.wait().unwrap();
        for t in reads {
            t.wait().unwrap();
        }
        let s = &eng.stats()[0];
        assert_eq!(s.class(IoClass::Background).completed, 1);
        assert_eq!(s.class(IoClass::Background).errors, 0);
        // Served mid-flood, not starved until the end of it.
        let bg_wait = s.class(IoClass::Background).mean_queue_secs();
        let ingest_tail = s.class(IoClass::Ingest).p99_queue_secs();
        assert!(
            bg_wait <= 0.6 * ingest_tail,
            "background waited {:.1} ms vs ingest tail {:.1} ms — starved",
            bg_wait * 1e3,
            ingest_tail * 1e3
        );
    }

    #[test]
    fn checkpoint_stream_yields_to_ingest_at_chunk_boundaries() {
        // 1-channel 20 MB/s device, 64 KB chunks (~3.2 ms each): a
        // 24-chunk checkpoint stream with preemption every 2 chunks
        // must let 3 ingest reads through long before it finishes.
        let mut m = model("d", 1, 1.0);
        m.read_bw = 20e6;
        m.write_bw = 20e6;
        let qos = QosConfig {
            preempt_chunks: 2,
            max_yield_wait: 0.5,
            ..QosConfig::default()
        };
        let (eng, _) = engine_with_qos(vec![m], 64 * 1024, qos);
        let dir = scratch("yield");
        let (mut w, stream_ticket) =
            eng.write_stream("d", dir.join("ck.data")).unwrap();
        let piece = vec![9u8; 64 * 1024];
        for _ in 0..6 {
            w.push(&piece).unwrap();
        }
        // Stream is mid-flight (the window bounds how far ahead the
        // producer can run): ingest arrives now.
        let reads: Vec<_> = (0..3)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "d".into(),
                    bytes: 64 * 1024,
                })
                .unwrap()
            })
            .collect();
        for _ in 6..24 {
            w.push(&piece).unwrap();
        }
        w.finish().unwrap();
        // The producer only finishes pushing once the consumer has
        // drained most of the stream — by which point the preemption
        // points must have let every read through.
        for t in &reads {
            assert!(t.ready(), "ingest read still queued behind the stream");
        }
        assert!(
            !stream_ticket.ready(),
            "stream finished before its tail chunks — can't witness yields"
        );
        let c = stream_ticket.wait().unwrap();
        assert_eq!(c.bytes, 24 * 64 * 1024);
        let s = &eng.stats()[0];
        // Reads cut in at a chunk boundary: their tail wait is a small
        // fraction of the stream's total service time.
        assert!(
            s.class(IoClass::Ingest).p99_queue_secs() <= 0.5 * c.service_secs,
            "ingest p99 {:.1} ms vs stream service {:.1} ms",
            s.class(IoClass::Ingest).p99_queue_secs() * 1e3,
            c.service_secs * 1e3
        );
    }

    // -- satellite: expired yield deadlines must not panic -----------

    #[test]
    fn zero_or_negative_max_yield_wait_never_panics() {
        // Regression: the drain wait computed `deadline - now`, which
        // panics once the deadline has passed; a zero (or negative)
        // max_yield_wait put the deadline in the past immediately.
        for bound in [0.0, -1.0] {
            let qos = QosConfig {
                preempt_chunks: 1,
                max_yield_wait: bound,
                ..QosConfig::default()
            };
            let (eng, _) =
                engine_with_qos(vec![model("d", 1, 1000.0)], 4 * 1024, qos);
            let dir = scratch(&format!("zeroyield{}", bound as i64));
            let (mut w, t) = eng.write_stream("d", dir.join("s.bin")).unwrap();
            // Queue ingest work so the yield predicate is true when
            // the stream hits its (every-chunk) preemption points.
            let reads: Vec<_> = (0..4)
                .map(|_| {
                    eng.submit(IoRequest::ProbeRead {
                        device: "d".into(),
                        bytes: 50_000,
                    })
                    .unwrap()
                })
                .collect();
            for _ in 0..12 {
                w.push(&vec![1u8; 4 * 1024]).unwrap();
            }
            w.finish().unwrap();
            assert_eq!(t.wait().unwrap().bytes, 12 * 4 * 1024);
            for r in reads {
                r.wait().unwrap();
            }
        }
    }

    // -- tentpole: per-class token-bucket rate caps ------------------

    #[test]
    fn capped_checkpoint_respects_rate_while_ingest_proceeds() {
        // Fast device (1 GB/s, no latency) so the only brake on the
        // checkpoint class is its 20 MB/s cap; ingest is uncapped.
        let m = model("d", 2, 1.0);
        let qos = QosConfig::default().with_rate_cap(
            IoClass::Checkpoint,
            20e6,
            64 * 1024,
        );
        let (eng, _) = engine_with_qos(vec![m], 64 * 1024, qos);
        let t0 = Instant::now();
        let writes: Vec<_> = (0..40)
            .map(|_| {
                eng.submit(IoRequest::ProbeWrite {
                    device: "d".into(),
                    bytes: 100_000,
                })
                .unwrap()
            })
            .collect();
        let reads: Vec<_> = (0..8)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "d".into(),
                    bytes: 100_000,
                })
                .unwrap()
            })
            .collect();
        for r in reads {
            r.wait().unwrap();
        }
        let ingest_done = t0.elapsed().as_secs_f64();
        for w in writes {
            w.wait().unwrap();
        }
        let ckpt_done = t0.elapsed().as_secs_f64();
        // 4 MB through a 20 MB/s cap: the long-run rate must stay
        // within 1.1x of the cap (the burst + one in-flight job are
        // the only slack, and 4 MB dwarfs both).  Host stalls only
        // lengthen the window, which keeps the bound safe.
        let achieved = 4_000_000.0 / ckpt_done;
        assert!(
            achieved <= 1.1 * 20e6,
            "capped class ran at {:.1} MB/s, cap 20 MB/s",
            achieved / 1e6
        );
        // The uncapped class must not be dragged down by the debt.
        assert!(
            ingest_done <= 0.5 * ckpt_done,
            "ingest took {ingest_done:.3}s vs capped ckpt {ckpt_done:.3}s"
        );
        let s = &eng.stats()[0];
        assert_eq!(s.class(IoClass::Checkpoint).completed, 40);
        assert_eq!(s.class(IoClass::Ingest).completed, 8);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn empty_bucket_class_does_not_starve_scheduler_round() {
        // Regression on the DRR cursor: a checkpoint backlog whose
        // bucket is dry must be *skipped* — not visited forever — so
        // ingest and background still flow at device speed.
        let m = model("d", 1, 1.0);
        let qos = QosConfig::default().with_rate_cap(
            IoClass::Checkpoint,
            1e6,
            1024,
        );
        let (eng, _) = engine_with_qos(vec![m], 8 * 1024, qos);
        // 4 x 50 KB checkpoint probes: the first rides the 1 KB burst
        // through, the rest wait out ~50 ms of debt each.
        let writes: Vec<_> = (0..4)
            .map(|_| {
                eng.submit(IoRequest::ProbeWrite {
                    device: "d".into(),
                    bytes: 50_000,
                })
                .unwrap()
            })
            .collect();
        let t0 = Instant::now();
        let mut others: Vec<IoTicket> = (0..8)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "d".into(),
                    bytes: 50_000,
                })
                .unwrap()
            })
            .collect();
        others.push(
            eng.submit_class(
                IoRequest::ProbeRead { device: "d".into(), bytes: 10_000 },
                IoClass::Background,
            )
            .unwrap(),
        );
        for t in others {
            t.wait().unwrap();
        }
        let others_done = t0.elapsed().as_secs_f64();
        // Uncapped classes finished while the capped backlog was
        // still throttled (its bucket pays off ~50 ms of debt per
        // remaining write)...
        let s = &eng.stats()[0];
        assert!(
            s.class(IoClass::Checkpoint).completed < 4,
            "checkpoint backlog drained implausibly fast \
             (cap not enforced?)"
        );
        // ...and the capped class still completes (skipped, not
        // starved).
        for w in writes {
            w.wait().unwrap();
        }
        let ckpt_done = t0.elapsed().as_secs_f64();
        // Relative, noise-robust bound: the uncapped classes beat the
        // throttled drain by a wide margin instead of waiting out the
        // whole round on a dry bucket (the pre-fix failure mode).
        assert!(
            others_done <= 0.5 * ckpt_done,
            "ingest/background ({others_done:.3}s) stalled behind a \
             dry-bucket class draining over {ckpt_done:.3}s"
        );
        let s = &eng.stats()[0];
        assert_eq!(s.class(IoClass::Checkpoint).completed, 4);
        assert_eq!(s.errors, 0);
    }

    // -- tentpole: AIMD adaptive ingest weight -----------------------

    #[test]
    fn adaptive_weight_rises_under_contention_then_decays() {
        // Contention phase: a saturating mixed backlog drives ingest
        // queue waits far past the 3 ms (modelled == wall here)
        // target, so the controller must walk the weight up.
        let mut m = model("d", 1, 1.0);
        m.read_bw = 50e6;
        m.write_bw = 50e6;
        let qos = QosConfig::adaptive(0.003);
        let (eng, _) = engine_with_qos(vec![m], 64 * 1024, qos);
        let base = QosConfig::default().weights[IoClass::Ingest.index()];
        let writes: Vec<_> = (0..6)
            .map(|_| {
                eng.submit(IoRequest::ProbeWrite {
                    device: "d".into(),
                    bytes: 500_000,
                })
                .unwrap()
            })
            .collect();
        let reads: Vec<_> = (0..20)
            .map(|_| {
                eng.submit(IoRequest::ProbeRead {
                    device: "d".into(),
                    bytes: 100_000,
                })
                .unwrap()
            })
            .collect();
        for t in reads {
            t.wait().unwrap();
        }
        for t in writes {
            t.wait().unwrap();
        }
        let hot = eng.stats().remove(0);
        assert!(
            !hot.weight_trajectory.is_empty(),
            "controller recorded no trajectory"
        );
        // The trajectory's peak proves the controller reacted; the
        // *final* weight may already have decayed while the write
        // backlog drained (cold ticks), so assert on the peak.
        let peak = hot
            .weight_trajectory
            .iter()
            .map(|&(_, w)| w)
            .max()
            .unwrap();
        assert!(
            peak > base,
            "ingest weight peaked at {peak}, never above base {base}"
        );
        // Cool-down phase: sporadic uncontended reads wait ~0, so
        // each tick decays the weight back toward base.
        for _ in 0..8 {
            eng.submit(IoRequest::ProbeRead {
                device: "d".into(),
                bytes: 1_000,
            })
            .unwrap()
            .wait()
            .unwrap();
            std::thread::sleep(Duration::from_millis(12));
        }
        let cold = eng.stats().remove(0);
        assert!(
            cold.ingest_weight < peak,
            "weight {} did not decay from peak {peak}",
            cold.ingest_weight
        );
    }

    // -- tentpole: request-level event stream ------------------------

    struct Sink(Mutex<Vec<EngineEvent>>);

    impl EngineObserver for Sink {
        fn record(&self, e: EngineEvent) {
            self.0.lock().unwrap().push(e);
        }
    }

    #[test]
    fn observer_sees_every_request_kind_with_origin() {
        let (eng, _) = engine_with(vec![model("d", 4, 1000.0)], 8 * 1024);
        let sink = Arc::new(Sink(Mutex::new(Vec::new())));
        eng.set_observer(Arc::clone(&sink) as Arc<dyn EngineObserver>);
        let dir = scratch("events");
        let path = dir.join("x.bin");
        with_origin("saver", || {
            eng.submit(IoRequest::WriteFile {
                device: "d".into(),
                path: path.clone(),
                data: vec![1u8; 10_000],
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        eng.submit(IoRequest::ReadFile { device: "d".into(), path: path.clone() })
            .unwrap()
            .wait()
            .unwrap();
        eng.submit(IoRequest::ProbeRead { device: "d".into(), bytes: 512 })
            .unwrap()
            .wait()
            .unwrap();
        eng.submit(IoRequest::Copy {
            src_device: "d".into(),
            src_path: path,
            dst_device: "d".into(),
            dst_path: dir.join("y.bin"),
        })
        .unwrap()
        .wait()
        .unwrap();
        eng.clear_observer();
        // Detached: this request must produce no event.
        eng.submit(IoRequest::ProbeWrite { device: "d".into(), bytes: 64 })
            .unwrap()
            .wait()
            .unwrap();
        let evs = sink.0.lock().unwrap();
        assert_eq!(
            evs.len(),
            5,
            "write + read + probe + copy (2 halves), none after detach"
        );
        let w = evs.iter().find(|e| e.op == EngineOp::Write).unwrap();
        assert_eq!(w.origin, "saver", "origin tag lost");
        assert_eq!(w.bytes, 10_000);
        assert_eq!(w.class, IoClass::Checkpoint);
        assert!(w.ok);
        let r = evs.iter().find(|e| e.op == EngineOp::Read).unwrap();
        assert_eq!(r.bytes, 10_000);
        assert_eq!(r.origin, "", "untagged submit must stay untagged");
        assert_eq!(r.class, IoClass::Ingest);
        let cr = evs.iter().find(|e| e.op == EngineOp::CopyRead).unwrap();
        assert_eq!(cr.class, IoClass::Drain);
        assert_eq!(cr.bytes, 10_000);
        let sw = evs.iter().find(|e| e.op == EngineOp::StreamWrite).unwrap();
        assert_eq!(sw.bytes, 10_000);
        for e in evs.iter() {
            assert!(e.submit_secs >= 0.0, "{e:?}");
            assert!(e.queue_secs >= 0.0 && e.service_secs >= 0.0, "{e:?}");
        }
    }

    #[test]
    fn failed_request_event_carries_intended_bytes() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 8 * 1024);
        let sink = Arc::new(Sink(Mutex::new(Vec::new())));
        eng.set_observer(Arc::clone(&sink) as Arc<dyn EngineObserver>);
        let dir = scratch("evfail");
        assert!(eng
            .submit(IoRequest::ReadFile {
                device: "d".into(),
                path: dir.join("absent.bin"),
            })
            .unwrap()
            .wait()
            .is_err());
        let evs = sink.0.lock().unwrap();
        assert_eq!(evs.len(), 1);
        assert!(!evs[0].ok);
        // A stat-less read's intended size falls back to the DRR cost
        // (the chunk size) — non-zero, so a replay still offers load.
        assert!(evs[0].bytes > 0, "failed event lost its load size");
    }

    #[test]
    fn class_and_op_names_roundtrip() {
        for c in IoClass::ALL {
            assert_eq!(IoClass::parse(c.name()), Some(c));
        }
        assert_eq!(IoClass::parse("nope"), None);
        for o in EngineOp::ALL {
            assert_eq!(EngineOp::parse(o.name()), Some(o));
        }
        assert_eq!(EngineOp::parse("nope"), None);
        assert_eq!(EngineOp::CopyRead.dir(), Dir::Read);
        assert_eq!(EngineOp::StreamWrite.dir(), Dir::Write);
    }

    #[test]
    fn with_origin_scopes_nest_and_restore() {
        assert_eq!(current_origin(), "");
        with_origin("outer", || {
            assert_eq!(current_origin(), "outer");
            with_origin("inner", || assert_eq!(current_origin(), "inner"));
            assert_eq!(current_origin(), "outer");
        });
        assert_eq!(current_origin(), "");
    }

    #[test]
    fn with_tier_scopes_nest_and_restore() {
        assert_eq!(current_tier(), None);
        with_tier(0, || {
            assert_eq!(current_tier(), Some(0));
            with_tier(3, || assert_eq!(current_tier(), Some(3)));
            assert_eq!(current_tier(), Some(0));
        });
        assert_eq!(current_tier(), None);
    }

    // -- tentpole: hierarchy tier tags on events + stats rows --------

    #[test]
    fn tier_tag_lands_on_events_and_per_tier_stats_rows() {
        let (eng, _) = engine_with(vec![model("d", 4, 1000.0)], 8 * 1024);
        let sink = Arc::new(Sink(Mutex::new(Vec::new())));
        eng.set_observer(Arc::clone(&sink) as Arc<dyn EngineObserver>);
        let dir = scratch("tiertag");
        let path = dir.join("x.bin");
        // Tier 0 write, tier 1 copy (both halves carry the
        // destination tier), one untiered probe.
        with_tier(0, || {
            eng.submit(IoRequest::WriteFile {
                device: "d".into(),
                path: path.clone(),
                data: vec![7u8; 5_000],
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        with_tier(1, || {
            eng.submit_class(
                IoRequest::Copy {
                    src_device: "d".into(),
                    src_path: path.clone(),
                    dst_device: "d".into(),
                    dst_path: dir.join("y.bin"),
                },
                IoClass::Drain,
            )
            .unwrap()
            .wait()
            .unwrap();
        });
        eng.submit(IoRequest::ProbeRead { device: "d".into(), bytes: 256 })
            .unwrap()
            .wait()
            .unwrap();
        eng.clear_observer();
        let evs = sink.0.lock().unwrap();
        let w = evs.iter().find(|e| e.op == EngineOp::Write).unwrap();
        assert_eq!(w.tier, Some(0), "write lost its tier tag");
        let cr = evs.iter().find(|e| e.op == EngineOp::CopyRead).unwrap();
        assert_eq!(cr.tier, Some(1), "copy read half: destination tier");
        let sw = evs.iter().find(|e| e.op == EngineOp::StreamWrite).unwrap();
        assert_eq!(sw.tier, Some(1), "copy write half: destination tier");
        let p = evs.iter().find(|e| e.op == EngineOp::ProbeRead).unwrap();
        assert_eq!(p.tier, None, "untiered submit must stay untiered");
        // Stats: one row per tier, sorted, with byte attribution.
        let stats = eng.stats();
        let s = stats.iter().find(|s| s.device == "d").unwrap();
        let tiers: Vec<u32> = s.tiers.iter().map(|t| t.tier).collect();
        assert_eq!(tiers, vec![0, 1]);
        let t0 = s.tier(0).unwrap();
        assert_eq!(t0.completed, 1);
        assert_eq!(t0.bytes_written, 5_000);
        let t1 = s.tier(1).unwrap();
        assert_eq!(t1.completed, 2, "both copy halves account to tier 1");
        assert_eq!(t1.bytes_read, 5_000);
        assert_eq!(t1.bytes_written, 5_000);
        assert!(s.tier(2).is_none());
        // reset_stats clears the tier rows with everything else.
        eng.reset_stats();
        let stats = eng.stats();
        let s = stats.iter().find(|s| s.device == "d").unwrap();
        assert!(s.tiers.is_empty());
    }

    // -- satellite: per-device adaptive controller targets -----------

    #[test]
    fn adaptive_target_resolves_per_device() {
        let mut qos = QosConfig::adaptive(0.010);
        if let Some(a) = &mut qos.adaptive {
            a.per_device.push(("fast".into(), 0.001));
        }
        let a = qos.adaptive.as_ref().unwrap();
        assert_eq!(a.target_for("fast"), 0.001);
        assert_eq!(a.target_for("anything-else"), 0.010);
        // The engine resolves per device at construction and still
        // schedules (smoke: the controller path runs with overrides).
        let (eng, _) = engine_with_qos(
            vec![model("fast", 2, 1000.0), model("slow", 2, 1000.0)],
            8 * 1024,
            qos,
        );
        for d in ["fast", "slow"] {
            eng.submit(IoRequest::ProbeRead { device: d.into(), bytes: 1024 })
                .unwrap()
                .wait()
                .unwrap();
        }
    }

    #[test]
    fn parse_mode_matches_mode_names() {
        for mode in ["fifo", "static", "adaptive"] {
            let qos = QosConfig::parse_mode(mode, 0.005).unwrap();
            assert_eq!(qos.mode_name(), mode);
        }
        assert!(QosConfig::parse_mode("banana", 0.005).is_err());
    }

    #[test]
    fn reset_stats_clears_counters_between_phases() {
        let (eng, _) = engine_with(vec![model("d", 2, 1000.0)], 8 * 1024);
        for _ in 0..3 {
            eng.submit(IoRequest::ProbeWrite {
                device: "d".into(),
                bytes: 100_000,
            })
            .unwrap()
            .wait()
            .unwrap();
        }
        assert_eq!(eng.stats()[0].completed, 3);
        eng.reset_stats();
        let s = &eng.stats()[0];
        assert_eq!(s.completed, 0);
        assert_eq!(s.submitted, 0);
        assert_eq!(s.bytes_written, 0);
        assert_eq!(s.max_queue_depth, 0);
        assert_eq!(s.class(IoClass::Checkpoint).completed, 0);
        // The engine keeps serving after a reset.
        eng.submit(IoRequest::ProbeRead { device: "d".into(), bytes: 1024 })
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(eng.stats()[0].completed, 1);
    }

    // -- tentpole: hierarchical (tenant -> class) scheduling ----------

    #[test]
    fn with_tenant_scopes_nest_and_restore() {
        let a = TenantId::new("job-a");
        let b = TenantId::new("job-b");
        assert!(current_tenant().is_default());
        with_tenant(&a, || {
            assert_eq!(current_tenant(), a);
            with_tenant(&b, || assert_eq!(current_tenant(), b));
            assert_eq!(current_tenant(), a);
        });
        assert!(current_tenant().is_default());
    }

    #[test]
    fn tenant_id_default_and_display() {
        assert!(TenantId::default().is_default());
        assert_eq!(TenantId::default().as_str(), "");
        assert_eq!(TenantId::default().to_string(), "-");
        let t = TenantId::new("job-a");
        assert!(!t.is_default());
        assert_eq!(t.to_string(), "job-a");
        assert_eq!(t, TenantId::new("job-a"));
    }

    #[test]
    fn tenant_qos_lookup_and_builders() {
        let tq = TenantQos::default()
            .with_share("a", 4)
            .with_share("b", 0) // clamped to 1
            .with_rate_cap("a", 20e6, 64 * 1024)
            .with_adaptive_target("b", 0.002);
        assert_eq!(tq.share_for("a"), 4);
        assert_eq!(tq.share_for("b"), 1, "zero share clamps to 1");
        assert_eq!(tq.share_for("unlisted"), 1, "default share");
        let cap = tq.rate_cap_for("a").unwrap();
        assert_eq!(cap.bytes_per_sec, 20e6);
        assert!(tq.rate_cap_for("b").is_none());
        assert_eq!(tq.adaptive_target_for("b"), Some(0.002));
        assert!(tq.adaptive_target_for("a").is_none());
        // Re-setting a share replaces, not duplicates.
        let tq = tq.with_share("a", 8);
        assert_eq!(tq.share_for("a"), 8);
        assert_eq!(
            tq.shares.iter().filter(|(t, _)| t == "a").count(),
            1
        );
    }

    #[test]
    fn tenant_tag_lands_on_events_and_stats_rows() {
        // The tagging seam works even on a tenant-blind engine: jobs
        // carry their tenant into events and stats rows while the
        // scheduler routes everything through the default slot.
        let (eng, _) = engine_with(vec![model("d", 4, 1000.0)], 8 * 1024);
        let sink = Arc::new(Sink(Mutex::new(Vec::new())));
        eng.set_observer(Arc::clone(&sink) as Arc<dyn EngineObserver>);
        let beta = TenantId::new("beta");
        let alpha = TenantId::new("alpha");
        with_tenant(&beta, || {
            eng.submit(IoRequest::ProbeWrite {
                device: "d".into(),
                bytes: 4_000,
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        with_tenant(&alpha, || {
            eng.submit(IoRequest::ProbeRead {
                device: "d".into(),
                bytes: 10_000,
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        eng.submit(IoRequest::ProbeRead { device: "d".into(), bytes: 256 })
            .unwrap()
            .wait()
            .unwrap();
        eng.clear_observer();
        let evs = sink.0.lock().unwrap();
        let w = evs.iter().find(|e| e.op == EngineOp::ProbeWrite).unwrap();
        assert_eq!(w.tenant, beta, "write lost its tenant tag");
        let r = evs
            .iter()
            .find(|e| e.op == EngineOp::ProbeRead && e.bytes == 10_000)
            .unwrap();
        assert_eq!(r.tenant, alpha);
        let untagged = evs
            .iter()
            .find(|e| e.op == EngineOp::ProbeRead && e.bytes == 256)
            .unwrap();
        assert!(untagged.tenant.is_default(), "untagged must stay default");
        drop(evs);
        // Stats: one row per non-default tenant, sorted by name; the
        // default tenant stays off the ledger (single-tenant output
        // unchanged).
        let stats = eng.stats();
        let s = stats.iter().find(|s| s.device == "d").unwrap();
        let names: Vec<&str> =
            s.tenants.iter().map(|t| t.tenant.as_str()).collect();
        assert_eq!(names, vec!["alpha", "beta"]);
        let a = s.tenant("alpha").unwrap();
        assert_eq!(a.completed, 1);
        assert_eq!(a.bytes_read, 10_000);
        assert_eq!(a.classes[IoClass::Ingest.index()].completed, 1);
        let b = s.tenant("beta").unwrap();
        assert_eq!(b.completed, 1);
        assert_eq!(b.bytes_written, 4_000);
        assert!(
            b.classes[IoClass::Checkpoint.index()].queue_hist.count() > 0,
            "tenant x class rows carry queue-latency histograms"
        );
        assert!(s.tenant("nope").is_none());
        // reset_stats clears the tenant rows with everything else.
        eng.reset_stats();
        let stats = eng.stats();
        let s = stats.iter().find(|s| s.device == "d").unwrap();
        assert!(s.tenants.is_empty());
    }

    #[test]
    fn idle_tenants_do_not_stall_the_round() {
        // Work conservation: shares for three tenants, but only one
        // ever submits — the round must skip the idle slots at zero
        // cost and finish in device-limited time, then serve a
        // late-waking tenant normally.
        let mut m = model("d", 1, 1.0);
        m.read_bw = 20e6; // 100 KB = 5 ms
        let qos = QosConfig::default().with_tenants(
            TenantQos::default()
                .with_share("a", 4)
                .with_share("b", 4)
                .with_share("c", 4),
        );
        let (eng, _) = engine_with_qos(vec![m], 64 * 1024, qos);
        let a = TenantId::new("a");
        let t0 = Instant::now();
        let tickets: Vec<_> = with_tenant(&a, || {
            (0..8)
                .map(|_| {
                    eng.submit(IoRequest::ProbeRead {
                        device: "d".into(),
                        bytes: 100_000,
                    })
                    .unwrap()
                })
                .collect()
        });
        for t in tickets {
            t.wait().unwrap();
        }
        // 8 x 5 ms of modelled service; anything near a second means
        // the round span idle slots instead of skipping them.
        assert!(
            t0.elapsed().as_secs_f64() < 1.0,
            "lone active tenant stalled behind idle slots: {:?}",
            t0.elapsed()
        );
        // A tenant waking later (churn) is served too.
        let b = TenantId::new("b");
        with_tenant(&b, || {
            eng.submit(IoRequest::ProbeRead { device: "d".into(), bytes: 100_000 })
                .unwrap()
                .wait()
                .unwrap();
        });
        let stats = eng.stats();
        let s = stats.iter().find(|st| st.device == "d").unwrap();
        assert_eq!(s.tenant("a").unwrap().completed, 8);
        assert_eq!(s.tenant("b").unwrap().completed, 1);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn saturated_device_splits_bandwidth_by_share() {
        // Share proportionality: one channel, tenants a:b at 4:1,
        // equal-size ingest backlogs submitted b-first (adversarial
        // arrival order).  Under saturation the dispatch mix must
        // track the share ratio, not arrival order.
        let mut m = model("d", 1, 1.0);
        m.read_bw = 20e6; // 100 KB = 5 ms service per job
        let qos = QosConfig::default().with_tenants(
            TenantQos::default().with_share("a", 4).with_share("b", 1),
        );
        let (eng, _) = engine_with_qos(vec![m], 64 * 1024, qos);
        let sink = Arc::new(Sink(Mutex::new(Vec::new())));
        eng.set_observer(Arc::clone(&sink) as Arc<dyn EngineObserver>);
        let a = TenantId::new("a");
        let b = TenantId::new("b");
        let mut tickets = Vec::new();
        // The first b job dispatches immediately (empty device); every
        // later dispatch picks from the full backlog under DRR.
        with_tenant(&b, || {
            for _ in 0..24 {
                tickets.push(
                    eng.submit(IoRequest::ProbeRead {
                        device: "d".into(),
                        bytes: 100_000,
                    })
                    .unwrap(),
                );
            }
        });
        with_tenant(&a, || {
            for _ in 0..24 {
                tickets.push(
                    eng.submit(IoRequest::ProbeRead {
                        device: "d".into(),
                        bytes: 100_000,
                    })
                    .unwrap(),
                );
            }
        });
        for t in tickets {
            t.wait().unwrap();
        }
        eng.clear_observer();
        let evs = sink.0.lock().unwrap();
        assert_eq!(evs.len(), 48);
        let first: Vec<&str> =
            evs[..20].iter().map(|e| e.tenant.as_str()).collect();
        let count_a = first.iter().filter(|t| **t == "a").count();
        let count_b = first.iter().filter(|t| **t == "b").count();
        // Ideal 4:1 over the first 20 completions is 16:4; demand a
        // wide-margin 2:1 so scheduling noise (the head-start b job,
        // bucket-free rounding) can't flake the test.
        assert!(
            count_a >= 2 * count_b,
            "share 4:1 not honored under saturation: \
             first 20 completions {first:?}"
        );
    }

    #[test]
    fn tenant_rate_cap_respected_while_others_proceed() {
        // Fast device (1 GB/s) so the only brake on tenant "capped"
        // is its 20 MB/s bucket; tenant "free" shares the device
        // uncapped.
        let m = model("d", 2, 1.0);
        let qos = QosConfig::default().with_tenants(
            TenantQos::default()
                .with_share("capped", 1)
                .with_share("free", 1)
                .with_rate_cap("capped", 20e6, 64 * 1024),
        );
        let (eng, _) = engine_with_qos(vec![m], 64 * 1024, qos);
        let capped = TenantId::new("capped");
        let free = TenantId::new("free");
        let t0 = Instant::now();
        let writes: Vec<_> = with_tenant(&capped, || {
            (0..40)
                .map(|_| {
                    eng.submit(IoRequest::ProbeWrite {
                        device: "d".into(),
                        bytes: 100_000,
                    })
                    .unwrap()
                })
                .collect()
        });
        let reads: Vec<_> = with_tenant(&free, || {
            (0..8)
                .map(|_| {
                    eng.submit(IoRequest::ProbeRead {
                        device: "d".into(),
                        bytes: 100_000,
                    })
                    .unwrap()
                })
                .collect()
        });
        for r in reads {
            r.wait().unwrap();
        }
        let free_done = t0.elapsed().as_secs_f64();
        for w in writes {
            w.wait().unwrap();
        }
        let capped_done = t0.elapsed().as_secs_f64();
        // 4 MB through a 20 MB/s tenant bucket: within 1.1x of the
        // cap (burst + one in-flight job are the only slack).
        let achieved = 4_000_000.0 / capped_done;
        assert!(
            achieved <= 1.1 * 20e6,
            "capped tenant ran at {:.1} MB/s, cap 20 MB/s",
            achieved / 1e6
        );
        // The uncapped tenant must not be dragged down by the debt.
        assert!(
            free_done <= 0.5 * capped_done,
            "free tenant took {free_done:.3}s vs capped {capped_done:.3}s"
        );
        let stats = eng.stats();
        let s = stats.iter().find(|st| st.device == "d").unwrap();
        assert_eq!(s.tenant("capped").unwrap().completed, 40);
        assert_eq!(s.tenant("free").unwrap().completed, 8);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn per_tenant_adaptive_targets_steer_independent_controllers() {
        // Smoke the per-tenant AIMD instancing: two tagged tenants
        // plus untagged traffic through an adaptive engine — every
        // request completes and the default controller still reports
        // a weight (the tenant-blind surface).
        let qos = QosConfig::adaptive(0.005).with_tenants(
            TenantQos::default()
                .with_share("a", 2)
                .with_share("b", 2)
                .with_adaptive_target("a", 0.001),
        );
        let (eng, _) = engine_with_qos(vec![model("d", 2, 1000.0)], 8 * 1024, qos);
        for name in ["a", "b"] {
            let t = TenantId::new(name);
            with_tenant(&t, || {
                for _ in 0..4 {
                    eng.submit(IoRequest::ProbeRead {
                        device: "d".into(),
                        bytes: 50_000,
                    })
                    .unwrap()
                    .wait()
                    .unwrap();
                }
            });
        }
        eng.submit(IoRequest::ProbeRead { device: "d".into(), bytes: 1024 })
            .unwrap()
            .wait()
            .unwrap();
        let stats = eng.stats();
        let s = stats.iter().find(|st| st.device == "d").unwrap();
        assert_eq!(s.completed, 9);
        assert!(s.ingest_weight >= 1);
        assert_eq!(s.tenant("a").unwrap().completed, 4);
        assert_eq!(s.tenant("b").unwrap().completed, 4);
    }

    fn engine_with_fault(
        phases: Vec<crate::storage::fault::FaultPhase>,
        qos: QosConfig,
    ) -> IoEngine {
        use crate::storage::clock::Clock;
        use crate::storage::fault::DeviceHealth;
        let clock = Clock::virt();
        let dev = Arc::new(Device::with_clock(
            model("d", 2, 1.0),
            Arc::new(NullObserver),
            clock.clone(),
        ));
        dev.set_health(Some(Arc::new(DeviceHealth::new(
            phases,
            clock.now(),
        ))));
        let mut devices = HashMap::new();
        devices.insert("d".to_string(), dev);
        IoEngine::with_config(&devices, 8 * 1024, qos)
    }

    #[test]
    fn exhausted_retry_budget_counts_error_exactly_once() {
        use crate::storage::fault::FaultPhase;
        // A permanently flaky device: every attempt draws a transient
        // error.  The worker burns the full Ingest retry budget, then
        // the error surfaces once — retries == budget, errors == 1.
        let qos = QosConfig::default()
            .with_retry(RetryPolicy { budget: [2; IoClass::COUNT], backoff: 0.002 });
        let eng = engine_with_fault(
            vec![FaultPhase::flaky(0.0, f64::INFINITY, 1.0)],
            qos,
        );
        let t = eng
            .submit(IoRequest::ProbeRead { device: "d".into(), bytes: 1024 })
            .unwrap();
        assert!(t.wait().is_err());
        let stats = eng.stats();
        let s = stats.iter().find(|st| st.device == "d").unwrap();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.errors, 1, "error must be exactly-once");
        assert_eq!(s.retries, 2, "retries must equal the class budget");
        let ingest = &s.classes[IoClass::Ingest.index()];
        assert_eq!(ingest.errors, 1);
        assert_eq!(ingest.retries, 2);
    }

    #[test]
    fn transient_fault_clearing_during_backoff_yields_no_error() {
        use crate::storage::fault::FaultPhase;
        // The fault window closes before the first backoff expires:
        // the retried attempt succeeds, so the ledger shows retries
        // but zero errors (a retried-then-successful request).
        let qos = QosConfig::default()
            .with_retry(RetryPolicy { budget: [4; IoClass::COUNT], backoff: 0.002 });
        let eng = engine_with_fault(
            vec![FaultPhase::flaky(0.0, 0.001, 1.0)],
            qos,
        );
        let t = eng
            .submit(IoRequest::ProbeRead { device: "d".into(), bytes: 4096 })
            .unwrap();
        let c = t.wait().unwrap();
        assert_eq!(c.bytes, 4096);
        let stats = eng.stats();
        let s = stats.iter().find(|st| st.device == "d").unwrap();
        assert_eq!(s.errors, 0, "recovered request must not count an error");
        assert!(s.retries >= 1, "the failed attempt must be ledgered");
        assert_eq!(s.classes[IoClass::Ingest.index()].errors, 0);
        assert!(s.classes[IoClass::Ingest.index()].retries >= 1);
    }

    #[test]
    fn zero_retry_budget_fails_fast() {
        use crate::storage::fault::{FaultPhase, HealthState};
        // RetryPolicy::none(): the first injected denial surfaces
        // immediately with no retry ledger entries.
        let qos = QosConfig::default().with_retry(RetryPolicy::none());
        let eng = engine_with_fault(
            vec![FaultPhase::state(0.0, f64::INFINITY, HealthState::Offline)],
            qos,
        );
        let t = eng
            .submit(IoRequest::ProbeWrite { device: "d".into(), bytes: 1024 })
            .unwrap();
        let err = t.wait().unwrap_err();
        assert!(
            err.to_string().contains("offline"),
            "error should name the injected state: {err}"
        );
        let stats = eng.stats();
        let s = stats.iter().find(|st| st.device == "d").unwrap();
        assert_eq!(s.errors, 1);
        assert_eq!(s.retries, 0);
    }
}
