//! Fault seam: injectable device faults and degraded-mode operation
//! (DESIGN.md §15).
//!
//! The paper's entire case for checkpointing (and the burst-buffer
//! result) is restart-after-failure, yet a simulator whose devices are
//! perfect never exercises one.  This module makes *health* a
//! first-class seam the way `clock.rs` did for time and the tenant
//! scheduler did for tenancy: a [`FaultPlan`] describes per-device
//! schedules of degradation, and an armed [`DeviceHealth`] handle is
//! consulted by every device service path.
//!
//! Three orthogonal degradation axes per scheduled [`FaultPhase`]:
//!
//! * **state machine** — `healthy → read-only → offline → recovered`
//!   ([`HealthState`]): a read-only device fails writes, an offline
//!   device fails everything, and once the phase window passes the
//!   device is healthy again (recovery is the absence of an active
//!   phase, so plans cannot leave a device wedged).
//! * **transient errors** — `error_rate` fails a fraction of requests
//!   with a retryable error (the engine's bounded retry-with-backoff
//!   path absorbs them up to its per-class budget).
//! * **latency spikes** — `slow_factor` multiplies the latency phase
//!   and stretches the transfer phase of every request in the window.
//!
//! Phase windows are *modelled seconds relative to arm time* and are
//! evaluated against the shared [`Clock`], so virtual-clock runs are
//! deterministic: the same plan over the same workload degrades the
//! same requests.  Transient-error draws come from a counter-seeded
//! hash stream (no global RNG), so a single-submitter virtual-clock
//! run replays bit-for-bit.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Result};

use super::clock::Clock;
use super::device::Dir;

/// Degradation state of a device at a point in time.  Order is
/// severity order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Full service (possibly still slowed / transiently erroring).
    Healthy,
    /// Reads succeed, writes fail (a filesystem remounted read-only
    /// after an error — the classic Lustre degraded mode).
    ReadOnly,
    /// Every request fails.
    Offline,
}

impl HealthState {
    pub fn label(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::ReadOnly => "read-only",
            HealthState::Offline => "offline",
        }
    }

    /// Whether a request in `dir` is admitted in this state.
    pub fn admits(self, dir: Dir) -> bool {
        match self {
            HealthState::Healthy => true,
            HealthState::ReadOnly => dir == Dir::Read,
            HealthState::Offline => false,
        }
    }
}

/// One scheduled window of degradation.  `start`/`end` are modelled
/// seconds **after the plan is armed** on a device; outside every
/// window the device is healthy (recovered).
#[derive(Debug, Clone)]
pub struct FaultPhase {
    pub start: f64,
    pub end: f64,
    pub state: HealthState,
    /// Fraction of admitted requests that fail transiently, `[0, 1]`.
    pub error_rate: f64,
    /// Latency/transfer-time multiplier, `>= 1`.
    pub slow_factor: f64,
}

impl FaultPhase {
    /// A phase that only changes the state machine.
    pub fn state(start: f64, end: f64, state: HealthState) -> FaultPhase {
        FaultPhase { start, end, state, error_rate: 0.0, slow_factor: 1.0 }
    }

    /// A latency-spike phase (state stays healthy).
    pub fn slow(start: f64, end: f64, factor: f64) -> FaultPhase {
        FaultPhase {
            start,
            end,
            state: HealthState::Healthy,
            error_rate: 0.0,
            slow_factor: factor.max(1.0),
        }
    }

    /// A transient-error phase (state stays healthy).
    pub fn flaky(start: f64, end: f64, rate: f64) -> FaultPhase {
        FaultPhase {
            start,
            end,
            state: HealthState::Healthy,
            error_rate: rate.clamp(0.0, 1.0),
            slow_factor: 1.0,
        }
    }
}

/// Schedule of fault phases for one device.  `device` may be `"*"` to
/// target every device the plan is applied to.
#[derive(Debug, Clone)]
pub struct DeviceFaultSpec {
    pub device: String,
    pub phases: Vec<FaultPhase>,
}

impl DeviceFaultSpec {
    /// Whether this spec targets device `name`.
    pub fn targets(&self, name: &str) -> bool {
        self.device == "*" || self.device == name
    }
}

/// Valid named fault kinds, in canonical order (error messages quote
/// it).  `none` is the explicit no-fault plan so sweep matrices can
/// carry a baseline cell.
pub const FAULT_KINDS: [&str; 5] =
    ["none", "slow", "flaky", "read-only", "offline"];

/// Latency/transfer multiplier of the named `slow` kind.
pub const SLOW_FACTOR: f64 = 8.0;
/// Transient error rate of the named `flaky` kind.
pub const FLAKY_RATE: f64 = 0.25;

/// A named, per-device fault schedule — the unit the CLI (`--inject`),
/// the replayer, and the sweep drivers pass around.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub name: String,
    pub devices: Vec<DeviceFaultSpec>,
}

impl FaultPlan {
    /// The explicit no-fault plan (baseline cells).
    pub fn none() -> FaultPlan {
        FaultPlan { name: "none".into(), devices: Vec::new() }
    }

    /// A single-device (or `"*"`) single-phase plan.
    pub fn single(
        name: impl Into<String>,
        device: impl Into<String>,
        phase: FaultPhase,
    ) -> FaultPlan {
        FaultPlan {
            name: name.into(),
            devices: vec![DeviceFaultSpec {
                device: device.into(),
                phases: vec![phase],
            }],
        }
    }

    /// Parse an injection spec: `kind[:device[:start[:duration]]]`.
    ///
    /// * `kind` — one of [`FAULT_KINDS`].
    /// * `device` — device name the fault targets (`*`, the default,
    ///   targets every device).
    /// * `start` / `duration` — window in modelled seconds after the
    ///   plan is armed; by default the fault starts immediately and
    ///   never clears.
    ///
    /// `slow:hdd:0.02:0.05` degrades `hdd` with an 8× latency spike
    /// from 20 ms to 70 ms after arming, then recovers.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let device = match parts.next() {
            None | Some("") => "*".to_string(),
            Some(d) => d.to_string(),
        };
        let num = |field: &str, s: Option<&str>, default: f64| -> Result<f64> {
            match s {
                None | Some("") => Ok(default),
                Some(s) => s.parse::<f64>().map_err(|_| {
                    anyhow!("bad fault {field} {s:?} in {spec:?} (seconds)")
                }),
            }
        };
        let start = num("start", parts.next(), 0.0)?;
        let duration = num("duration", parts.next(), f64::INFINITY)?;
        if let Some(extra) = parts.next() {
            bail!("trailing fault field {extra:?} in {spec:?}");
        }
        if start < 0.0 || duration <= 0.0 {
            bail!("fault window must have start >= 0 and duration > 0, got {spec:?}");
        }
        let end = start + duration;
        let phase = match kind {
            "none" => return Ok(FaultPlan::none()),
            "slow" => FaultPhase::slow(start, end, SLOW_FACTOR),
            "flaky" => FaultPhase::flaky(start, end, FLAKY_RATE),
            "read-only" => {
                FaultPhase::state(start, end, HealthState::ReadOnly)
            }
            "offline" => FaultPhase::state(start, end, HealthState::Offline),
            other => bail!(
                "unknown fault kind {other:?} (valid: {})",
                FAULT_KINDS.join(", ")
            ),
        };
        Ok(FaultPlan::single(kind, device, phase))
    }

    /// The phase schedule this plan holds for device `name`, if any.
    pub fn spec_for(&self, name: &str) -> Option<&DeviceFaultSpec> {
        self.devices.iter().find(|s| s.targets(name))
    }

    /// Arm this plan's schedule for device `name` at the clock's
    /// current time (`None` when the plan does not target it).
    pub fn arm(&self, name: &str, clock: &Clock) -> Option<DeviceHealth> {
        self.spec_for(name)
            .map(|s| DeviceHealth::new(s.phases.clone(), clock.now()))
    }
}

/// Armed health schedule for one device: phase windows pinned to an
/// arm time on the shared clock.  The device consults it on every
/// service path; cheap when healthy (a time compare per phase).
#[derive(Debug)]
pub struct DeviceHealth {
    phases: Vec<FaultPhase>,
    /// Clock time the plan was armed; phase windows are relative.
    t0: f64,
    /// Deterministic transient-error draw stream (counter-seeded
    /// hash, no global RNG).
    draws: AtomicU64,
}

impl DeviceHealth {
    pub fn new(phases: Vec<FaultPhase>, t0: f64) -> DeviceHealth {
        DeviceHealth { phases, t0, draws: AtomicU64::new(0) }
    }

    fn phase_at(&self, now: f64) -> Option<&FaultPhase> {
        let t = now - self.t0;
        self.phases.iter().find(|p| t >= p.start && t < p.end)
    }

    /// State-machine position at `now` (healthy outside every phase —
    /// the `recovered` arc).
    pub fn state_at(&self, now: f64) -> HealthState {
        self.phase_at(now).map_or(HealthState::Healthy, |p| p.state)
    }

    /// Latency/transfer multiplier at `now` (1.0 when healthy).
    pub fn slow_factor_at(&self, now: f64) -> f64 {
        self.phase_at(now).map_or(1.0, |p| p.slow_factor.max(1.0))
    }

    /// Whether any degradation (state, errors, or slowdown) is active
    /// at `now` — the migrator's pause-and-retry predicate.
    pub fn degraded_at(&self, now: f64) -> bool {
        self.phase_at(now).map_or(false, |p| {
            p.state != HealthState::Healthy
                || p.error_rate > 0.0
                || p.slow_factor > 1.0
        })
    }

    /// Clock time after which every phase has ended (`None` for an
    /// open-ended plan): the earliest the device is surely recovered.
    pub fn recovered_after(&self) -> Option<f64> {
        let end = self
            .phases
            .iter()
            .map(|p| p.end)
            .fold(0.0_f64, f64::max);
        end.is_finite().then_some(self.t0 + end)
    }

    /// Admission gate for one request on `device` in `dir` at `now`:
    /// `Err` fails the request (state denial or a transient-error
    /// draw).  Transient errors are retryable; state denials persist
    /// until the phase window passes.
    pub fn admit(&self, device: &str, dir: Dir, now: f64) -> Result<()> {
        let Some(p) = self.phase_at(now) else { return Ok(()) };
        if !p.state.admits(dir) {
            bail!(
                "device {device:?}: injected fault: {}",
                p.state.label()
            );
        }
        if p.error_rate > 0.0 && self.unit_draw() < p.error_rate {
            bail!("device {device:?}: injected transient I/O error");
        }
        Ok(())
    }

    /// Uniform draw in `[0, 1)` from a counter-seeded splitmix64
    /// stream: deterministic per armed handle, no global RNG.
    fn unit_draw(&self) -> f64 {
        let n = self.draws.fetch_add(1, Ordering::Relaxed);
        let mut z = n.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_error_lists_valid_kinds() {
        let err = FaultPlan::parse("meltdown:ssd").unwrap_err().to_string();
        for kind in FAULT_KINDS {
            assert!(
                err.contains(kind),
                "error {err:?} does not list valid kind {kind:?}"
            );
        }
    }

    #[test]
    fn parse_spec_fields_and_defaults() {
        let p = FaultPlan::parse("slow:hdd:0.02:0.05").unwrap();
        assert_eq!(p.name, "slow");
        let s = p.spec_for("hdd").expect("targets hdd");
        assert!(p.spec_for("ssd").is_none());
        assert!((s.phases[0].start - 0.02).abs() < 1e-12);
        assert!((s.phases[0].end - 0.07).abs() < 1e-12);
        assert!((s.phases[0].slow_factor - SLOW_FACTOR).abs() < 1e-12);

        // Device defaults to "*", window to [0, inf).
        let p = FaultPlan::parse("offline").unwrap();
        let s = p.spec_for("anything").expect("wildcard targets all");
        assert_eq!(s.phases[0].state, HealthState::Offline);
        assert_eq!(s.phases[0].end, f64::INFINITY);

        assert!(FaultPlan::parse("none").unwrap().devices.is_empty());
        assert!(FaultPlan::parse("slow:hdd:x").is_err());
        assert!(FaultPlan::parse("slow:hdd:0:-1").is_err());
        assert!(FaultPlan::parse("slow:hdd:0:1:9").is_err());
    }

    #[test]
    fn state_machine_walks_healthy_degraded_recovered() {
        let h = DeviceHealth::new(
            vec![
                FaultPhase::state(1.0, 2.0, HealthState::ReadOnly),
                FaultPhase::state(2.0, 3.0, HealthState::Offline),
            ],
            10.0, // armed at t=10
        );
        assert_eq!(h.state_at(10.5), HealthState::Healthy);
        assert_eq!(h.state_at(11.5), HealthState::ReadOnly);
        assert!(h.admit("d", Dir::Read, 11.5).is_ok());
        assert!(h.admit("d", Dir::Write, 11.5).is_err());
        assert_eq!(h.state_at(12.5), HealthState::Offline);
        assert!(h.admit("d", Dir::Read, 12.5).is_err());
        // Recovered: past every window the device is healthy again.
        assert_eq!(h.state_at(13.5), HealthState::Healthy);
        assert!(h.admit("d", Dir::Write, 13.5).is_ok());
        assert_eq!(h.recovered_after(), Some(13.0));
        assert!(h.degraded_at(11.5) && !h.degraded_at(13.5));
    }

    #[test]
    fn transient_draws_match_rate_and_are_deterministic() {
        let h = DeviceHealth::new(
            vec![FaultPhase::flaky(0.0, f64::INFINITY, 0.25)],
            0.0,
        );
        let fails = (0..4000)
            .filter(|_| h.admit("d", Dir::Read, 0.0).is_err())
            .count();
        let frac = fails as f64 / 4000.0;
        assert!(
            (0.18..0.32).contains(&frac),
            "transient failure fraction {frac} far from 0.25"
        );
        // Identical armed handles draw identical streams.
        let a = DeviceHealth::new(
            vec![FaultPhase::flaky(0.0, f64::INFINITY, 0.5)],
            0.0,
        );
        let b = DeviceHealth::new(
            vec![FaultPhase::flaky(0.0, f64::INFINITY, 0.5)],
            0.0,
        );
        for _ in 0..256 {
            assert_eq!(
                a.admit("d", Dir::Read, 0.0).is_ok(),
                b.admit("d", Dir::Read, 0.0).is_ok()
            );
        }
    }

    #[test]
    fn slow_factor_applies_only_inside_the_window() {
        let h = DeviceHealth::new(vec![FaultPhase::slow(1.0, 2.0, 8.0)], 0.0);
        assert_eq!(h.slow_factor_at(0.5), 1.0);
        assert_eq!(h.slow_factor_at(1.5), 8.0);
        assert_eq!(h.slow_factor_at(2.5), 1.0);
    }
}
