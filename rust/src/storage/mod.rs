//! Simulated storage substrate (DESIGN.md §2).
//!
//! The paper's experiments run on physical HDD/SSD/Optane/Lustre; this
//! module substitutes a calibrated queueing simulator over real backing
//! files, reproducing the only surface the experiments observe: service
//! time of reads/writes vs request size and concurrency.

pub mod clock;
pub mod device;
pub mod engine;
pub mod fault;
pub mod hierarchy;
pub mod ior;
pub mod page_cache;
pub mod policy;
pub mod profiles;
pub mod sim;

pub use clock::{Clock, ClockSpec, SimCondvar, TimeSource};
pub use device::{
    Device, DeviceModel, Dir, IoObserver, LatencyTables, NullObserver,
};
pub use engine::{
    with_origin, with_tenant, with_tier, AdaptiveQos, ChunkWriter,
    ClassStats, EngineDeviceStats, EngineEvent, EngineObserver, EngineOp,
    IoClass, IoCompletion, IoEngine, IoRequest, IoTicket, QosConfig,
    RateCap, RetryPolicy, TenantId, TenantIoStats, TenantQos, TierIoStats,
};
pub use fault::{
    DeviceFaultSpec, DeviceHealth, FaultPhase, FaultPlan, HealthState,
    FAULT_KINDS,
};
pub use hierarchy::{
    HierarchySpec, RamTier, StorageHierarchy, TierKind, TierSpec,
    TierStatsSnap,
};
pub use page_cache::PageCache;
pub use policy::{
    Migration, PlacementPolicy, PolicyDecisions, TierView,
};
pub use sim::{PendingRead, PendingWrite, SimPath, StorageSim};
