//! The engine's time seam (DESIGN.md §10): one `Clock` trait, two
//! implementations.
//!
//! * [`WallClock`] — today's paced-sleep behaviour: modelled service
//!   time is spent as real `thread::sleep` (with a sub-millisecond
//!   spin so multi-GB/s devices aren't halved by timer slack).  Kept
//!   for pacing-sensitive tests and trace recording, where wall-time
//!   interleavings are the point.
//! * [`VirtualClock`] — a discrete-event scheduler.  Threads never
//!   sleep: a "sleep" pushes a timer onto a global event heap and
//!   parks the thread; when **every registered thread is parked**, the
//!   earliest timer fires, virtual-now jumps straight to its deadline,
//!   and the owning thread wakes.  Token-bucket refills, latency
//!   phases, DRR throttle waits and migrator wakeups all become heap
//!   events, so a sweep cell that models minutes of device time runs
//!   in milliseconds of wall time while producing the *same* byte and
//!   class totals.
//!
//! ## Registration
//!
//! Virtual time may only advance when no registered thread can still
//! make progress at the current instant.  Every thread that
//! participates in the simulation — engine workers, stream writers,
//! copy readers, the hierarchy migrator, and driver threads that want
//! deterministic timestamps — registers via [`Clock::enter`].  A
//! registered thread must block **only** through the clock
//! ([`Clock::sleep`], [`SimCondvar`]); blocking on a foreign primitive
//! (e.g. `JoinHandle::join`) while registered would stall virtual time
//! forever, so joiners first drop out with [`Clock::suspend`].
//! Unregistered threads may use the same primitives freely; the clock
//! simply does not wait for them before advancing.
//!
//! ## What "virtual now" means
//!
//! [`Clock::now`] is seconds since an arbitrary epoch: process start
//! for [`WallClock`], zero for [`VirtualClock`].  All engine
//! timestamps (`EngineEvent::submit_secs`, queue/service durations,
//! histogram samples, trace records) are differences of `now()`
//! readings, so they carry identical meaning in both modes — in
//! virtual mode they are *exactly* the modelled durations, free of
//! host-scheduler noise.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Process-wide wall epoch: all `WallClock` instances agree on `now`.
fn wall_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

// ---------------------------------------------------------------------------
// Parker
// ---------------------------------------------------------------------------

/// Per-thread park/unpark cell.  One per OS thread (thread-local);
/// clock implementations block threads by parking them here and wake
/// them by setting the flag.
pub struct Parker {
    lock: Mutex<bool>, // notified flag
    cv: Condvar,
    /// Whether this parker is currently counted in a `VirtualClock`'s
    /// `parked` tally.  Mutated only under that clock's state lock, so
    /// the waker (who decrements the tally when it sets the flag) and
    /// the wakee can never double-count.
    counted: AtomicBool,
}

impl Parker {
    fn new() -> Parker {
        Parker {
            lock: Mutex::new(false),
            cv: Condvar::new(),
            counted: AtomicBool::new(false),
        }
    }

    /// The calling thread's parker.
    pub(crate) fn current() -> Arc<Parker> {
        thread_local! {
            static PARKER: Arc<Parker> = Arc::new(Parker::new());
        }
        PARKER.with(Arc::clone)
    }

    /// Clear any stale notification before arming a new wait.
    fn prepare(&self) {
        *self.lock.lock().unwrap() = false;
    }

    /// Block until notified (consumes the notification).
    fn block(&self) {
        let mut g = self.lock.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
        *g = false;
    }

    /// Block until notified or `deadline` (wall time).  Returns `true`
    /// if the wait timed out.
    fn block_until(&self, deadline: Option<Instant>) -> bool {
        let mut g = self.lock.lock().unwrap();
        loop {
            if *g {
                *g = false;
                return false;
            }
            match deadline {
                None => g = self.cv.wait(g).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return true;
                    }
                    g = self.cv.wait_timeout(g, d - now).unwrap().0;
                }
            }
        }
    }

    fn set_notified(&self) {
        *self.lock.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

/// The engine's time source.  Object-safe core; ergonomic helpers live
/// on the [`Clock`] handle and [`SimCondvar`].
pub trait TimeSource: Send + Sync {
    /// Seconds since this clock's epoch.
    fn now(&self) -> f64;
    /// Spend `dur` of modelled time (really, for wall; as a heap event
    /// for virtual).
    fn sleep(&self, dur: Duration);
    /// Whether this is a discrete-event clock.
    fn is_virtual(&self) -> bool;
    /// Count the calling thread as a simulation participant.
    fn register(&self);
    /// Undo one [`register`](Self::register).
    fn deregister(&self);
    /// Whether the calling thread is currently registered here.
    fn is_registered(&self) -> bool;
    /// Park the calling thread until [`unpark`](Self::unpark)ed or the
    /// (clock-time) `deadline` passes.  Returns `true` on timeout.
    fn park(&self, parker: &Arc<Parker>, deadline: Option<f64>) -> bool;
    /// Wake a parked thread.
    fn unpark(&self, parker: &Arc<Parker>);
}

// ---------------------------------------------------------------------------
// WallClock
// ---------------------------------------------------------------------------

/// Real time: sleeps sleep, waits wait.  Registration is a no-op —
/// the host scheduler decides who runs.
pub struct WallClock;

impl TimeSource for WallClock {
    fn now(&self) -> f64 {
        wall_epoch().elapsed().as_secs_f64()
    }

    fn sleep(&self, dur: Duration) {
        let secs = dur.as_secs_f64();
        if secs <= 0.0 {
            return;
        }
        if secs >= 0.001 {
            std::thread::sleep(dur);
        } else {
            // thread::sleep overshoots sub-ms requests by ~0.1 ms
            // (timer slack), which would halve multi-GB/s devices;
            // spin-wait instead.
            let until = Instant::now() + dur;
            while Instant::now() < until {
                std::hint::spin_loop();
            }
        }
    }

    fn is_virtual(&self) -> bool {
        false
    }

    fn register(&self) {}
    fn deregister(&self) {}
    fn is_registered(&self) -> bool {
        false
    }

    fn park(&self, parker: &Arc<Parker>, deadline: Option<f64>) -> bool {
        let wall = deadline.map(|d| {
            Instant::now() + Duration::from_secs_f64((d - self.now()).max(0.0))
        });
        parker.block_until(wall)
    }

    fn unpark(&self, parker: &Arc<Parker>) {
        parker.set_notified();
    }
}

// ---------------------------------------------------------------------------
// VirtualClock
// ---------------------------------------------------------------------------

/// A pending timer on the event heap.  Min-ordered by
/// `(deadline, seq)`; `seq` breaks ties FIFO so same-instant events
/// fire in arming order (determinism).
struct VTimer {
    deadline: f64,
    seq: u64,
    parker: Arc<Parker>,
    cancelled: Arc<AtomicBool>,
}

impl PartialEq for VTimer {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for VTimer {}
impl PartialOrd for VTimer {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for VTimer {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.deadline
            .total_cmp(&other.deadline)
            .then(self.seq.cmp(&other.seq))
    }
}

struct VState {
    now: f64,
    /// Threads participating in the simulation.
    registered: usize,
    /// Registered threads currently parked in the clock.
    parked: usize,
    seq: u64,
    timers: BinaryHeap<Reverse<VTimer>>,
}

/// Discrete-event time.  See the module docs for the advancement rule;
/// the implementation invariant is that `parked` counts exactly the
/// registered threads whose parker has `counted == true`, and both are
/// only mutated under the state lock (the *waker* clears the count
/// when it delivers a wakeup, so a woken-but-not-yet-running thread is
/// already "runnable" for advancement purposes).
pub struct VirtualClock {
    uid: u64,
    state: Mutex<VState>,
}

thread_local! {
    /// (clock uid, registration depth) for the clocks this thread has
    /// entered.  Tiny: a thread rarely touches more than one clock.
    static REGISTRY: RefCell<Vec<(u64, u32)>> = const { RefCell::new(Vec::new()) };
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        static NEXT_UID: AtomicU64 = AtomicU64::new(1);
        VirtualClock {
            uid: NEXT_UID.fetch_add(1, Ordering::Relaxed),
            state: Mutex::new(VState {
                now: 0.0,
                registered: 0,
                parked: 0,
                seq: 0,
                timers: BinaryHeap::new(),
            }),
        }
    }

    fn registered_here(&self) -> bool {
        REGISTRY.with(|r| {
            r.borrow().iter().any(|&(uid, d)| uid == self.uid && d > 0)
        })
    }

    /// If every registered thread is parked (or nothing is registered),
    /// jump `now` to the earliest live timer and fire every timer due
    /// at that instant.  Fires at most one deadline batch: the woken
    /// thread(s) get to run — and possibly schedule new events — before
    /// time moves again.
    fn advance_locked(&self, st: &mut VState) {
        if st.registered > 0 && st.parked < st.registered {
            return;
        }
        // Shed cancelled heads, then read the next live deadline.
        let deadline = loop {
            match st.timers.peek() {
                None => return,
                Some(Reverse(t)) if t.cancelled.load(Ordering::Relaxed) => {
                    st.timers.pop();
                }
                Some(Reverse(t)) => break t.deadline,
            }
        };
        if deadline > st.now {
            st.now = deadline;
        }
        while let Some(Reverse(head)) = st.timers.peek() {
            if head.cancelled.load(Ordering::Relaxed) {
                st.timers.pop();
                continue;
            }
            if head.deadline > st.now {
                break;
            }
            let t = st.timers.pop().unwrap().0;
            if t.parker.counted.swap(false, Ordering::AcqRel) {
                st.parked -= 1;
            }
            t.parker.set_notified();
        }
    }

    fn arm_locked(
        &self,
        st: &mut VState,
        deadline: f64,
        parker: &Arc<Parker>,
    ) -> Arc<AtomicBool> {
        let cancelled = Arc::new(AtomicBool::new(false));
        st.seq += 1;
        st.timers.push(Reverse(VTimer {
            deadline,
            seq: st.seq,
            parker: Arc::clone(parker),
            cancelled: Arc::clone(&cancelled),
        }));
        cancelled
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl TimeSource for VirtualClock {
    fn now(&self) -> f64 {
        self.state.lock().unwrap().now
    }

    fn sleep(&self, dur: Duration) {
        let secs = dur.as_secs_f64();
        if secs <= 0.0 {
            return;
        }
        let parker = Parker::current();
        let registered = self.registered_here();
        let deadline;
        let cancelled;
        {
            let mut st = self.state.lock().unwrap();
            deadline = st.now + secs;
            cancelled = self.arm_locked(&mut st, deadline, &parker);
            parker.prepare();
            if registered && !parker.counted.swap(true, Ordering::AcqRel) {
                st.parked += 1;
            }
            self.advance_locked(&mut st);
        }
        loop {
            parker.block();
            let mut st = self.state.lock().unwrap();
            if st.now >= deadline - 1e-9 {
                if parker.counted.swap(false, Ordering::AcqRel) {
                    st.parked -= 1;
                }
                cancelled.store(true, Ordering::Relaxed);
                return;
            }
            // Spurious wake (a stale unpark from an earlier wait):
            // re-park until the timer actually fires.
            parker.prepare();
            if registered && !parker.counted.swap(true, Ordering::AcqRel) {
                st.parked += 1;
            }
            self.advance_locked(&mut st);
        }
    }

    fn is_virtual(&self) -> bool {
        true
    }

    fn register(&self) {
        let first_entry = REGISTRY.with(|r| {
            let mut reg = r.borrow_mut();
            if let Some(e) = reg.iter_mut().find(|e| e.0 == self.uid) {
                e.1 += 1;
                e.1 == 1
            } else {
                reg.push((self.uid, 1));
                true
            }
        });
        if first_entry {
            self.state.lock().unwrap().registered += 1;
        }
    }

    fn deregister(&self) {
        let last_exit = REGISTRY.with(|r| {
            let mut reg = r.borrow_mut();
            let e = reg
                .iter_mut()
                .find(|e| e.0 == self.uid)
                .expect("deregister without register");
            assert!(e.1 > 0, "deregister without register");
            e.1 -= 1;
            e.1 == 0
        });
        if last_exit {
            let mut st = self.state.lock().unwrap();
            st.registered -= 1;
            // One fewer thread to wait for: time may now advance.
            self.advance_locked(&mut st);
        }
    }

    fn is_registered(&self) -> bool {
        self.registered_here()
    }

    fn park(&self, parker: &Arc<Parker>, deadline: Option<f64>) -> bool {
        // NB: no `prepare()` here — callers (SimCondvar) arm the
        // parker *before* enlisting, so a notify that lands between
        // enlist and park is not lost.
        let registered = self.registered_here();
        let cancelled;
        {
            let mut st = self.state.lock().unwrap();
            if let Some(dl) = deadline {
                if st.now >= dl {
                    return true;
                }
            }
            cancelled = deadline.map(|dl| self.arm_locked(&mut st, dl, parker));
            if registered && !parker.counted.swap(true, Ordering::AcqRel) {
                st.parked += 1;
            }
            self.advance_locked(&mut st);
        }
        parker.block();
        let mut st = self.state.lock().unwrap();
        if parker.counted.swap(false, Ordering::AcqRel) {
            st.parked -= 1;
        }
        if let Some(c) = &cancelled {
            c.store(true, Ordering::Relaxed);
        }
        deadline.is_some_and(|dl| st.now >= dl - 1e-9)
    }

    fn unpark(&self, parker: &Arc<Parker>) {
        let mut st = self.state.lock().unwrap();
        if parker.counted.swap(false, Ordering::AcqRel) {
            st.parked -= 1;
        }
        drop(st);
        parker.set_notified();
    }
}

// ---------------------------------------------------------------------------
// Clock handle + guards
// ---------------------------------------------------------------------------

/// Cheap-to-clone handle to a [`TimeSource`]; every component of one
/// simulation (devices, engine, hierarchy, drivers) shares one.
#[derive(Clone)]
pub struct Clock(Arc<dyn TimeSource>);

impl Clock {
    /// Real time (shared process-wide epoch).
    pub fn wall() -> Clock {
        static SHARED: OnceLock<Arc<WallClock>> = OnceLock::new();
        Clock(SHARED.get_or_init(|| Arc::new(WallClock)).clone())
    }

    /// A fresh discrete-event clock starting at `now == 0`.
    pub fn virt() -> Clock {
        Clock(Arc::new(VirtualClock::new()))
    }

    pub fn now(&self) -> f64 {
        self.0.now()
    }

    pub fn sleep(&self, dur: Duration) {
        self.0.sleep(dur)
    }

    pub fn sleep_secs(&self, secs: f64) {
        if secs > 0.0 {
            // Floor at one nanosecond: Duration rounds sub-ns requests
            // to zero, and a zero-length virtual sleep would never
            // advance the clock (pacing loops retrying a residual
            // sub-ns wait would livelock).
            self.0.sleep(Duration::from_secs_f64(secs.max(1e-9)));
        }
    }

    pub fn is_virtual(&self) -> bool {
        self.0.is_virtual()
    }

    /// Two handles to the same underlying source?
    pub fn same(&self, other: &Clock) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }

    /// Register the calling thread as a simulation participant until
    /// the guard drops.  See the module docs for the contract.
    pub fn enter(&self) -> ClockGuard {
        self.0.register();
        ClockGuard { clock: self.clone(), _not_send: PhantomData }
    }

    /// Temporarily drop the calling thread's registration (if any) —
    /// for blocking on foreign primitives like `JoinHandle::join`
    /// without stalling virtual time.  Re-registers on drop.
    pub fn suspend(&self) -> SuspendGuard {
        let was_registered = self.0.is_registered();
        if was_registered {
            self.0.deregister();
        }
        SuspendGuard {
            clock: self.clone(),
            re_register: was_registered,
            _not_send: PhantomData,
        }
    }

    fn park(&self, parker: &Arc<Parker>, deadline: Option<f64>) -> bool {
        self.0.park(parker, deadline)
    }

    fn unpark(&self, parker: &Arc<Parker>) {
        self.0.unpark(parker)
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Clock({})",
            if self.is_virtual() { "virtual" } else { "wall" }
        )
    }
}

/// Registration guard from [`Clock::enter`].
pub struct ClockGuard {
    clock: Clock,
    _not_send: PhantomData<*const ()>,
}

impl Drop for ClockGuard {
    fn drop(&mut self) {
        self.clock.0.deregister();
    }
}

/// Guard from [`Clock::suspend`].
pub struct SuspendGuard {
    clock: Clock,
    re_register: bool,
    _not_send: PhantomData<*const ()>,
}

impl Drop for SuspendGuard {
    fn drop(&mut self) {
        if self.re_register {
            self.clock.0.register();
        }
    }
}

// ---------------------------------------------------------------------------
// SimCondvar
// ---------------------------------------------------------------------------

/// A condition variable that blocks through the [`Clock`], so waits
/// are real under [`WallClock`] and heap events under
/// [`VirtualClock`].  Same contract as `std::sync::Condvar`: callers
/// loop on a predicate protected by the external mutex, and notifiers
/// mutate the predicate under that mutex before notifying.  Spurious
/// wakeups are possible.
pub struct SimCondvar {
    waiters: Mutex<VecDeque<Arc<Parker>>>,
}

impl SimCondvar {
    pub fn new() -> SimCondvar {
        SimCondvar { waiters: Mutex::new(VecDeque::new()) }
    }

    /// Atomically release `guard` and wait for a notification.
    pub fn wait<'a, T>(
        &self,
        clock: &Clock,
        mutex: &'a Mutex<T>,
        guard: MutexGuard<'a, T>,
    ) -> MutexGuard<'a, T> {
        let parker = Parker::current();
        parker.prepare();
        self.waiters.lock().unwrap().push_back(Arc::clone(&parker));
        drop(guard);
        clock.park(&parker, None);
        self.unlist(&parker);
        mutex.lock().unwrap()
    }

    /// Like [`wait`](Self::wait) with a timeout; returns the reacquired
    /// guard and whether the wait timed out.
    pub fn wait_timeout<'a, T>(
        &self,
        clock: &Clock,
        mutex: &'a Mutex<T>,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let parker = Parker::current();
        parker.prepare();
        self.waiters.lock().unwrap().push_back(Arc::clone(&parker));
        let deadline = clock.now() + dur.as_secs_f64().max(0.0);
        drop(guard);
        let timed_out = clock.park(&parker, Some(deadline));
        let was_listed = self.unlist(&parker);
        if timed_out && !was_listed {
            // A notifier popped us concurrently with our timeout: that
            // notification would otherwise evaporate.  Forward it.
            self.notify_one(clock);
        }
        (mutex.lock().unwrap(), timed_out)
    }

    fn unlist(&self, parker: &Arc<Parker>) -> bool {
        let mut w = self.waiters.lock().unwrap();
        if let Some(pos) = w.iter().position(|p| Arc::ptr_eq(p, parker)) {
            w.remove(pos);
            true
        } else {
            false
        }
    }

    pub fn notify_one(&self, clock: &Clock) {
        let head = self.waiters.lock().unwrap().pop_front();
        if let Some(p) = head {
            clock.unpark(&p);
        }
    }

    pub fn notify_all(&self, clock: &Clock) {
        let all: Vec<_> =
            self.waiters.lock().unwrap().drain(..).collect();
        for p in all {
            clock.unpark(&p);
        }
    }
}

impl Default for SimCondvar {
    fn default() -> Self {
        SimCondvar::new()
    }
}

// ---------------------------------------------------------------------------
// ClockSpec (CLI surface)
// ---------------------------------------------------------------------------

/// Which clock a driver should build — the `--clock wall|virtual`
/// flag, kept as a plain enum so configs stay `Clone + Debug`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockSpec {
    Wall,
    Virtual,
}

impl ClockSpec {
    pub fn parse(s: &str) -> anyhow::Result<ClockSpec> {
        match s {
            "wall" => Ok(ClockSpec::Wall),
            "virtual" => Ok(ClockSpec::Virtual),
            // A typo'd clock name must say what IS valid, matching the
            // --profile / hierarchy / policy error style.
            other => anyhow::bail!("unknown clock {other:?} (valid: wall, virtual)"),
        }
    }

    pub fn build(self) -> Clock {
        match self {
            ClockSpec::Wall => Clock::wall(),
            ClockSpec::Virtual => Clock::virt(),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ClockSpec::Wall => "wall",
            ClockSpec::Virtual => "virtual",
        }
    }
}

impl std::fmt::Display for ClockSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_sleep_is_exact_and_free() {
        let clock = Clock::virt();
        let wall0 = Instant::now();
        let t0 = clock.now();
        clock.sleep(Duration::from_secs_f64(123.456));
        let dt = clock.now() - t0;
        assert!((dt - 123.456).abs() < 1e-9, "virtual sleep drifted: {dt}");
        assert!(
            wall0.elapsed().as_secs_f64() < 1.0,
            "virtual sleep consumed wall time"
        );
    }

    #[test]
    fn registered_sleepers_overlap() {
        // Two registered threads sleeping 1 s each: virtual time ends
        // at 1 s (parallel), not 2 s (serial).  Register-then-barrier:
        // a registered thread stuck at the barrier blocks advancement,
        // so neither timer can fire before both are armed (without it,
        // an early sleeper's timer fires while the late thread is
        // still spawning and the sleeps serialize).
        let clock = Clock::virt();
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let c = clock.clone();
                let b = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    let _g = c.enter();
                    b.wait();
                    c.sleep(Duration::from_secs(1));
                    c.now()
                })
            })
            .collect();
        for h in hs {
            let end = h.join().unwrap();
            assert!((end - 1.0).abs() < 1e-9, "woke at {end}");
        }
        assert!((clock.now() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sequential_deadlines_fire_in_order() {
        // Distinct deadlines across threads fire earliest-first.
        // Register-then-barrier so all three timers are armed before
        // the first can fire (see registered_sleepers_overlap).
        let clock = Clock::virt();
        let order = Arc::new(Mutex::new(Vec::new()));
        let barrier = Arc::new(std::sync::Barrier::new(3));
        let hs: Vec<_> = [0.3, 0.1, 0.2]
            .iter()
            .map(|&d| {
                let c = clock.clone();
                let order = Arc::clone(&order);
                let b = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    let _g = c.enter();
                    b.wait();
                    c.sleep(Duration::from_secs_f64(d));
                    order.lock().unwrap().push(d);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0.1, 0.2, 0.3]);
    }

    #[test]
    fn simcondvar_delivers_across_clock() {
        for clock in [Clock::wall(), Clock::virt()] {
            let slot: Arc<(Mutex<Option<u32>>, SimCondvar)> =
                Arc::new((Mutex::new(None), SimCondvar::new()));
            let producer = {
                let slot = Arc::clone(&slot);
                let c = clock.clone();
                std::thread::spawn(move || {
                    let _g = c.enter();
                    c.sleep(Duration::from_millis(5));
                    *slot.0.lock().unwrap() = Some(7);
                    slot.1.notify_one(&c);
                })
            };
            let mut g = slot.0.lock().unwrap();
            while g.is_none() {
                g = slot.1.wait(&clock, &slot.0, g);
            }
            assert_eq!(*g, Some(7));
            drop(g);
            producer.join().unwrap();
        }
    }

    #[test]
    fn wait_timeout_expires_at_virtual_deadline() {
        let clock = Clock::virt();
        let _g = clock.enter();
        let m = Mutex::new(());
        let cv = SimCondvar::new();
        let t0 = clock.now();
        let (guard, timed_out) = cv.wait_timeout(
            &clock,
            &m,
            m.lock().unwrap(),
            Duration::from_secs_f64(2.5),
        );
        drop(guard);
        assert!(timed_out);
        assert!((clock.now() - t0 - 2.5).abs() < 1e-9);
    }

    #[test]
    fn suspend_lets_time_advance_past_joiner() {
        // A registered thread that joins another must suspend, or the
        // sleeper could never fire.  With suspend(), this completes.
        let clock = Clock::virt();
        let _g = clock.enter();
        let sleeper = {
            let c = clock.clone();
            std::thread::spawn(move || {
                let _g = c.enter();
                c.sleep(Duration::from_secs(5));
            })
        };
        {
            let _s = clock.suspend();
            sleeper.join().unwrap();
        }
        assert!((clock.now() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn clock_spec_parses() {
        assert_eq!(ClockSpec::parse("wall").unwrap(), ClockSpec::Wall);
        assert_eq!(ClockSpec::parse("virtual").unwrap(), ClockSpec::Virtual);
        assert!(ClockSpec::parse("nope").is_err());
        assert_eq!(ClockSpec::Virtual.as_str(), "virtual");
    }

    #[test]
    fn clock_spec_error_lists_valid_names() {
        // Regression: the unknown-clock error must list the valid
        // names, matching the --profile/hierarchy/policy error style.
        let err = ClockSpec::parse("sundial").unwrap_err().to_string();
        assert!(
            err.contains("\"sundial\"")
                && err.contains("wall")
                && err.contains("virtual"),
            "unknown-clock error does not list valid names: {err}"
        );
    }
}
