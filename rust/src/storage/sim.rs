//! [`StorageSim`]: the facade tying devices, page cache and backing
//! files together.
//!
//! Each simulated device owns a directory under the sim root; reads and
//! writes perform *real* file I/O there (so checkpoints can actually be
//! restored and corpora actually decoded) while service timing is paced
//! by the [`Device`] queueing model.  This is the layer every consumer
//! (pipeline map functions, the checkpoint saver, IOR) talks to — the
//! equivalent of the paper's "file system adapter" interface (Fig. 1).
//!
//! All device traffic flows through the request-level
//! [`IoEngine`](super::engine::IoEngine): the classic blocking calls
//! (`read`/`write`/`copy`/probes) are thin submit-then-wait wrappers,
//! and the `*_async` variants expose the submission/completion surface
//! directly (pipeline readahead, overlapped checkpoint saves,
//! burst-buffer drains).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::device::{Device, DeviceModel, IoObserver, NullObserver};
use super::engine::{ChunkWriter, IoEngine, IoRequest, IoTicket};
use super::page_cache::PageCache;

/// A path on a simulated device: `(device, relative path)`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SimPath {
    pub device: String,
    pub rel: String,
}

impl SimPath {
    pub fn new(device: impl Into<String>, rel: impl Into<String>) -> Self {
        SimPath { device: device.into(), rel: rel.into() }
    }

    /// Parse `"device://rel/path"` (the paper's "substituting the
    /// prefix of a file path" idiom, §II).
    pub fn parse(s: &str) -> Result<SimPath> {
        let (dev, rel) = s
            .split_once("://")
            .ok_or_else(|| anyhow!("expected device://path, got {s:?}"))?;
        if dev.is_empty() || rel.is_empty() {
            return Err(anyhow!("empty device or path in {s:?}"));
        }
        Ok(SimPath::new(dev, rel))
    }
}

impl std::fmt::Display for SimPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}://{}", self.device, self.rel)
    }
}

/// The simulated storage system: devices + page cache + backing dir,
/// with all device traffic scheduled by the request-level engine.
pub struct StorageSim {
    root: PathBuf,
    devices: HashMap<String, Arc<Device>>,
    engine: IoEngine,
    cache: PageCache,
}

/// An in-flight (or cache-served) read; resolve with
/// [`wait`](PendingRead::wait).
pub enum PendingRead {
    /// Page-cache hit: served from memory, no device charge.
    Ready(Vec<u8>),
    /// Cold read in flight on the engine.
    InFlight(IoTicket),
}

impl PendingRead {
    /// Block until the data is available.
    pub fn wait(self) -> Result<Vec<u8>> {
        match self {
            PendingRead::Ready(data) => Ok(data),
            PendingRead::InFlight(ticket) => {
                let c = ticket.wait()?;
                c.data.ok_or_else(|| anyhow!("read completion without data"))
            }
        }
    }

    /// Non-blocking completion check.
    pub fn ready(&self) -> bool {
        match self {
            PendingRead::Ready(_) => true,
            PendingRead::InFlight(t) => t.ready(),
        }
    }
}

/// An in-flight write; resolve with [`StorageSim::finish_write`] so
/// the page cache learns about the written file.
pub struct PendingWrite {
    ticket: IoTicket,
    cache_key: String,
}

impl StorageSim {
    /// Create a sim rooted at `root` with the given device models.
    /// `cache_capacity` = 0 reproduces the paper's cold-cache protocol.
    pub fn new(
        root: impl Into<PathBuf>,
        models: Vec<DeviceModel>,
        cache_capacity: u64,
        observer: Arc<dyn IoObserver>,
    ) -> Result<Self> {
        let root = root.into();
        let mut devices = HashMap::new();
        for m in models {
            std::fs::create_dir_all(root.join(&m.name))
                .with_context(|| format!("mkdir device dir {}", m.name))?;
            devices.insert(
                m.name.clone(),
                Arc::new(Device::new(m, Arc::clone(&observer))),
            );
        }
        let engine = IoEngine::new(&devices);
        Ok(StorageSim {
            root,
            devices,
            engine,
            cache: PageCache::new(cache_capacity),
        })
    }

    /// Convenience: no tracing, no cache.
    pub fn cold(root: impl Into<PathBuf>, models: Vec<DeviceModel>) -> Result<Self> {
        Self::new(root, models, 0, Arc::new(NullObserver))
    }

    pub fn device(&self, name: &str) -> Result<&Arc<Device>> {
        self.devices
            .get(name)
            .ok_or_else(|| anyhow!("unknown device {name:?}"))
    }

    pub fn device_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.devices.keys().cloned().collect();
        v.sort();
        v
    }

    /// Absolute backing path for a sim path.
    pub fn backing_path(&self, p: &SimPath) -> PathBuf {
        self.root.join(&p.device).join(&p.rel)
    }

    pub fn cache(&self) -> &PageCache {
        &self.cache
    }

    /// The request-level I/O engine scheduling this sim's devices.
    pub fn engine(&self) -> &IoEngine {
        &self.engine
    }

    /// Read a whole file through the device model (tf.read_file()).
    /// Page-cache hits bypass the device.  Blocking wrapper over
    /// [`read_async`](Self::read_async).
    pub fn read(&self, p: &SimPath) -> Result<Vec<u8>> {
        self.read_async(p)?.wait()
    }

    /// Submit a read; returns immediately with a [`PendingRead`].
    /// The cache is consulted (and populated on a miss) at submit
    /// time, matching the blocking path's semantics.
    pub fn read_async(&self, p: &SimPath) -> Result<PendingRead> {
        let _ = self.device(&p.device)?;
        let path = self.backing_path(p);
        let size = std::fs::metadata(&path)
            .with_context(|| format!("stat {p}"))?
            .len();
        let key = p.to_string();
        if self.cache.access(&key, size) {
            // Warm: served from memory, no device charge.
            let data =
                std::fs::read(&path).with_context(|| format!("read {p}"))?;
            return Ok(PendingRead::Ready(data));
        }
        let ticket = self.engine.submit(IoRequest::ReadFile {
            device: p.device.clone(),
            path,
        })?;
        Ok(PendingRead::InFlight(ticket))
    }

    /// Write a whole file through the device model (checkpoint path).
    /// Streams the borrowed payload through the engine in bounded
    /// chunks — no payload-sized intermediate buffer.
    pub fn write(&self, p: &SimPath, data: &[u8]) -> Result<()> {
        let (mut writer, pending) = self.write_stream(p)?;
        writer.push(data)?;
        writer.finish()?;
        self.finish_write(pending)?;
        Ok(())
    }

    /// Submit a whole-buffer write; returns immediately.  Resolve with
    /// [`finish_write`](Self::finish_write).
    pub fn write_async(&self, p: &SimPath, data: Vec<u8>) -> Result<PendingWrite> {
        let _ = self.device(&p.device)?;
        let path = self.backing_path(p);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let ticket = self.engine.submit(IoRequest::WriteFile {
            device: p.device.clone(),
            path,
            data,
        })?;
        Ok(PendingWrite { ticket, cache_key: p.to_string() })
    }

    /// Submit several whole-buffer writes through one engine doorbell:
    /// every request joins its device queue before any is serviced, so
    /// the elevator model sees the whole burst (how the overlapped
    /// checkpoint triple beats three serial writes on an HDD).
    pub fn write_batch_async(
        &self,
        writes: Vec<(&SimPath, Vec<u8>)>,
    ) -> Result<Vec<PendingWrite>> {
        let mut reqs = Vec::with_capacity(writes.len());
        let mut keys = Vec::with_capacity(writes.len());
        for (p, data) in writes {
            let _ = self.device(&p.device)?;
            let path = self.backing_path(p);
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            keys.push(p.to_string());
            reqs.push(IoRequest::WriteFile {
                device: p.device.clone(),
                path,
                data,
            });
        }
        let tickets = self.engine.submit_batch(reqs)?;
        Ok(tickets
            .into_iter()
            .zip(keys)
            .map(|(ticket, cache_key)| PendingWrite { ticket, cache_key })
            .collect())
    }

    /// Open a chunked streaming write (bounded memory): push bytes via
    /// the returned [`ChunkWriter`], `finish()` it, then resolve the
    /// [`PendingWrite`].
    pub fn write_stream(&self, p: &SimPath) -> Result<(ChunkWriter, PendingWrite)> {
        let _ = self.device(&p.device)?;
        let path = self.backing_path(p);
        let (writer, ticket) = self.engine.write_stream(&p.device, path)?;
        Ok((writer, PendingWrite { ticket, cache_key: p.to_string() }))
    }

    /// Wait for a submitted write and record it in the page cache
    /// (ext4 journaling behaviour the paper describes in §V-C).
    /// Returns the bytes written.
    pub fn finish_write(&self, pending: PendingWrite) -> Result<u64> {
        let c = pending.ticket.wait()?;
        self.cache.access(&pending.cache_key, c.bytes);
        Ok(c.bytes)
    }

    /// Copy a file between devices, paying a read on `src`'s device and
    /// a write on `dst`'s (the burst-buffer drain path).  Chunked and
    /// pipelined by the engine: peak memory is bounded by the stream
    /// window, and the source read overlaps the destination write.
    pub fn copy(&self, src: &SimPath, dst: &SimPath) -> Result<u64> {
        let ticket = self.copy_async(src, dst)?;
        let c = ticket.wait()?;
        self.cache.access(&dst.to_string(), c.bytes);
        Ok(c.bytes)
    }

    /// Submit a chunked cross-device copy; returns immediately.
    /// As with [`read_async`](Self::read_async), a page-cache hit on
    /// the source serves the read from memory (only the destination
    /// write is charged), matching the blocking path's old semantics.
    pub fn copy_async(&self, src: &SimPath, dst: &SimPath) -> Result<IoTicket> {
        let _ = self.device(&src.device)?;
        let _ = self.device(&dst.device)?;
        let src_path = self.backing_path(src);
        let size = std::fs::metadata(&src_path)
            .with_context(|| format!("stat {src}"))?
            .len();
        if self.cache.access(&src.to_string(), size) {
            // Warm source: no device charge for the read half; the
            // write still streams in bounded chunks.
            return self.engine.write_from_file(
                &dst.device,
                src_path,
                self.backing_path(dst),
            );
        }
        self.engine.submit(IoRequest::Copy {
            src_device: src.device.clone(),
            src_path,
            dst_device: dst.device.clone(),
            dst_path: self.backing_path(dst),
        })
    }

    /// Remove a file (checkpoint retention cleanup).
    pub fn remove(&self, p: &SimPath) -> Result<()> {
        let _ = self.device(&p.device)?;
        self.cache.invalidate(&p.to_string());
        std::fs::remove_file(self.backing_path(p))
            .with_context(|| format!("remove {p}"))
    }

    pub fn exists(&self, p: &SimPath) -> bool {
        self.backing_path(p).exists()
    }

    pub fn file_size(&self, p: &SimPath) -> Result<u64> {
        Ok(std::fs::metadata(self.backing_path(p))?.len())
    }

    /// Pace a read of `bytes` through the device model *without* any
    /// backing-file I/O.  Used by bandwidth probes (IOR, Table I)
    /// where only the service-time envelope matters — backing-store
    /// speed must not cap the modelled device.
    pub fn probe_read(&self, device: &str, bytes: u64) -> Result<()> {
        self.engine
            .submit(IoRequest::ProbeRead { device: device.into(), bytes })?
            .wait()?;
        Ok(())
    }

    /// Pacing-only write probe (see [`probe_read`](Self::probe_read)).
    pub fn probe_write(&self, device: &str, bytes: u64) -> Result<()> {
        self.engine
            .submit(IoRequest::ProbeWrite { device: device.into(), bytes })?
            .wait()?;
        Ok(())
    }

    /// `syncfs()` on the backing filesystem of a device directory —
    /// the paper calls this after every checkpoint (§III-C).
    pub fn syncfs(&self, device: &str) -> Result<()> {
        let _ = self.device(device)?;
        let dir = std::fs::File::open(self.root.join(device))?;
        let rc = unsafe { libc::syncfs(std::os::fd::AsRawFd::as_raw_fd(&dir)) };
        if rc != 0 {
            return Err(anyhow!("syncfs failed: {}",
                               std::io::Error::last_os_error()));
        }
        Ok(())
    }

    /// Drop the simulated page cache (the paper's
    /// `echo 1 > /proc/sys/vm/drop_caches`).
    pub fn drop_caches(&self) {
        self.cache.drop_all();
    }

    /// List files under a device-relative directory, sorted.
    pub fn list(&self, device: &str, rel_dir: &str) -> Result<Vec<SimPath>> {
        let _ = self.device(device)?;
        let dir = self.root.join(device).join(rel_dir);
        if !dir.exists() {
            return Ok(Vec::new());
        }
        let mut out: Vec<PathBuf> = Vec::new();
        collect_files(&dir, &mut out)?;
        let root = self.root.join(device);
        let mut paths: Vec<SimPath> = out
            .into_iter()
            .map(|p| {
                let rel = p
                    .strip_prefix(&root)
                    .expect("backing path under device root")
                    .to_string_lossy()
                    .into_owned();
                SimPath::new(device, rel)
            })
            .collect();
        paths.sort_by(|a, b| a.rel.cmp(&b.rel));
        Ok(paths)
    }
}

fn collect_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_files(&path, out)?;
        } else {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::device::DeviceModel;

    fn fast_model(name: &str) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 8,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
        }
    }

    fn sim(tag: &str) -> StorageSim {
        let dir = std::env::temp_dir().join(format!("dlio-sim-test-{tag}-{}",
            std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        StorageSim::cold(dir, vec![fast_model("ssd"), fast_model("hdd")])
            .unwrap()
    }

    #[test]
    fn simpath_parse_and_display() {
        let p = SimPath::parse("ssd://a/b.bin").unwrap();
        assert_eq!(p.device, "ssd");
        assert_eq!(p.rel, "a/b.bin");
        assert_eq!(p.to_string(), "ssd://a/b.bin");
        assert!(SimPath::parse("nope").is_err());
        assert!(SimPath::parse("://x").is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let s = sim("rw");
        let p = SimPath::new("ssd", "dir/file.bin");
        s.write(&p, b"hello world").unwrap();
        assert_eq!(s.read(&p).unwrap(), b"hello world");
        assert_eq!(s.file_size(&p).unwrap(), 11);
    }

    #[test]
    fn read_missing_file_errors() {
        let s = sim("missing");
        assert!(s.read(&SimPath::new("ssd", "nope.bin")).is_err());
    }

    #[test]
    fn unknown_device_errors() {
        let s = sim("unknown");
        assert!(s.read(&SimPath::new("tape", "x")).is_err());
        assert!(s.device("tape").is_err());
    }

    #[test]
    fn copy_moves_bytes_across_devices() {
        let s = sim("copy");
        let src = SimPath::new("ssd", "x.bin");
        let dst = SimPath::new("hdd", "x.bin");
        s.write(&src, &vec![7u8; 1024]).unwrap();
        let n = s.copy(&src, &dst).unwrap();
        assert_eq!(n, 1024);
        assert_eq!(s.read(&dst).unwrap(), vec![7u8; 1024]);
    }

    #[test]
    fn remove_deletes_backing_file() {
        let s = sim("rm");
        let p = SimPath::new("ssd", "x.bin");
        s.write(&p, b"x").unwrap();
        assert!(s.exists(&p));
        s.remove(&p).unwrap();
        assert!(!s.exists(&p));
    }

    #[test]
    fn list_returns_sorted_recursive() {
        let s = sim("list");
        for name in ["b/2.bin", "a/1.bin", "c.bin"] {
            s.write(&SimPath::new("ssd", name), b"x").unwrap();
        }
        let files = s.list("ssd", "").unwrap();
        let rels: Vec<_> = files.iter().map(|p| p.rel.as_str()).collect();
        assert_eq!(rels, vec!["a/1.bin", "b/2.bin", "c.bin"]);
    }

    #[test]
    fn syncfs_succeeds_on_real_fs() {
        let s = sim("sync");
        s.write(&SimPath::new("ssd", "x.bin"), b"x").unwrap();
        s.syncfs("ssd").unwrap();
    }

    #[test]
    fn warm_cache_serves_without_device() {
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-test-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Slow device (1 MB/s, unscaled) + big cache: the warm read
        // must be far faster than the cold one.  Bounds are relative
        // (warm vs cold) rather than absolute wall-clock, so a loaded
        // CI host cannot flake the assertion.
        let model = DeviceModel {
            name: "slow".into(),
            read_bw: 1e6,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 1,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
        };
        let s = StorageSim::new(dir, vec![model], 1 << 30,
                                Arc::new(crate::storage::device::NullObserver))
            .unwrap();
        let p = SimPath::new("slow", "f.bin");
        // write goes through write_bucket (fast) and caches the file
        s.write(&p, &vec![1u8; 200_000]).unwrap();
        let t0 = std::time::Instant::now();
        s.read(&p).unwrap(); // cache hit
        let warm = t0.elapsed().as_secs_f64();
        s.drop_caches();
        let t0 = std::time::Instant::now();
        s.read(&p).unwrap(); // cold: 200 KB at 1 MB/s ≈ 0.2 s
        let cold = t0.elapsed().as_secs_f64();
        // The cold read sleeps through ~0.14 s of modelled pacing
        // (burst credit shaves ~64 KB) — a deterministic lower bound.
        assert!(cold > 0.08, "cold read unpaced: {cold}");
        assert!(warm < cold / 2.0, "warm {warm} !<< cold {cold}");
    }

    #[test]
    fn async_reads_overlap_on_the_engine() {
        // Submit N cold reads at once on a multi-channel device: all
        // tickets resolve, data intact, submits don't block.
        let s = sim("async");
        let mut pending = Vec::new();
        for i in 0..8 {
            let p = SimPath::new("ssd", format!("f{i}.bin"));
            s.write(&p, &vec![i as u8; 4096]).unwrap();
        }
        s.drop_caches();
        for i in 0..8 {
            let p = SimPath::new("ssd", format!("f{i}.bin"));
            pending.push((i, s.read_async(&p).unwrap()));
        }
        for (i, pr) in pending {
            assert_eq!(pr.wait().unwrap(), vec![i as u8; 4096]);
        }
    }

    #[test]
    fn warm_source_copy_skips_src_device_but_streams_bounded() {
        use crate::storage::device::{Dir, IoObserver};
        use std::sync::atomic::{AtomicU64, Ordering};
        struct Reads(AtomicU64);
        impl IoObserver for Reads {
            fn record(&self, device: &str, dir: Dir, bytes: u64) {
                if device == "src" && dir == Dir::Read {
                    self.0.fetch_add(bytes, Ordering::SeqCst);
                }
            }
        }
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-warmcopy-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let obs = Arc::new(Reads(AtomicU64::new(0)));
        let s = StorageSim::new(
            dir,
            vec![fast_model("src"), fast_model("dst")],
            1 << 30, // warm page cache
            obs.clone(),
        )
        .unwrap();
        let src = SimPath::new("src", "ck.bin");
        let dst = SimPath::new("dst", "ck.bin");
        // Larger than several chunks so the warm path must stream.
        let payload: Vec<u8> =
            (0..3_000_000u32).map(|i| (i % 241) as u8).collect();
        s.write(&src, &payload).unwrap(); // lands in the page cache
        let n = s.copy(&src, &dst).unwrap();
        assert_eq!(n, payload.len() as u64);
        assert_eq!(s.read(&dst).unwrap(), payload);
        // Warm source: the copy charged no reads on the src device.
        assert_eq!(obs.0.load(Ordering::SeqCst), 0, "src device was charged");
        // And the stream window bounded the transfer memory.
        let bound = (s.engine().chunk_size() * 6) as u64;
        assert!(
            s.engine().peak_stream_bytes() <= bound,
            "peak {} exceeds bound {bound}",
            s.engine().peak_stream_bytes()
        );
    }

    #[test]
    fn write_stream_roundtrips_through_engine() {
        let s = sim("stream");
        let p = SimPath::new("ssd", "ck/stream.bin");
        let (mut w, pending) = s.write_stream(&p).unwrap();
        let payload: Vec<u8> = (0..300_000u32).map(|i| (i % 253) as u8).collect();
        for piece in payload.chunks(7001) {
            w.push(piece).unwrap();
        }
        w.finish().unwrap();
        assert_eq!(s.finish_write(pending).unwrap(), payload.len() as u64);
        assert_eq!(s.read(&p).unwrap(), payload);
    }
}
