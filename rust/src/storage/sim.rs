//! [`StorageSim`]: the facade tying devices, page cache and backing
//! files together.
//!
//! Each simulated device owns a directory under the sim root; reads and
//! writes perform *real* file I/O there (so checkpoints can actually be
//! restored and corpora actually decoded) while service timing is paced
//! by the [`Device`] queueing model.  This is the layer every consumer
//! (pipeline map functions, the checkpoint saver, IOR) talks to — the
//! equivalent of the paper's "file system adapter" interface (Fig. 1).
//!
//! All device traffic flows through the request-level
//! [`IoEngine`](super::engine::IoEngine): the classic blocking calls
//! (`read`/`write`/`copy`/probes) are thin submit-then-wait wrappers,
//! and the `*_async` variants expose the submission/completion surface
//! directly (pipeline readahead, overlapped checkpoint saves,
//! burst-buffer drains).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::clock::Clock;
use super::device::{Device, DeviceModel, IoObserver, NullObserver};
use super::engine::{
    ChunkWriter, IoClass, IoEngine, IoRequest, IoTicket, QosConfig,
};
use super::fault::FaultPlan;
use super::page_cache::PageCache;

/// A path on a simulated device: `(device, relative path)`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SimPath {
    pub device: String,
    pub rel: String,
}

impl SimPath {
    pub fn new(device: impl Into<String>, rel: impl Into<String>) -> Self {
        SimPath { device: device.into(), rel: rel.into() }
    }

    /// Parse `"device://rel/path"` (the paper's "substituting the
    /// prefix of a file path" idiom, §II).
    pub fn parse(s: &str) -> Result<SimPath> {
        let (dev, rel) = s
            .split_once("://")
            .ok_or_else(|| anyhow!("expected device://path, got {s:?}"))?;
        if dev.is_empty() || rel.is_empty() {
            return Err(anyhow!("empty device or path in {s:?}"));
        }
        Ok(SimPath::new(dev, rel))
    }
}

impl std::fmt::Display for SimPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}://{}", self.device, self.rel)
    }
}

/// The simulated storage system: devices + page cache + backing dir,
/// with all device traffic scheduled by the request-level engine.
pub struct StorageSim {
    root: PathBuf,
    devices: HashMap<String, Arc<Device>>,
    engine: IoEngine,
    cache: PageCache,
    /// Cache keys with engine writes/copies in flight (count per key).
    /// While a key is dirty, reads bypass the page cache entirely —
    /// without this, a read during an overwrite would re-insert the
    /// key on its miss and the NEXT read would be served warm from the
    /// stale/partial backing file.  `finish_write` (or the blocking
    /// copy, or a dropped `PendingWrite`) releases the count; the
    /// cache only re-learns the file once the key is fully clean.
    dirty: DirtyMap,
}

/// An in-flight (or cache-served) read; resolve with
/// [`wait`](PendingRead::wait).
pub enum PendingRead {
    /// Page-cache hit: served from memory, no device charge.
    Ready(Vec<u8>),
    /// Cold read in flight on the engine.
    InFlight(IoTicket),
}

impl PendingRead {
    /// Block until the data is available.
    pub fn wait(self) -> Result<Vec<u8>> {
        match self {
            PendingRead::Ready(data) => Ok(data),
            PendingRead::InFlight(ticket) => {
                let c = ticket.wait()?;
                c.data.ok_or_else(|| anyhow!("read completion without data"))
            }
        }
    }

    /// Non-blocking completion check.
    pub fn ready(&self) -> bool {
        match self {
            PendingRead::Ready(_) => true,
            PendingRead::InFlight(t) => t.ready(),
        }
    }
}

/// Keys with engine overwrites in flight (count per key), shared with
/// every [`PendingWrite`] so abandoning one still releases its mark.
type DirtyMap = Arc<Mutex<HashMap<String, u32>>>;

/// Decrement `key`'s in-flight-overwrite count; returns `true` when
/// no overwrites remain (only then may the cache re-learn the file).
fn release_dirty(dirty: &DirtyMap, key: &str) -> bool {
    let mut d = dirty.lock().unwrap();
    match d.get_mut(key) {
        Some(n) if *n > 1 => {
            *n -= 1;
            false
        }
        Some(_) => {
            d.remove(key);
            true
        }
        // Untracked: treat as clean.
        None => true,
    }
}

/// An in-flight write; resolve with [`StorageSim::finish_write`] so
/// the page cache learns about the written file.
pub struct PendingWrite {
    ticket: Option<IoTicket>,
    cache_key: String,
    dirty: DirtyMap,
    released: bool,
}

impl PendingWrite {
    fn new(ticket: IoTicket, cache_key: String, dirty: &DirtyMap)
        -> PendingWrite
    {
        PendingWrite {
            ticket: Some(ticket),
            cache_key,
            dirty: Arc::clone(dirty),
            released: false,
        }
    }

    /// Release this write's dirty mark (once); `true` = key now clean.
    fn release(&mut self) -> bool {
        if self.released {
            return false;
        }
        self.released = true;
        release_dirty(&self.dirty, &self.cache_key)
    }
}

impl Drop for PendingWrite {
    fn drop(&mut self) {
        // Abandoned without finish_write (an error-path `?` in the
        // caller): lift the mark so the key is not uncacheable
        // forever.  The write may still be in flight, but a read that
        // then caches a partial file self-corrects via the page
        // cache's stale-size reconciliation on the next access.
        self.release();
    }
}

impl StorageSim {
    /// Create a sim rooted at `root` with the given device models.
    /// `cache_capacity` = 0 reproduces the paper's cold-cache protocol.
    pub fn new(
        root: impl Into<PathBuf>,
        models: Vec<DeviceModel>,
        cache_capacity: u64,
        observer: Arc<dyn IoObserver>,
    ) -> Result<Self> {
        Self::with_qos(root, models, cache_capacity, observer,
                       QosConfig::default())
    }

    /// Create a sim with an explicit engine scheduler config (FIFO
    /// baseline vs weighted DRR; see [`QosConfig`]).
    pub fn with_qos(
        root: impl Into<PathBuf>,
        models: Vec<DeviceModel>,
        cache_capacity: u64,
        observer: Arc<dyn IoObserver>,
        qos: QosConfig,
    ) -> Result<Self> {
        Self::with_qos_clock(root, models, cache_capacity, observer, qos,
                             Clock::wall())
    }

    /// Full constructor: explicit scheduler config *and* time source.
    /// Every device, the engine, and all pacing run against `clock`;
    /// pass [`Clock::virt`] to run the whole sim in discrete-event
    /// time (sweep drivers do this by default).
    pub fn with_qos_clock(
        root: impl Into<PathBuf>,
        models: Vec<DeviceModel>,
        cache_capacity: u64,
        observer: Arc<dyn IoObserver>,
        qos: QosConfig,
        clock: Clock,
    ) -> Result<Self> {
        let root = root.into();
        let mut devices = HashMap::new();
        for m in models {
            std::fs::create_dir_all(root.join(&m.name))
                .with_context(|| format!("mkdir device dir {}", m.name))?;
            devices.insert(
                m.name.clone(),
                Arc::new(Device::with_clock(
                    m,
                    Arc::clone(&observer),
                    clock.clone(),
                )),
            );
        }
        let engine = IoEngine::with_config(
            &devices,
            super::engine::DEFAULT_CHUNK,
            qos,
        );
        Ok(StorageSim {
            root,
            devices,
            engine,
            cache: PageCache::new(cache_capacity),
            dirty: Arc::new(Mutex::new(HashMap::new())),
        })
    }

    /// Convenience: no tracing, no cache.
    pub fn cold(root: impl Into<PathBuf>, models: Vec<DeviceModel>) -> Result<Self> {
        Self::new(root, models, 0, Arc::new(NullObserver))
    }

    /// Convenience: no tracing, no cache, explicit scheduler config.
    pub fn cold_with_qos(
        root: impl Into<PathBuf>,
        models: Vec<DeviceModel>,
        qos: QosConfig,
    ) -> Result<Self> {
        Self::with_qos(root, models, 0, Arc::new(NullObserver), qos)
    }

    /// Convenience: no tracing, no cache, explicit scheduler config
    /// and time source.
    pub fn cold_with_qos_clock(
        root: impl Into<PathBuf>,
        models: Vec<DeviceModel>,
        qos: QosConfig,
        clock: Clock,
    ) -> Result<Self> {
        Self::with_qos_clock(root, models, 0, Arc::new(NullObserver), qos,
                             clock)
    }

    pub fn device(&self, name: &str) -> Result<&Arc<Device>> {
        self.devices
            .get(name)
            .ok_or_else(|| anyhow!("unknown device {name:?}"))
    }

    pub fn device_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.devices.keys().cloned().collect();
        v.sort();
        v
    }

    /// Arm `plan` on this sim's devices at the current clock time.
    /// Every targeted device gets its own armed
    /// [`DeviceHealth`](super::fault::DeviceHealth) handle; devices
    /// the plan does not target are reset to healthy, so re-arming a
    /// different plan fully replaces the old one.  A plan naming a
    /// device this sim does not have is an error listing the valid
    /// names.
    pub fn apply_fault_plan(&self, plan: &FaultPlan) -> Result<()> {
        for spec in &plan.devices {
            if spec.device != "*"
                && !self.devices.contains_key(&spec.device)
            {
                return Err(anyhow!(
                    "fault plan targets unknown device {:?} (valid: {})",
                    spec.device,
                    self.device_names().join(", ")
                ));
            }
        }
        for (name, dev) in &self.devices {
            dev.set_health(plan.arm(name, self.clock()).map(Arc::new));
        }
        Ok(())
    }

    /// Detach every armed fault schedule (all devices healthy again).
    pub fn clear_faults(&self) {
        for dev in self.devices.values() {
            dev.set_health(None);
        }
    }

    /// Absolute backing path for a sim path.
    pub fn backing_path(&self, p: &SimPath) -> PathBuf {
        self.root.join(&p.device).join(&p.rel)
    }

    pub fn cache(&self) -> &PageCache {
        &self.cache
    }

    /// Mark `key` as having an overwrite in flight (and drop any
    /// cached entry for it).
    fn mark_dirty(&self, key: &str) {
        *self.dirty.lock().unwrap().entry(key.to_string()).or_insert(0) += 1;
        self.cache.invalidate(key);
    }

    fn is_dirty(&self, key: &str) -> bool {
        self.dirty.lock().unwrap().contains_key(key)
    }

    /// Is an engine overwrite currently in flight for this path?  The
    /// dirty-key guard, exposed so cache-like layers stacked above the
    /// sim (the hierarchy's RAM tiers) can apply the same
    /// mid-overwrite bypass instead of serving a torn backing file.
    pub fn overwrite_in_flight(&self, p: &SimPath) -> bool {
        self.is_dirty(&p.to_string())
    }

    /// The request-level I/O engine scheduling this sim's devices.
    pub fn engine(&self) -> &IoEngine {
        &self.engine
    }

    /// The time source every device of this sim paces against.
    pub fn clock(&self) -> &Clock {
        self.engine.clock()
    }

    /// Read a whole file through the device model (tf.read_file()).
    /// Page-cache hits bypass the device.  Blocking wrapper over
    /// [`read_async`](Self::read_async).
    pub fn read(&self, p: &SimPath) -> Result<Vec<u8>> {
        self.read_async(p)?.wait()
    }

    /// Submit a read under [`IoClass::Ingest`] (the dataset-source
    /// default); returns immediately with a [`PendingRead`].
    /// The cache is consulted (and populated on a miss) at submit
    /// time, matching the blocking path's semantics.
    pub fn read_async(&self, p: &SimPath) -> Result<PendingRead> {
        self.read_async_class(p, IoClass::Ingest)
    }

    /// Submit a read under an explicit traffic class.
    pub fn read_async_class(
        &self,
        p: &SimPath,
        class: IoClass,
    ) -> Result<PendingRead> {
        let _ = self.device(&p.device)?;
        let path = self.backing_path(p);
        let size = std::fs::metadata(&path)
            .with_context(|| format!("stat {p}"))?
            .len();
        let key = p.to_string();
        // A key with an overwrite in flight bypasses the cache both
        // ways: no stale hit, and no miss-insert that would let the
        // NEXT read hit stale.
        if !self.is_dirty(&key) && self.cache.access(&key, size) {
            // Warm: served from memory, no device charge.
            let data =
                std::fs::read(&path).with_context(|| format!("read {p}"))?;
            return Ok(PendingRead::Ready(data));
        }
        // The stat above already sized the file: pass it through so
        // the engine's DRR cost doesn't re-stat on the hot path.
        let ticket =
            self.engine.submit_read_sized(&p.device, path, size, class)?;
        Ok(PendingRead::InFlight(ticket))
    }

    /// Write a whole file through the device model (checkpoint path).
    /// Streams the borrowed payload through the engine in bounded
    /// chunks — no payload-sized intermediate buffer.
    pub fn write(&self, p: &SimPath, data: &[u8]) -> Result<()> {
        self.write_class(p, data, IoClass::Checkpoint)
    }

    /// Blocking whole-file write under an explicit class.
    pub fn write_class(
        &self,
        p: &SimPath,
        data: &[u8],
        class: IoClass,
    ) -> Result<()> {
        let (mut writer, pending) = self.write_stream_class(p, class)?;
        writer.push(data)?;
        writer.finish()?;
        self.finish_write(pending)?;
        Ok(())
    }

    /// Submit a whole-buffer write; returns immediately.  Resolve with
    /// [`finish_write`](Self::finish_write).
    pub fn write_async(&self, p: &SimPath, data: Vec<u8>) -> Result<PendingWrite> {
        self.write_async_class(p, data, IoClass::Checkpoint)
    }

    /// [`write_async`](Self::write_async) under an explicit class.
    pub fn write_async_class(
        &self,
        p: &SimPath,
        data: Vec<u8>,
        class: IoClass,
    ) -> Result<PendingWrite> {
        let _ = self.device(&p.device)?;
        let path = self.backing_path(p);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        // The overwrite is in flight from this point: a cached entry
        // for the old contents must not serve (stale-size accounting,
        // torn mid-overwrite reads).  finish_write re-inserts the new
        // file once it is durable.
        let key = p.to_string();
        self.mark_dirty(&key);
        let ticket = match self.engine.submit_class(
            IoRequest::WriteFile { device: p.device.clone(), path, data },
            class,
        ) {
            Ok(t) => t,
            Err(e) => {
                release_dirty(&self.dirty, &key);
                return Err(e);
            }
        };
        Ok(PendingWrite::new(ticket, key, &self.dirty))
    }

    /// Submit several whole-buffer writes through one engine doorbell:
    /// every request joins its device queue before any is serviced, so
    /// the elevator model sees the whole burst (how the overlapped
    /// checkpoint triple beats three serial writes on an HDD).
    pub fn write_batch_async(
        &self,
        writes: Vec<(&SimPath, Vec<u8>)>,
    ) -> Result<Vec<PendingWrite>> {
        self.write_batch_async_class(writes, IoClass::Checkpoint)
    }

    /// One-doorbell batch of writes under an explicit class.
    pub fn write_batch_async_class(
        &self,
        writes: Vec<(&SimPath, Vec<u8>)>,
        class: IoClass,
    ) -> Result<Vec<PendingWrite>> {
        // Build (and run every fallible per-item step) BEFORE marking
        // anything dirty, so an early `?` cannot leak a mark.
        let mut reqs = Vec::with_capacity(writes.len());
        let mut keys = Vec::with_capacity(writes.len());
        for (p, data) in writes {
            let _ = self.device(&p.device)?;
            let path = self.backing_path(p);
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            keys.push(p.to_string());
            reqs.push(IoRequest::WriteFile {
                device: p.device.clone(),
                path,
                data,
            });
        }
        // Overwrites in flight: stale cache entries must not serve.
        for key in &keys {
            self.mark_dirty(key);
        }
        let tickets = match self.engine.submit_batch_class(reqs, class) {
            Ok(t) => t,
            Err(e) => {
                for key in &keys {
                    release_dirty(&self.dirty, key);
                }
                return Err(e);
            }
        };
        Ok(tickets
            .into_iter()
            .zip(keys)
            .map(|(ticket, key)| PendingWrite::new(ticket, key, &self.dirty))
            .collect())
    }

    /// Open a chunked streaming write (bounded memory): push bytes via
    /// the returned [`ChunkWriter`], `finish()` it, then resolve the
    /// [`PendingWrite`].
    pub fn write_stream(&self, p: &SimPath) -> Result<(ChunkWriter, PendingWrite)> {
        self.write_stream_class(p, IoClass::Checkpoint)
    }

    /// Streaming write under an explicit class.
    pub fn write_stream_class(
        &self,
        p: &SimPath,
        class: IoClass,
    ) -> Result<(ChunkWriter, PendingWrite)> {
        let _ = self.device(&p.device)?;
        let path = self.backing_path(p);
        // The stream truncates the backing file as soon as its worker
        // thread starts: any cached copy of the old contents is stale
        // from here on, so mark before the engine call (and release
        // the mark if that call never opened a stream).
        let key = p.to_string();
        self.mark_dirty(&key);
        let (writer, ticket) =
            match self.engine.write_stream_class(&p.device, path, class) {
                Ok(pair) => pair,
                Err(e) => {
                    release_dirty(&self.dirty, &key);
                    return Err(e);
                }
            };
        Ok((writer, PendingWrite::new(ticket, key, &self.dirty)))
    }

    /// Wait for a submitted write and record it in the page cache
    /// (ext4 journaling behaviour the paper describes in §V-C).
    /// Returns the bytes written.
    pub fn finish_write(&self, mut pending: PendingWrite) -> Result<u64> {
        let ticket = pending
            .ticket
            .take()
            .expect("PendingWrite resolved exactly once");
        let result = ticket.wait();
        // Lift the in-flight-overwrite mark whatever the outcome — a
        // failed write leaves the key uncached, not stuck dirty.
        let clean = pending.release();
        let c = result?;
        if clean {
            self.cache.access(&pending.cache_key, c.bytes);
        }
        Ok(c.bytes)
    }

    /// Copy a file between devices, paying a read on `src`'s device and
    /// a write on `dst`'s (the burst-buffer drain path).  Chunked and
    /// pipelined by the engine: peak memory is bounded by the stream
    /// window, and the source read overlaps the destination write.
    pub fn copy(&self, src: &SimPath, dst: &SimPath) -> Result<u64> {
        self.copy_class(src, dst, IoClass::Drain)
    }

    /// Blocking copy under an explicit class.
    pub fn copy_class(
        &self,
        src: &SimPath,
        dst: &SimPath,
        class: IoClass,
    ) -> Result<u64> {
        let pending = self.copy_async_class(src, dst, class)?;
        self.finish_write(pending)
    }

    /// Submit a chunked cross-device copy; returns immediately.
    /// As with [`read_async`](Self::read_async), a page-cache hit on
    /// the source serves the read from memory (only the destination
    /// write is charged), matching the blocking path's old semantics.
    /// Resolve with [`finish_write`](Self::finish_write) — a copy is a
    /// write to its destination, and the returned [`PendingWrite`]
    /// carries the destination's in-flight-overwrite mark (released
    /// on resolve or drop, never leaked).
    pub fn copy_async(&self, src: &SimPath, dst: &SimPath)
        -> Result<PendingWrite>
    {
        self.copy_async_class(src, dst, IoClass::Drain)
    }

    /// Asynchronous copy under an explicit class (the burst buffer
    /// drains as [`IoClass::Drain`]).
    pub fn copy_async_class(
        &self,
        src: &SimPath,
        dst: &SimPath,
        class: IoClass,
    ) -> Result<PendingWrite> {
        let _ = self.device(&src.device)?;
        let _ = self.device(&dst.device)?;
        let src_path = self.backing_path(src);
        let size = std::fs::metadata(&src_path)
            .with_context(|| format!("stat {src}"))?
            .len();
        // The destination is being overwritten: drop any stale cache
        // entry and keep it uncacheable until the copy resolves
        // (finish_write, or the PendingWrite's drop, releases the
        // mark).  A failed submission releases it here.
        let dst_key = dst.to_string();
        self.mark_dirty(&dst_key);
        let submitted = if !self.is_dirty(&src.to_string())
            && self.cache.access(&src.to_string(), size)
        {
            // Warm source: no device charge for the read half; the
            // write still streams in bounded chunks.
            self.engine.write_from_file_class(
                &dst.device,
                src_path,
                self.backing_path(dst),
                class,
            )
        } else {
            self.engine.submit_class(
                IoRequest::Copy {
                    src_device: src.device.clone(),
                    src_path,
                    dst_device: dst.device.clone(),
                    dst_path: self.backing_path(dst),
                },
                class,
            )
        };
        match submitted {
            Ok(ticket) => Ok(PendingWrite::new(ticket, dst_key, &self.dirty)),
            Err(e) => {
                release_dirty(&self.dirty, &dst_key);
                Err(e)
            }
        }
    }

    /// Remove a file (checkpoint retention cleanup).
    pub fn remove(&self, p: &SimPath) -> Result<()> {
        let _ = self.device(&p.device)?;
        self.cache.invalidate(&p.to_string());
        std::fs::remove_file(self.backing_path(p))
            .with_context(|| format!("remove {p}"))
    }

    pub fn exists(&self, p: &SimPath) -> bool {
        self.backing_path(p).exists()
    }

    pub fn file_size(&self, p: &SimPath) -> Result<u64> {
        Ok(std::fs::metadata(self.backing_path(p))?.len())
    }

    /// Pace a read of `bytes` through the device model *without* any
    /// backing-file I/O.  Used by bandwidth probes (IOR, Table I)
    /// where only the service-time envelope matters — backing-store
    /// speed must not cap the modelled device.
    pub fn probe_read(&self, device: &str, bytes: u64) -> Result<()> {
        self.engine
            .submit(IoRequest::ProbeRead { device: device.into(), bytes })?
            .wait()?;
        Ok(())
    }

    /// Pacing-only write probe (see [`probe_read`](Self::probe_read)).
    pub fn probe_write(&self, device: &str, bytes: u64) -> Result<()> {
        self.engine
            .submit(IoRequest::ProbeWrite { device: device.into(), bytes })?
            .wait()?;
        Ok(())
    }

    /// `syncfs()` on the backing filesystem of a device directory —
    /// the paper calls this after every checkpoint (§III-C).
    pub fn syncfs(&self, device: &str) -> Result<()> {
        let _ = self.device(device)?;
        let dir = std::fs::File::open(self.root.join(device))?;
        let rc = unsafe { libc::syncfs(std::os::fd::AsRawFd::as_raw_fd(&dir)) };
        if rc != 0 {
            return Err(anyhow!("syncfs failed: {}",
                               std::io::Error::last_os_error()));
        }
        Ok(())
    }

    /// Drop the simulated page cache (the paper's
    /// `echo 1 > /proc/sys/vm/drop_caches`).
    pub fn drop_caches(&self) {
        self.cache.drop_all();
    }

    /// List files under a device-relative directory, sorted.
    pub fn list(&self, device: &str, rel_dir: &str) -> Result<Vec<SimPath>> {
        let _ = self.device(device)?;
        let dir = self.root.join(device).join(rel_dir);
        if !dir.exists() {
            return Ok(Vec::new());
        }
        let mut out: Vec<PathBuf> = Vec::new();
        collect_files(&dir, &mut out)?;
        let root = self.root.join(device);
        let mut paths: Vec<SimPath> = out
            .into_iter()
            .map(|p| {
                let rel = p
                    .strip_prefix(&root)
                    .expect("backing path under device root")
                    .to_string_lossy()
                    .into_owned();
                SimPath::new(device, rel)
            })
            .collect();
        paths.sort_by(|a, b| a.rel.cmp(&b.rel));
        Ok(paths)
    }
}

fn collect_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_files(&path, out)?;
        } else {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::device::DeviceModel;

    fn fast_model(name: &str) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 8,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
            lat_tables: None,
        }
    }

    fn sim(tag: &str) -> StorageSim {
        let dir = std::env::temp_dir().join(format!("dlio-sim-test-{tag}-{}",
            std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        StorageSim::cold(dir, vec![fast_model("ssd"), fast_model("hdd")])
            .unwrap()
    }

    #[test]
    fn simpath_parse_and_display() {
        let p = SimPath::parse("ssd://a/b.bin").unwrap();
        assert_eq!(p.device, "ssd");
        assert_eq!(p.rel, "a/b.bin");
        assert_eq!(p.to_string(), "ssd://a/b.bin");
        assert!(SimPath::parse("nope").is_err());
        assert!(SimPath::parse("://x").is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let s = sim("rw");
        let p = SimPath::new("ssd", "dir/file.bin");
        s.write(&p, b"hello world").unwrap();
        assert_eq!(s.read(&p).unwrap(), b"hello world");
        assert_eq!(s.file_size(&p).unwrap(), 11);
    }

    #[test]
    fn read_missing_file_errors() {
        let s = sim("missing");
        assert!(s.read(&SimPath::new("ssd", "nope.bin")).is_err());
    }

    #[test]
    fn unknown_device_errors() {
        let s = sim("unknown");
        assert!(s.read(&SimPath::new("tape", "x")).is_err());
        assert!(s.device("tape").is_err());
    }

    #[test]
    fn copy_moves_bytes_across_devices() {
        let s = sim("copy");
        let src = SimPath::new("ssd", "x.bin");
        let dst = SimPath::new("hdd", "x.bin");
        s.write(&src, &vec![7u8; 1024]).unwrap();
        let n = s.copy(&src, &dst).unwrap();
        assert_eq!(n, 1024);
        assert_eq!(s.read(&dst).unwrap(), vec![7u8; 1024]);
    }

    #[test]
    fn remove_deletes_backing_file() {
        let s = sim("rm");
        let p = SimPath::new("ssd", "x.bin");
        s.write(&p, b"x").unwrap();
        assert!(s.exists(&p));
        s.remove(&p).unwrap();
        assert!(!s.exists(&p));
    }

    #[test]
    fn list_returns_sorted_recursive() {
        let s = sim("list");
        for name in ["b/2.bin", "a/1.bin", "c.bin"] {
            s.write(&SimPath::new("ssd", name), b"x").unwrap();
        }
        let files = s.list("ssd", "").unwrap();
        let rels: Vec<_> = files.iter().map(|p| p.rel.as_str()).collect();
        assert_eq!(rels, vec!["a/1.bin", "b/2.bin", "c.bin"]);
    }

    #[test]
    fn syncfs_succeeds_on_real_fs() {
        let s = sim("sync");
        s.write(&SimPath::new("ssd", "x.bin"), b"x").unwrap();
        s.syncfs("ssd").unwrap();
    }

    #[test]
    fn warm_cache_serves_without_device() {
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-test-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Slow device (1 MB/s, unscaled) + big cache, run on a virtual
        // clock: modelled durations are exact, so the warm read costs
        // precisely zero device time and the cold read costs precisely
        // its pacing debt — no wall-clock tolerance needed.
        let model = DeviceModel {
            name: "slow".into(),
            read_bw: 1e6,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 1,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
            lat_tables: None,
        };
        let clock = Clock::virt();
        let s = StorageSim::with_qos_clock(
            dir,
            vec![model],
            1 << 30,
            Arc::new(crate::storage::device::NullObserver),
            QosConfig::default(),
            clock.clone(),
        )
        .unwrap();
        let p = SimPath::new("slow", "f.bin");
        // write goes through write_bucket (fast) and caches the file
        s.write(&p, &vec![1u8; 200_000]).unwrap();
        let t0 = clock.now();
        s.read(&p).unwrap(); // cache hit: never touches the device
        let warm = clock.now() - t0;
        assert_eq!(warm, 0.0, "warm read consumed device time: {warm}");
        s.drop_caches();
        let t0 = clock.now();
        s.read(&p).unwrap();
        let cold = clock.now() - t0;
        // 200 KB at 1 MB/s, minus the bucket's 64 KiB burst credit.
        let expect = (200_000.0 - 65536.0) / 1e6;
        // Sub-µs slack only: per-chunk sleeps quantize to nanoseconds.
        assert!(
            (cold - expect).abs() < 1e-6,
            "cold read {cold} != modelled {expect}"
        );
    }

    #[test]
    fn async_reads_overlap_on_the_engine() {
        // Submit N cold reads at once on a multi-channel device: all
        // tickets resolve, data intact, submits don't block.
        let s = sim("async");
        let mut pending = Vec::new();
        for i in 0..8 {
            let p = SimPath::new("ssd", format!("f{i}.bin"));
            s.write(&p, &vec![i as u8; 4096]).unwrap();
        }
        s.drop_caches();
        for i in 0..8 {
            let p = SimPath::new("ssd", format!("f{i}.bin"));
            pending.push((i, s.read_async(&p).unwrap()));
        }
        for (i, pr) in pending {
            assert_eq!(pr.wait().unwrap(), vec![i as u8; 4096]);
        }
    }

    #[test]
    fn warm_source_copy_skips_src_device_but_streams_bounded() {
        use crate::storage::device::{Dir, IoObserver};
        use std::sync::atomic::{AtomicU64, Ordering};
        struct Reads(AtomicU64);
        impl IoObserver for Reads {
            fn record(&self, device: &str, dir: Dir, bytes: u64) {
                if device == "src" && dir == Dir::Read {
                    self.0.fetch_add(bytes, Ordering::SeqCst);
                }
            }
        }
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-warmcopy-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let obs = Arc::new(Reads(AtomicU64::new(0)));
        let s = StorageSim::new(
            dir,
            vec![fast_model("src"), fast_model("dst")],
            1 << 30, // warm page cache
            obs.clone(),
        )
        .unwrap();
        let src = SimPath::new("src", "ck.bin");
        let dst = SimPath::new("dst", "ck.bin");
        // Larger than several chunks so the warm path must stream.
        let payload: Vec<u8> =
            (0..3_000_000u32).map(|i| (i % 241) as u8).collect();
        s.write(&src, &payload).unwrap(); // lands in the page cache
        let n = s.copy(&src, &dst).unwrap();
        assert_eq!(n, payload.len() as u64);
        assert_eq!(s.read(&dst).unwrap(), payload);
        // Warm source: the copy charged no reads on the src device.
        assert_eq!(obs.0.load(Ordering::SeqCst), 0, "src device was charged");
        // And the stream window bounded the transfer memory.
        let bound = (s.engine().chunk_size() * 6) as u64;
        assert!(
            s.engine().peak_stream_bytes() <= bound,
            "peak {} exceeds bound {bound}",
            s.engine().peak_stream_bytes()
        );
    }

    #[test]
    fn engine_overwrite_invalidates_page_cache() {
        // Satellite regression: legacy StorageSim paths invalidated on
        // remove, but engine write/copy overwrites left stale entries
        // (stale size accounting; torn reads during the overwrite).
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-inval-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = StorageSim::new(
            dir,
            vec![fast_model("ssd")],
            1 << 30, // warm cache
            Arc::new(crate::storage::device::NullObserver),
        )
        .unwrap();
        let p = SimPath::new("ssd", "ck.bin");
        s.write(&p, &vec![1u8; 100]).unwrap();
        // Cached: a read is served without the device.
        assert!(matches!(s.read_async(&p).unwrap(), PendingRead::Ready(_)));
        assert_eq!(s.cache().resident_bytes(), 100);
        // Overwrite through the engine with a different size: the
        // cache must track the new file, not the stale 100 bytes.
        let payload = vec![2u8; 50_000];
        s.write(&p, &payload).unwrap();
        assert_eq!(s.cache().resident_bytes(), 50_000, "stale cached size");
        assert_eq!(s.read(&p).unwrap(), payload);
        // Copy overwrites invalidate the destination too.
        let src = SimPath::new("ssd", "src.bin");
        s.write(&src, &vec![3u8; 256]).unwrap();
        s.copy(&src, &p).unwrap();
        assert_eq!(s.read(&p).unwrap(), vec![3u8; 256]);
        // src (256) + freshly re-inserted dst (256): the 50 KB entry
        // was dropped when the copy overwrote it.
        assert_eq!(s.cache().resident_bytes(), 512);
    }

    #[test]
    fn in_flight_stream_overwrite_is_not_served_from_cache() {
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-torn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = StorageSim::new(
            dir,
            vec![fast_model("ssd")],
            1 << 30,
            Arc::new(crate::storage::device::NullObserver),
        )
        .unwrap();
        let p = SimPath::new("ssd", "x.bin");
        s.write(&p, &vec![7u8; 4096]).unwrap();
        assert!(matches!(s.read_async(&p).unwrap(), PendingRead::Ready(_)));
        // Open a streaming overwrite (truncates the backing file) and
        // read while it is in flight: the cache MUST NOT serve the old
        // entry — the read has to go through the engine.
        let (mut w, pending) = s.write_stream(&p).unwrap();
        w.push(&[8u8; 10]).unwrap();
        let pr = s.read_async(&p).unwrap();
        assert!(
            matches!(pr, PendingRead::InFlight(_)),
            "cache served a file with an overwrite in flight"
        );
        // The first read's miss must NOT have re-inserted the key: a
        // second read during the overwrite is also forced through the
        // engine (the reader-repopulation hole).
        let pr2 = s.read_async(&p).unwrap();
        assert!(
            matches!(pr2, PendingRead::InFlight(_)),
            "first miss re-cached a dirty key; second read served stale"
        );
        w.finish().unwrap();
        s.finish_write(pending).unwrap();
        let _ = pr.wait(); // whatever it raced to see; must not hang
        let _ = pr2.wait();
        assert_eq!(s.read(&p).unwrap(), vec![8u8; 10]);
    }

    #[test]
    fn abandoned_pending_write_releases_dirty_mark() {
        // Dropping a PendingWrite without finish_write (an error-path
        // `?` in a caller) must not leave the key dirty forever —
        // later, properly-finished writes must make it cacheable
        // again.
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-abandon-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut m = fast_model("one");
        m.channels = 1; // single worker: probe below is a barrier
        let s = StorageSim::new(
            dir,
            vec![m],
            1 << 30,
            Arc::new(crate::storage::device::NullObserver),
        )
        .unwrap();
        let p = SimPath::new("one", "x.bin");
        s.write(&p, &vec![1u8; 100]).unwrap();
        let pending = s.write_async(&p, vec![2u8; 50]).unwrap();
        drop(pending); // abandoned, write still in flight
        // Same-class FIFO on the single worker: once the probe is
        // done, the abandoned write has fully landed.
        s.probe_write("one", 1).unwrap();
        s.write(&p, &vec![3u8; 77]).unwrap();
        // The key is clean again: cached and served warm.
        assert!(
            matches!(s.read_async(&p).unwrap(), PendingRead::Ready(_)),
            "abandoned write left the key permanently uncacheable"
        );
        assert_eq!(s.read(&p).unwrap(), vec![3u8; 77]);
    }

    #[test]
    fn write_stream_roundtrips_through_engine() {
        let s = sim("stream");
        let p = SimPath::new("ssd", "ck/stream.bin");
        let (mut w, pending) = s.write_stream(&p).unwrap();
        let payload: Vec<u8> = (0..300_000u32).map(|i| (i % 253) as u8).collect();
        for piece in payload.chunks(7001) {
            w.push(piece).unwrap();
        }
        w.finish().unwrap();
        assert_eq!(s.finish_write(pending).unwrap(), payload.len() as u64);
        assert_eq!(s.read(&p).unwrap(), payload);
    }

    #[test]
    fn fault_plan_arms_matching_devices_and_rejects_unknown() {
        use crate::storage::fault::{FaultPlan, HealthState};
        let s = sim("fault");
        s.apply_fault_plan(&FaultPlan::parse("offline:hdd").unwrap())
            .unwrap();
        assert_eq!(
            s.device("hdd").unwrap().health_state(),
            HealthState::Offline
        );
        assert_eq!(
            s.device("ssd").unwrap().health_state(),
            HealthState::Healthy
        );
        // Writes on the offline device fail; the healthy one serves.
        assert!(s.write(&SimPath::new("hdd", "x.bin"), b"x").is_err());
        s.write(&SimPath::new("ssd", "x.bin"), b"x").unwrap();
        // Re-arming the no-fault plan recovers everything.
        s.apply_fault_plan(&FaultPlan::none()).unwrap();
        s.write(&SimPath::new("hdd", "x.bin"), b"x").unwrap();
        // Unknown target errors, listing this sim's device names.
        let err = s
            .apply_fault_plan(&FaultPlan::parse("offline:optane").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("optane") && err.contains("hdd")
                    && err.contains("ssd"),
                "unhelpful fault-plan error: {err}");
    }
}
